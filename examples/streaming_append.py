"""Streaming-append scenario: a live dashboard that survives data ticks.

The classic failure mode of snapshot invalidation (§6.2) is a dashboard of
open-ended intents losing its whole working set every time a micro-batch of
rows lands, then paying full scans to rebuild it.  With incremental refresh,
``advance_snapshot(delta=...)`` appends the rows, scans *only the delta
partition* as one fused batch, and merges the delta aggregates into the
cached tables — every tile stays a cache hit, and each tile's table is
verified here against an independent numpy full rescan of the grown table.

    PYTHONPATH=src python examples/streaming_append.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import SemanticCache  # noqa: E402
from repro.olap.executor import OlapExecutor  # noqa: E402
from repro.service import CacheService, QueryRequest  # noqa: E402
from repro.workloads import ssb  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks pkg
from benchmarks.bench_refresh import DASHBOARD, make_delta  # noqa: E402

ROWS, DELTA, TICKS = 60_000, 2_000, 3

print(f"building SSB with {ROWS:,} fact rows ...")
wl = ssb.build(n_fact=ROWS, seed=0)
backend = OlapExecutor(wl.dataset, impl="numpy")  # oracle impl: runs anywhere
svc = CacheService()
svc.register_tenant("live", schema=wl.schema, backend=backend,
                    cache=SemanticCache(wl.schema,
                                        level_mapper=wl.dataset.level_mapper()))

reqs = [QueryRequest(sql=q, tenant="live") for q in DASHBOARD]
svc.submit_batch(reqs)  # cold warm-up: every tile misses once
cache = svc.tenant("live").cache
print(f"warmed {len(cache)} dashboard tiles (snapshot {wl.dataset.snapshot_id})")

rng = np.random.default_rng(42)
for tick in range(1, TICKS + 1):
    delta = make_delta(wl.dataset, DELTA, rng)
    rep = svc.advance_snapshot("live", f"snap{tick}", delta=delta)
    served = svc.submit_batch(
        [QueryRequest(sql=q, tenant="live", read_only=True) for q in DASHBOARD])
    hits = sum(1 for r in served if r.hit)
    print(f"tick {tick}: +{rep.appended_rows:,} rows "
          f"[{rep.updated_start}, {rep.updated_end}) -> "
          f"{rep.refreshed} merged / {rep.recomputed} recomputed / "
          f"{rep.unaffected} untouched; dashboard: {hits}/{len(served)} hits, "
          f"{rep.delta_rows_scanned:,} rows scanned")

# trust, but verify: served tables match a full rescan of the grown table
oracle = OlapExecutor(wl.dataset, impl="numpy")
served = svc.submit_batch(
    [QueryRequest(sql=q, tenant="live", read_only=True) for q in DASHBOARD])
assert all(r.hit and r.table.equals(oracle.execute(r.signature)) for r in served)
s = cache.stats
print(f"verified {len(served)} tiles against full-rescan oracle at "
      f"{wl.dataset.fact.num_rows:,} rows")
print(f"cache stats: {s.refreshes} delta merges, {s.refresh_fallbacks} "
      f"fallback recomputes, {s.invalidations} invalidations, "
      f"hit rate {s.hit_rate:.3f}")
