"""End-to-end driver: train the ~100M canonicalizer LM on NL->signature pairs
for a few hundred steps with checkpointing, then serve it with grammar-
constrained JSON decoding and measure held-out canonicalization accuracy.

Reduced defaults keep a single CPU core busy for a few minutes; pass
--full for the real 100M config / 300 steps (the production path).

    PYTHONPATH=src python examples/train_canonicalizer.py [--full]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.registry import get, reduced
from repro.core.sql_canon import SQLCanonicalizer
from repro.serving.engine import CanonicalizerService, ServingEngine
from repro.training.data import BatchIterator, build_pairs
from repro.training.tokenizer import build_tokenizer
from repro.training.train_lib import TrainConfig, train
from repro.workloads import ssb
from repro.workloads.paraphrase import gen_paraphrases

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

wl = ssb.build(n_fact=2000)
tok = build_tokenizer([wl])
pairs = build_pairs([wl], paraphrases_per_intent=24)
print(f"{len(pairs)} training pairs, tokenizer vocab {tok.vocab_size}")

if args.full:
    cfg = get("canonicalizer-100m")
    steps = args.steps or 300
    batch, seq = 16, 192
else:
    cfg = dataclasses.replace(reduced("canonicalizer-100m"),
                              n_layers=4, d_model=256, d_ff=512, vocab=4096,
                              n_heads=8, kv_heads=4, head_dim=32)
    steps = args.steps or 120
    batch, seq = 8, 128

batches = BatchIterator(pairs, tok, batch=batch, seq_len=seq)
out = train(cfg, TrainConfig(steps=steps, ckpt_dir="ckpts/canonicalizer",
                             ckpt_every=50, log_every=20),
            batches, key=jax.random.PRNGKey(0))

# ---- held-out evaluation through the real serving engine
engine = ServingEngine(cfg, out["params"], tok, max_len=256)
svc = CanonicalizerService(engine, wl.schema.name)
canon = SQLCanonicalizer(wl.schema)
correct = parsed = 0
held_out = []
for i, intent in enumerate(wl.intents[:8]):
    gold = canon.canonicalize(intent.sql)
    text = gen_paraphrases(intent, n=40, seed=777 + i)[-1]  # unseen template mix
    held_out.append((text, gold))
for text, gold in held_out:
    r = svc.canonicalize(text)
    parsed += r.signature is not None
    correct += r.signature is not None and r.signature.key() == gold.key()
    verdict = ("EXACT" if r.signature is not None and r.signature.key() == gold.key()
               else ("valid-json" if r.signature else "reject"))
    print(f"  conf={r.confidence:.2f} {verdict:10s} | {text[:56]}")
    if verdict == "reject":
        print(f"      emitted: {r.raw_json[:90]!r}")
print(f"\nheld-out: {parsed}/{len(held_out)} parseable signatures, "
      f"{correct}/{len(held_out)} exact intent matches "
      f"(training longer / --full improves this; the safety layer gates the rest)")
