"""Dashboard session replay on the batch-first service API: a BI tool, a
notebook, and an NL interface all hitting one CacheService tenant over NYC
TLC data — the paper's cross-client fragmentation story, plus LRU behaviour
under a Zipf request mix.  Requests arrive in refresh-sized batches, so each
wave's cache misses are deduped and executed as one shared backend scan.

    PYTHONPATH=src python examples/dashboard_session.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (MemoizedNL, SafetyPolicy, SemanticCache,
                        SimulatedLLM)
from repro.olap.executor import OlapExecutor
from repro.service import CacheService, QueryRequest
from repro.workloads import nyc_tlc

REFRESH = 8  # tiles per dashboard refresh wave

wl = nyc_tlc.build(n_fact=60_000)
backend = OlapExecutor(wl.dataset)
cache = SemanticCache(wl.schema, capacity=10,  # ~half the intent set: LRU visible
                      level_mapper=wl.dataset.level_mapper())
svc = CacheService()
tenant = svc.register_tenant(
    "tlc", schema=wl.schema, backend=backend, cache=cache,
    nl=MemoizedNL(SimulatedLLM(wl.vocab, model="gpt-4o-mini")),
    policy=SafetyPolicy.balanced(
        wl.spatial_ambiguous,
        qualified=("pickup zone", "dropoff zone", "pickup borough", "dropoff borough")))

stream = wl.queries(order="zipf", seed=7)[:400]
reqs = [QueryRequest(sql=q.text, tenant="tlc") if q.kind == "sql"
        else QueryRequest(nl=q.text, tenant="tlc") for q in stream]
for i in range(0, len(reqs), REFRESH):
    svc.submit_batch(reqs[i:i + REFRESH])

s = cache.stats
t = tenant.stats
print(f"zipf dashboard mix over {len(stream)} requests, "
      f"waves of {REFRESH}, cache capacity 10 intents")
print(f"  hit rate        : {s.hit_rate:.3f}")
print(f"  exact / rollup  : {s.hits_exact} / {s.hits_rollup}")
print(f"  cross-surface   : {s.cross_surface_hits} (NL served by SQL-seeded entries or v.v.)")
print(f"  evictions       : {s.evictions} (LRU)")
print(f"  batched misses  : {t.batched_misses} (served by shared execute_batch scans)")
print(f"  deduped in-batch: {t.deduped_misses} (identical in-flight intents coalesced)")
print(f"  backend executes: {backend.executions} "
      f"({backend.rows_scanned:,} fact rows scanned vs "
      f"{len(stream) * wl.dataset.fact.num_rows:,} without the cache)")

# data refresh: new partition arrives -> open/intersecting windows invalidated
# (examples/streaming_append.py shows the delta path that refreshes in place)
rep = svc.advance_snapshot("tlc", "snap1", "2024-12-01", "2025-01-01")
print(f"  invalidated on refresh of [2024-12-01, 2025-01-01): {rep.dropped} entries")

# warm the next day's dashboard through the same pipeline the live path uses
warmed = svc.warm(reqs[:REFRESH])
print(f"  warm({REFRESH} tiles)  : "
      f"{sum(1 for r in warmed if r.status == 'miss')} re-executed, "
      f"{sum(1 for r in warmed if r.hit)} already present")
