"""Dashboard session replay: a BI tool, a notebook, and an NL interface all
hitting the same middleware over NYC TLC data — the paper's cross-client
fragmentation story, plus LRU behaviour under a Zipf request mix.

    PYTHONPATH=src python examples/dashboard_session.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (MemoizedNL, SafetyPolicy, SemanticCache,
                        SemanticCacheMiddleware, SimulatedLLM)
from repro.olap.executor import OlapExecutor
from repro.workloads import nyc_tlc

wl = nyc_tlc.build(n_fact=60_000)
backend = OlapExecutor(wl.dataset)
cache = SemanticCache(wl.schema, capacity=10,  # ~half the intent set: LRU visible
                      level_mapper=wl.dataset.level_mapper())
mw = SemanticCacheMiddleware(
    wl.schema, backend, cache,
    nl=MemoizedNL(SimulatedLLM(wl.vocab, model="gpt-4o-mini")),
    policy=SafetyPolicy.balanced(
        wl.spatial_ambiguous,
        qualified=("pickup zone", "dropoff zone", "pickup borough", "dropoff borough")))

stream = wl.queries(order="zipf", seed=7)[:400]
for q in stream:
    if q.kind == "sql":
        mw.query_sql(q.text)
    else:
        mw.query_nl(q.text)

s = cache.stats
print(f"zipf dashboard mix over {len(stream)} requests, cache capacity 10 intents")
print(f"  hit rate        : {s.hit_rate():.3f}")
print(f"  exact / rollup  : {s.hits_exact} / {s.hits_rollup}")
print(f"  cross-surface   : {s.cross_surface_hits} (NL served by SQL-seeded entries or v.v.)")
print(f"  evictions       : {s.evictions} (LRU)")
print(f"  backend executes: {backend.executions} "
      f"({backend.rows_scanned:,} fact rows scanned vs "
      f"{len(stream) * wl.dataset.fact.num_rows:,} without the cache)")

# data refresh: new partition arrives -> open/intersecting windows invalidated
dropped = cache.invalidate_snapshot("2024-12-01", "2025-01-01")
print(f"  invalidated on refresh of [2024-12-01, 2025-01-01): {dropped} entries")
