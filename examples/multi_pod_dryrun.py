"""Production-mesh walkthrough: lower + compile one (arch x shape) cell on
the 2x16x16 multi-pod mesh and print its memory / cost / collective report —
the same machinery `python -m repro.launch.dryrun --all` sweeps over all
64 cells.

    PYTHONPATH=src python examples/multi_pod_dryrun.py [arch] [shape]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell  # noqa: E402

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-32b"
shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"

for variant in ("baseline", "kv_seq_shard") if shape == "decode_32k" else ("baseline",):
    r = run_cell(arch, shape, "multi", variant=variant)
    m = r["memory"]
    c = r["collectives"]
    print(f"\n== {arch} x {shape} x 2x16x16 pods [{variant}] "
          f"(compiled in {r['compile_s']}s)")
    print(f"  params            : {r['params_total']/1e9:.1f}B total, "
          f"{r['params_active']/1e9:.1f}B active")
    print(f"  per-device memory : args {m['argument_bytes']/1e9:.2f} GB, "
          f"temp {m['temp_bytes']/1e9:.2f} GB, out {m['output_bytes']/1e9:.2f} GB")
    print(f"  global FLOPs      : {r['flops_global']:.3e}")
    print(f"  collectives       : " + ", ".join(
        f"{k} {v/1e9:.2f} GB" for k, v in sorted(c["bytes_by_kind"].items())))
