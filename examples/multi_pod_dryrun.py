"""Multi-device cold-scan walkthrough: a dashboard miss burst served by the
partition-parallel scan plane across 8 virtual host devices.

Forces 8 CPU devices (the same trick the launch dryrun uses for mesh
shapes), registers an SSB tenant whose backend is
``OlapExecutor(partitions=8)``, and submits a cold dashboard through
:class:`CacheService`.  The miss burst runs ONE shared partitioned scan —
each partition pinned to its own device via ``jax.default_device`` — and
the merged results are cross-checked against an unpartitioned
``partitions=1`` oracle.  Prints per-partition row/launch accounting and
the warm-pass hit statuses.

    PYTHONPATH=src python examples/multi_pod_dryrun.py [n_fact_rows]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402
import time  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.olap.executor import OlapExecutor  # noqa: E402
from repro.service.api import QueryRequest  # noqa: E402
from repro.service.service import CacheService  # noqa: E402
from repro.workloads import ssb  # noqa: E402

N_FACT = int(sys.argv[1]) if len(sys.argv) > 1 else 200_000

_JOINS = ("JOIN dates ON lineorder.lo_orderdate = dates.d_key "
          "JOIN customer ON lineorder.lo_custkey = customer.c_key "
          "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
          "JOIN part ON lineorder.lo_partkey = part.p_key ")
_DASHBOARD = [
    f"SELECT c_region, SUM(lo_revenue) AS rev, AVG(lo_quantity) AS q, "
    f"COUNT(*) AS n FROM lineorder {_JOINS}WHERE d_year = {y} GROUP BY c_region"
    for y in (1993, 1995, 1997)
] + [
    f"SELECT p_mfgr, SUM(lo_revenue) AS rev, MIN(lo_supplycost) AS lo, "
    f"MAX(lo_supplycost) AS hi FROM lineorder {_JOINS}"
    f"WHERE s_region = 'AMERICA' GROUP BY p_mfgr",
]

devices = jax.local_devices()
print(f"== scan plane across {len(devices)} host devices "
      f"({devices[0].platform} x{len(devices)})")

print(f"building SSB: {N_FACT:,} fact rows ...")
wl = ssb.build(n_fact=N_FACT, seed=0)

svc = CacheService()
svc.register_tenant("dash", schema=wl.schema,
                    backend=OlapExecutor(wl.dataset, partitions=8))

reqs = [QueryRequest(sql=q, tenant="dash") for q in _DASHBOARD]
t0 = time.perf_counter()
cold = svc.submit_batch(reqs)
cold_s = time.perf_counter() - t0
print(f"\ncold burst: {len(cold)} queries in {cold_s:.2f}s "
      f"(statuses: {sorted({r.status for r in cold})})")
print(f"  provenance tail: {cold[0].provenance[-2:]}")

st = svc.tenant("dash").backend.stats()
print(f"  partitioned scans : {st['partitioned_scans']} "
      f"(one shared scan for the whole burst)")
print(f"  rows scanned      : {st['rows_scanned']:,} "
      f"(same-shape queries share one pass over the {N_FACT:,} rows)")
print("  per-partition accounting:")
for p in st["per_partition"]:
    print(f"    rows [{p['start']:>7,}, {p['end']:>7,})  "
          f"scanned {p['rows_scanned']:>9,}  launches {p['executions']}")

warm = svc.submit_batch([QueryRequest(sql=q, tenant="dash") for q in _DASHBOARD])
print(f"\nwarm pass: statuses {sorted({r.status for r in warm})} "
      f"(served from cache, no scan)")

print("\ncross-checking merged results vs partitions=1 oracle ...")
oracle = OlapExecutor(wl.dataset, partitions=1)
svc2 = CacheService()
svc2.register_tenant("oracle", schema=wl.schema, backend=oracle)
expect = svc2.submit_batch([QueryRequest(sql=q, tenant="oracle") for q in _DASHBOARD])
bad = [i for i, (g, e) in enumerate(zip(cold, expect))
       if not g.table.equals(e.table, rtol=1e-3)]
if bad:
    raise SystemExit(f"MISMATCH vs unpartitioned oracle: queries {bad}")
print(f"  all {len(cold)} merged results match the unpartitioned oracle")
