"""Quickstart: the paper's full pipeline in ~40 lines.

Builds the SSB workload (schema + synthetic data), boots the semantic cache
middleware with the calibrated NL canonicalizer, runs a mixed SQL/NL
dashboard session, and prints the cache's view of it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (MemoizedNL, SafetyPolicy, SemanticCache,
                        SemanticCacheMiddleware, SimulatedLLM)
from repro.olap.executor import OlapExecutor
from repro.workloads import ssb

wl = ssb.build(n_fact=30_000)
backend = OlapExecutor(wl.dataset)
cache = SemanticCache(wl.schema, level_mapper=wl.dataset.level_mapper())
mw = SemanticCacheMiddleware(
    wl.schema, backend, cache,
    nl=MemoizedNL(SimulatedLLM(wl.vocab, model="oracle")),
    policy=SafetyPolicy.balanced(wl.spatial_ambiguous,
                                 qualified=("customer region", "supplier region")),
)

requests = [
    # fine-grain query populates the cache (cold miss)
    ("sql", "SELECT c_nation, SUM(lo_revenue) AS revenue FROM lineorder "
            "JOIN customer ON lineorder.lo_custkey = customer.c_key "
            "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
            "WHERE d_year = 1994 GROUP BY c_nation"),
    # same intent, different SQL surface form -> exact hit
    ("sql", "select SUM(lo_revenue) revenue, c_nation from lineorder "
            "join dates on dates.d_key = lineorder.lo_orderdate "
            "join customer on customer.c_key = lineorder.lo_custkey "
            "where lo_date >= '1994-01-01' and lo_date < '1995-01-01' "
            "group by c_nation"),
    # same intent in natural language -> cross-surface exact hit
    ("nl", "Show total revenue by customer nation in 1994"),
    # coarser grouping -> answered by roll-up derivation, no backend touch
    ("sql", "SELECT c_region, SUM(lo_revenue) AS revenue FROM lineorder "
            "JOIN customer ON lineorder.lo_custkey = customer.c_key "
            "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
            "WHERE d_year = 1994 GROUP BY c_region"),
    # global total -> roll-up to the empty grouping
    ("nl", "What is total revenue in 1994?"),
]

for kind, text in requests:
    r = mw.query_sql(text) if kind == "sql" else mw.query_nl(text)
    rows = r.table.num_rows if r.table is not None else 0
    print(f"[{kind:3s}] {r.status:15s} rows={rows:3d}  {text[:60]}...")

s = cache.stats
print(f"\nhits: exact={s.hits_exact} rollup={s.hits_rollup} "
      f"cross_surface={s.cross_surface_hits} | misses={s.misses} "
      f"| backend executions={backend.executions}")
