"""Systematic SQL variant generation (§5.1: 21 variants per canonical intent).

AST-level rewrites (alias renaming, predicate/join/group-by reordering,
BETWEEN <-> inequality pairs, single-element IN <-> equality, commutative
operand swaps, time-dimension <-> raw-date-range predicates) composed with
text-level styles (keyword case, layout, AS/INNER/ASC toggles, comments).
Every variant is verified to canonicalize to the *same* intent signature as
the canonical query — they are surface forms of one intent, which is what
makes ground-truth hit-rate measurement possible.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import itertools
import random
from typing import Callable

from ..core import sqlparse as sp
from ..core.schema import StarSchema
from ..core.sql_canon import SQLCanonicalizer
from .render import Style, render

# ---------------------------------------------------------- AST-level rewrites


def rename_aliases(q: sp.Query, naming: str) -> sp.Query:
    """naming: 'long' (table-name aliases) or 'tN' (positional)."""
    mapping: dict[str, str] = {}
    if naming == "long":
        mapping[q.alias] = q.table
        for j in q.joins:
            mapping[j.alias] = j.table
    else:
        mapping[q.alias] = "t0"
        for i, j in enumerate(q.joins):
            mapping[j.alias] = f"t{i + 1}"

    def fix_col(c: sp.ColRef) -> sp.ColRef:
        if c.table is not None and c.table in mapping:
            return sp.ColRef(mapping[c.table], c.column)
        return c

    def fix_expr(e: sp.Expr) -> sp.Expr:
        if isinstance(e, sp.ColRef):
            return fix_col(e)
        if isinstance(e, sp.BinOp):
            return sp.BinOp(e.op, fix_expr(e.left), fix_expr(e.right))
        if isinstance(e, sp.AggCall):
            return sp.AggCall(e.func, None if e.arg is None else fix_expr(e.arg), e.distinct)
        return e

    def fix_pred(p: sp.Predicate) -> sp.Predicate:
        right = p.right
        if isinstance(right, sp.ColRef):
            right = fix_col(right)
        elif isinstance(right, (sp.BinOp,)):
            right = fix_expr(right)
        return sp.Predicate(fix_expr(p.left), p.op, right)

    return sp.Query(
        select=tuple(sp.SelectItem(fix_expr(s.expr), s.alias) for s in q.select),
        table=q.table,
        alias=mapping[q.alias],
        joins=tuple(
            sp.Join(j.table, mapping[j.alias], fix_col(j.left), fix_col(j.right))
            for j in q.joins
        ),
        where=tuple(fix_pred(p) for p in q.where),
        group_by=tuple(fix_col(c) for c in q.group_by),
        having=tuple(fix_pred(p) for p in q.having),
        order_by=tuple((fix_expr(e), d) for e, d in q.order_by),
        limit=q.limit,
    )


def shuffle_predicates(q: sp.Query, seed: int) -> sp.Query:
    where = list(q.where)
    random.Random(seed).shuffle(where)
    return dataclasses.replace(q, where=tuple(where))


def shuffle_joins(q: sp.Query, seed: int) -> sp.Query:
    joins = list(q.joins)
    random.Random(seed).shuffle(joins)
    return dataclasses.replace(q, joins=tuple(joins))


def shuffle_group_by(q: sp.Query, seed: int) -> sp.Query:
    g = list(q.group_by)
    random.Random(seed).shuffle(g)
    return dataclasses.replace(q, group_by=tuple(g))


def between_to_ineq(q: sp.Query) -> sp.Query:
    out = []
    for p in q.where:
        if p.op == "between":
            lo, hi = p.right
            out.append(sp.Predicate(p.left, ">=", lo))
            out.append(sp.Predicate(p.left, "<=", hi))
        else:
            out.append(p)
    return dataclasses.replace(q, where=tuple(out))


def eq_to_in(q: sp.Query) -> sp.Query:
    """x = v  ->  x IN (v): same semantics, different surface form."""
    out = []
    changed = False
    for p in q.where:
        if p.op == "=" and isinstance(p.right, sp.Literal) and not changed:
            out.append(sp.Predicate(p.left, "in", [p.right]))
            changed = True
        else:
            out.append(p)
    return dataclasses.replace(q, where=tuple(out))


def swap_comparison_sides(q: sp.Query) -> sp.Query:
    """quantity < 25  ->  25 > quantity (first applicable predicate)."""
    flip = {"<": ">", ">": "<", "<=": ">=", ">=": "<=", "=": "=", "!=": "!="}
    out = []
    changed = False
    for p in q.where:
        if (
            not changed
            and p.op in flip
            and isinstance(p.right, sp.Literal)
            and isinstance(p.left, sp.ColRef)
        ):
            out.append(sp.Predicate(p.right, flip[p.op], p.left))
            changed = True
        else:
            out.append(p)
    return dataclasses.replace(q, where=tuple(out))


def commute_expressions(q: sp.Query) -> sp.Query:
    """Swap operands of commutative ops inside measure expressions."""

    def fix(e: sp.Expr) -> sp.Expr:
        if isinstance(e, sp.BinOp):
            l, r = fix(e.left), fix(e.right)
            if e.op in ("*", "+"):
                return sp.BinOp(e.op, r, l)
            return sp.BinOp(e.op, l, r)
        if isinstance(e, sp.AggCall) and e.arg is not None:
            return sp.AggCall(e.func, fix(e.arg), e.distinct)
        return e

    return dataclasses.replace(
        q, select=tuple(sp.SelectItem(fix(s.expr), s.alias) for s in q.select)
    )


def time_level_to_date_range(q: sp.Query, schema: StarSchema) -> sp.Query | None:
    """Rewrite a time-dimension level predicate (d_year = 1997) into the
    equivalent raw-date-range predicate on the fact date column.  Returns None
    when not applicable (no such predicate / no fact date column)."""
    if schema.fact.date_column is None or schema.time_dimension is None:
        return None
    tdim = schema.dimension(schema.time_dimension)
    alias_to_table = {q.alias: q.table, **{j.alias: j.table for j in q.joins}}
    from ..core.sql_canon import _kind_window  # shared canonical window logic

    fact_alias = q.alias
    out, found = [], False
    for p in q.where:
        if (
            not found
            and isinstance(p.left, sp.ColRef)
            and isinstance(p.right, sp.Literal)
            and p.op == "="
        ):
            tab = alias_to_table.get(p.left.table, p.left.table) if p.left.table else None
            if tab is None:
                try:
                    tab, _ = schema.resolve_column(p.left.column)
                except Exception:
                    tab = None
            if tab == tdim.name:
                kind = tdim.time_kind(p.left.column)
                if kind:
                    w = _kind_window(kind, p.right.value)
                    if w:
                        start, end = w
                        dcol = sp.ColRef(fact_alias, schema.fact.date_column)
                        out.append(sp.Predicate(dcol, ">=", sp.Literal(start)))
                        out.append(sp.Predicate(dcol, "<", sp.Literal(end)))
                        found = True
                        continue
        out.append(p)
    if not found:
        return None
    return dataclasses.replace(q, where=tuple(out))


# ------------------------------------------------------------- the generator

AstRewrite = Callable[[sp.Query], sp.Query]


def make_variants(canonical_sql: str, schema: StarSchema, n: int = 21, seed: int = 0):
    """Produce ``n`` SQL texts (the canonical query first) that all
    canonicalize to the same intent signature."""
    base = sp.parse(canonical_sql)
    canon = SQLCanonicalizer(schema)
    want_key = canon.from_ast(base).key()

    ast_forms: list[sp.Query] = [base]

    def add(q: sp.Query | None):
        if q is None:
            return
        try:
            if canon.from_ast(q).key() == want_key:
                ast_forms.append(q)
        except Exception:
            pass

    add(rename_aliases(base, "long"))
    add(rename_aliases(base, "tN"))
    add(shuffle_predicates(base, seed + 1))
    add(shuffle_predicates(base, seed + 2))
    add(shuffle_joins(base, seed + 3))
    add(shuffle_group_by(base, seed + 4))
    add(between_to_ineq(base))
    add(eq_to_in(base))
    add(swap_comparison_sides(base))
    add(commute_expressions(base))
    add(time_level_to_date_range(base, schema))
    add(shuffle_predicates(rename_aliases(base, "long"), seed + 5))
    add(between_to_ineq(rename_aliases(base, "tN")))
    add(commute_expressions(shuffle_predicates(base, seed + 6)))

    styles = [
        Style(),
        Style(upper_keywords=False),
        Style(newlines=False),
        Style(use_as=False),
        Style(explicit_inner=True),
        Style(explicit_asc=True, trailing_semicolon=True),
        Style(leading_comment="dashboard tile 7", compact=True),
        Style(upper_keywords=False, use_as=False, newlines=False),
    ]

    texts: list[str] = []
    seen: set[str] = set()
    for ast, style in itertools.product(ast_forms, styles):
        t = render(ast, style)
        if t not in seen:
            seen.add(t)
            texts.append(t)
        if len(texts) >= 4 * n:
            break
    # deterministic selection: canonical first, then spread across the list
    rnd = random.Random(seed + 99)
    rest = texts[1:]
    rnd.shuffle(rest)
    out = [texts[0]] + rest[: n - 1]
    while len(out) < n:  # degenerate intents with few distinct forms
        out.append(texts[0])
    # ground-truth guarantee
    for t in out:
        k = canon.canonicalize(t).key()
        assert k == want_key, f"variant diverged from intent:\n{t}"
    return out


_WINDOW_KIND_IMPORT_GUARD = _dt.date  # keep datetime import (used by rewrites)
