"""Workload infrastructure: intents, datasets, and query streams.

A workload = a star schema + synthetic columnar data + a set of canonical
intents.  Each intent expands into 21 SQL variants (variants.py) and 10 NL
paraphrases (paraphrase.py), reproducing the paper's 1,395-query evaluation
corpus (945 SQL + 450 NL over 45 intents: TPC-DS 14, SSB 13, NYC TLC 18).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

import numpy as np

from ..core.nl_canon import NLVocab
from ..core.schema import StarSchema
from ..olap.columnar import Dataset
from .variants import make_variants


@dataclasses.dataclass
class Intent:
    id: str
    sql: str  # canonical SQL text
    # NL building blocks (consumed by paraphrase.py): measure phrases like
    # 'total revenue', grouping nouns, filter phrases, time phrase, extras.
    nl_measures: tuple[str, ...] = ()
    nl_levels: tuple[str, ...] = ()
    nl_filters: tuple[str, ...] = ()
    nl_time: Optional[str] = None
    nl_extra: Optional[str] = None  # e.g. 'top 10'
    tags: frozenset = frozenset()


@dataclasses.dataclass
class Query:
    """One element of the evaluation stream."""

    workload: str
    intent_id: str
    kind: str  # 'sql' | 'nl'
    text: str
    variant_idx: int


@dataclasses.dataclass
class Workload:
    name: str
    schema: StarSchema
    dataset: Dataset
    intents: list[Intent]
    vocab: NLVocab
    spatial_ambiguous: tuple = ()

    def queries(
        self,
        sql_variants: int = 21,
        nl_paraphrases: int = 10,
        order: str = "sequential",
        seed: int = 0,
        zipf_a: float = 1.4,
        repeat_factor: int = 1,
    ) -> list[Query]:
        """Expand intents into the evaluation stream.

        order: 'sequential' (all forms of an intent consecutively — dashboard
        refresh pattern), 'interleaved' (round-robin across intents), 'random',
        or 'zipf' (popularity-skewed sampling with replacement).
        """
        from .paraphrase import gen_paraphrases

        per_intent: list[list[Query]] = []
        for i, intent in enumerate(self.intents):
            qs: list[Query] = []
            for vi, sql in enumerate(
                make_variants(intent.sql, self.schema, n=sql_variants, seed=seed + i)
            ):
                qs.append(Query(self.name, intent.id, "sql", sql, vi))
            for pi, text in enumerate(
                gen_paraphrases(intent, n=nl_paraphrases, seed=seed + 1000 + i)
            ):
                qs.append(Query(self.name, intent.id, "nl", text, pi))
            per_intent.append(qs)

        rnd = random.Random(seed + 7)
        if order == "sequential":
            return [q for qs in per_intent for q in qs]
        if order == "interleaved":
            out: list[Query] = []
            for round_idx in range(max(len(qs) for qs in per_intent)):
                for qs in per_intent:
                    if round_idx < len(qs):
                        out.append(qs[round_idx])
            return out
        if order == "random":
            flat = [q for qs in per_intent for q in qs]
            rnd.shuffle(flat)
            return flat
        if order == "zipf":
            flat_by_intent = per_intent
            total = sum(len(qs) for qs in per_intent) * repeat_factor
            ranks = np.arange(1, len(per_intent) + 1, dtype=np.float64)
            probs = ranks ** (-zipf_a)
            probs /= probs.sum()
            rs = np.random.default_rng(seed + 11)
            out = []
            for intent_idx in rs.choice(len(per_intent), size=total, p=probs):
                qs = flat_by_intent[intent_idx]
                out.append(qs[rs.integers(0, len(qs))])
            return out
        raise ValueError(f"unknown order {order!r}")


def dict_columns(n: int, rng: np.random.Generator, values: list[str]) -> np.ndarray:
    return np.asarray(values)[rng.integers(0, len(values), size=n)]
