"""TPC-DS-style workload (14 in-scope canonical intents, §3.1/§5.1).

The paper keeps 14 of 99 TPC-DS templates — the dashboard-shaped aggregations
without window functions / CTEs / set operations.  This module mirrors that
in-scope fragment over a store_sales star: more multi-measure ("compositional")
and HAVING/top-k intents than SSB or TLC, which is what drives its lower NL
coverage in the paper's Table 1.
"""
from __future__ import annotations

import datetime as _dt

import numpy as np

from ..core.nl_canon import MeasureSense, NLVocab
from ..core.schema import Column, Dimension, FactTable, Hierarchy, StarSchema
from ..olap.columnar import ColumnData, Dataset, TableData
from .base import Intent, Workload

STATES = ["CA", "NY", "TX", "WA", "IL", "FL", "GA", "MI", "OH", "PA"]
CHANNELS = ["email", "tv", "radio", "web"]
CATEGORIES = ["Books", "Electronics", "Home", "Jewelry", "Men", "Music",
              "Shoes", "Sports", "Toys", "Women"]


def build_schema() -> StarSchema:
    date_dim = Dimension(
        name="date_dim", fact_fk="ss_sold_date_key", pk="d_key",
        columns=(
            Column("d_key", "int"), Column("d_date", "date"),
            Column("d_yearmonth", "str"), Column("d_quarter", "str"),
            Column("d_year", "int"),
        ),
        hierarchies=(Hierarchy("time", ("d_date", "d_yearmonth", "d_quarter", "d_year")),),
        time_kinds=(
            ("d_date", "date"), ("d_year", "year"),
            ("d_yearmonth", "yearmonth_str"), ("d_quarter", "yearquarter_str"),
        ),
    )
    item = Dimension(
        name="item", fact_fk="ss_item_key", pk="i_key",
        columns=(
            Column("i_key", "int"), Column("i_brand", "str"),
            Column("i_class", "str"), Column("i_category", "str"),
        ),
        hierarchies=(Hierarchy("prod", ("i_brand", "i_class", "i_category")),),
    )
    store = Dimension(
        name="store", fact_fk="ss_store_key", pk="s_key",
        columns=(
            Column("s_key", "int"), Column("s_store_name", "str"),
            Column("s_county", "str"), Column("s_state", "str"),
        ),
        hierarchies=(Hierarchy("geo", ("s_store_name", "s_county", "s_state")),),
    )
    promotion = Dimension(
        name="promotion", fact_fk="ss_promo_key", pk="p_key",
        columns=(Column("p_key", "int"), Column("p_channel", "str")),
    )
    fact = FactTable(
        name="store_sales",
        columns=(
            Column("ss_sold_date_key", "int"), Column("ss_item_key", "int"),
            Column("ss_store_key", "int"), Column("ss_promo_key", "int"),
            Column("ss_quantity", "int"), Column("ss_ext_sales_price", "float"),
            Column("ss_net_paid", "float"), Column("ss_net_profit", "float"),
            Column("ss_coupon_amt", "float"), Column("ss_date", "date"),
        ),
        date_column="ss_date",
    )
    sch = StarSchema("tpcds", fact, (date_dim, item, store, promotion),
                     time_dimension="date_dim")
    sch.validate()
    return sch


def build_dataset(schema: StarSchema, n_fact: int = 150_000, seed: int = 2) -> Dataset:
    rng = np.random.default_rng(seed)
    start = _dt.date(2000, 1, 1)
    days = (_dt.date(2003, 12, 31) - start).days + 1
    all_dates = [start + _dt.timedelta(days=i) for i in range(days)]
    mon = ["Jan", "Feb", "Mar", "Apr", "May", "Jun",
           "Jul", "Aug", "Sep", "Oct", "Nov", "Dec"]
    date_dim = TableData("date_dim", {
        "d_key": ColumnData("int", np.arange(days)),
        "d_date": ColumnData("date", np.asarray([d.isoformat() for d in all_dates])),
        "d_yearmonth": ColumnData("str", np.asarray(
            [f"{mon[d.month - 1]}{d.year}" for d in all_dates])),
        "d_quarter": ColumnData("str", np.asarray(
            [f"{d.year}Q{(d.month - 1) // 3 + 1}" for d in all_dates])),
        "d_year": ColumnData("int", np.asarray([d.year for d in all_dates])),
    })
    classes = [f"{c}_class_{j}" for c in CATEGORIES for j in range(3)]
    class_cat = {cl: CATEGORIES[i // 3] for i, cl in enumerate(classes)}
    brands = [f"{cl}_brand_{k}" for cl in classes for k in range(4)]
    brand_class = {b: classes[i // 4] for i, b in enumerate(brands)}
    n_item = 2000
    bi = rng.integers(0, len(brands), size=n_item)
    bs = np.asarray(brands)[bi]
    item = TableData("item", {
        "i_key": ColumnData("int", np.arange(n_item)),
        "i_brand": ColumnData("str", bs),
        "i_class": ColumnData("str", np.asarray([brand_class[b] for b in bs])),
        "i_category": ColumnData("str", np.asarray(
            [class_cat[brand_class[b]] for b in bs])),
    })
    counties = [f"{s}_county_{j}" for s in STATES for j in range(3)]
    county_state = {c: STATES[i // 3] for i, c in enumerate(counties)}
    n_store = 120
    ci = rng.integers(0, len(counties), size=n_store)
    cs = np.asarray(counties)[ci]
    store = TableData("store", {
        "s_key": ColumnData("int", np.arange(n_store)),
        "s_store_name": ColumnData("str", np.asarray(
            [f"store_{i:03d}" for i in range(n_store)])),
        "s_county": ColumnData("str", cs),
        "s_state": ColumnData("str", np.asarray([county_state[c] for c in cs])),
    })
    promotion = TableData("promotion", {
        "p_key": ColumnData("int", np.arange(len(CHANNELS))),
        "p_channel": ColumnData("str", np.asarray(CHANNELS)),
    })
    dk = rng.integers(0, days, size=n_fact)
    qty = rng.integers(1, 20, size=n_fact)
    price = np.round(rng.uniform(5, 300, size=n_fact) * qty, 2)
    coupon = np.round(np.where(rng.random(n_fact) < 0.2, price * 0.1, 0.0), 2)
    paid = np.round(price - coupon, 2)
    profit = np.round(paid - price * rng.uniform(0.5, 0.9, size=n_fact), 2)
    fact = TableData("store_sales", {
        "ss_sold_date_key": ColumnData("int", dk),
        "ss_item_key": ColumnData("int", rng.integers(0, n_item, size=n_fact)),
        "ss_store_key": ColumnData("int", rng.integers(0, n_store, size=n_fact)),
        "ss_promo_key": ColumnData("int", rng.integers(0, len(CHANNELS), size=n_fact)),
        "ss_quantity": ColumnData("int", qty),
        "ss_ext_sales_price": ColumnData("float", price),
        "ss_net_paid": ColumnData("float", paid),
        "ss_net_profit": ColumnData("float", profit),
        "ss_coupon_amt": ColumnData("float", coupon),
        "ss_date": ColumnData("date", date_dim.columns["d_date"].data[dk].copy()),
    })
    return Dataset(schema, fact, {
        "date_dim": date_dim, "item": item, "store": store, "promotion": promotion,
    })


def build_vocab() -> NLVocab:
    return NLVocab(
        schema="tpcds",
        measures={
            "sales": (MeasureSense("store_sales.ss_ext_sales_price", "SUM"),),
            "profit": (MeasureSense("store_sales.ss_net_profit", "SUM"),),
            "net paid": (MeasureSense("store_sales.ss_net_paid", "SUM"),),
            "coupon savings": (MeasureSense("store_sales.ss_coupon_amt", "SUM"),),
            "units sold": (MeasureSense("store_sales.ss_quantity", "SUM"),),
            "transactions": (MeasureSense("*", "COUNT"),),
            # adversarial: 'revenue' net-vs-gross
            "revenue": (
                MeasureSense("store_sales.ss_ext_sales_price", "SUM"),
                MeasureSense("store_sales.ss_net_paid", "SUM"),
            ),
        },
        levels={
            "year": ("date_dim.d_year",),
            "quarter": ("date_dim.d_quarter",),
            "month": ("date_dim.d_yearmonth",),
            "category": ("item.i_category",),
            "class": ("item.i_class",),
            "brand": ("item.i_brand",),
            "state": ("store.s_state",),
            "county": ("store.s_county",),
            "store": ("store.s_store_name",),
            "channel": ("promotion.p_channel",),
        },
        values={
            **{f"in category {c.lower()}": (("item.i_category", c),) for c in CATEGORIES},
            **{f"in state {s.lower()}": (("store.s_state", s),) for s in STATES},
            **{f"via {ch}": (("promotion.p_channel", ch),) for ch in CHANNELS},
        },
        numeric_cols={"quantity": "store_sales.ss_quantity"},
        agg_ambiguous_nouns=("units sold",),
    )


_JD = "JOIN date_dim ON store_sales.ss_sold_date_key = date_dim.d_key "
_JI = "JOIN item ON store_sales.ss_item_key = item.i_key "
_JS = "JOIN store ON store_sales.ss_store_key = store.s_key "
_JP = "JOIN promotion ON store_sales.ss_promo_key = promotion.p_key "

_INTENTS = [
    Intent(
        "ds_01",
        f"SELECT i_category, SUM(ss_ext_sales_price) AS sales FROM store_sales {_JI}{_JD}"
        "WHERE d_year = 2002 GROUP BY i_category",
        nl_measures=("total sales",), nl_levels=("category",), nl_time="in 2002",
    ),
    Intent(
        "ds_02",
        f"SELECT s_state, SUM(ss_net_profit) AS profit FROM store_sales {_JS}{_JD}"
        "WHERE d_year = 2002 GROUP BY s_state",
        nl_measures=("total profit",), nl_levels=("state",), nl_time="in 2002",
    ),
    Intent(
        "ds_03",
        f"SELECT i_brand, SUM(ss_ext_sales_price) AS sales FROM store_sales {_JI}{_JD}"
        "WHERE i_category = 'Electronics' AND d_yearmonth = 'Mar2002' GROUP BY i_brand",
        nl_measures=("total sales",), nl_levels=("brand",),
        nl_filters=("in category electronics",), nl_time="in march 2002",
    ),
    Intent(
        "ds_04",
        f"SELECT d_yearmonth, SUM(ss_ext_sales_price) AS sales, SUM(ss_net_profit) AS profit "
        f"FROM store_sales {_JD}"
        "WHERE d_year = 2001 GROUP BY d_yearmonth",
        nl_measures=("total sales", "total profit"), nl_levels=("month",), nl_time="in 2001",
    ),
    Intent(
        "ds_05",
        f"SELECT i_category, s_state, SUM(ss_net_paid) AS paid FROM store_sales {_JI}{_JS}{_JD}"
        "WHERE d_quarter = '2002Q4' GROUP BY i_category, s_state",
        nl_measures=("total net paid",), nl_levels=("category", "state"),
        nl_time="in q4 2002",
    ),
    Intent(
        "ds_06",
        f"SELECT p_channel, SUM(ss_coupon_amt) AS coupons FROM store_sales {_JP}{_JD}"
        "WHERE d_year = 2003 GROUP BY p_channel",
        nl_measures=("total coupon savings",), nl_levels=("channel",), nl_time="in 2003",
    ),
    Intent(
        "ds_07",
        f"SELECT i_class, SUM(ss_quantity) AS units FROM store_sales {_JI}{_JD}"
        "WHERE i_category = 'Sports' AND d_year = 2002 GROUP BY i_class",
        nl_measures=("total units sold",), nl_levels=("class",),
        nl_filters=("in category sports",), nl_time="in 2002",
    ),
    Intent(
        "ds_08",
        f"SELECT s_state, COUNT(*) AS n FROM store_sales {_JS}{_JD}"
        "WHERE d_quarter = '2003Q1' GROUP BY s_state",
        nl_measures=("number of transactions",), nl_levels=("state",), nl_time="in q1 2003",
    ),
    Intent(
        "ds_09",
        f"SELECT i_category, SUM(ss_ext_sales_price) AS sales FROM store_sales {_JI}{_JD}"
        "WHERE d_year = 2002 GROUP BY i_category "
        "HAVING SUM(ss_ext_sales_price) > 100000",
        nl_measures=("total sales",), nl_levels=("category",), nl_time="in 2002",
        nl_extra="having total sales over 100000",
    ),
    Intent(
        "ds_10",
        f"SELECT i_brand, SUM(ss_ext_sales_price) AS sales FROM store_sales {_JI}{_JD}"
        "WHERE d_year = 2003 GROUP BY i_brand ORDER BY SUM(ss_ext_sales_price) DESC "
        "LIMIT 10",
        nl_measures=("total sales",), nl_levels=("brand",), nl_time="in 2003",
        nl_extra="top 10",
    ),
    Intent(
        "ds_11",
        f"SELECT d_year, AVG(ss_net_paid) AS avg_paid FROM store_sales {_JD}"
        "GROUP BY d_year",
        nl_measures=("average net paid",), nl_levels=("year",),
    ),
    Intent(
        "ds_12",
        f"SELECT s_county, SUM(ss_net_profit) AS profit FROM store_sales {_JS}{_JD}"
        "WHERE s_state = 'CA' AND d_year = 2002 GROUP BY s_county",
        nl_measures=("total profit",), nl_levels=("county",),
        nl_filters=("in state ca",), nl_time="in 2002",
    ),
    Intent(
        "ds_13",
        f"SELECT i_category, SUM(ss_ext_sales_price) AS sales, SUM(ss_coupon_amt) AS coupons "
        f"FROM store_sales {_JI}{_JD}"
        "WHERE d_year = 2002 AND ss_quantity < 10 GROUP BY i_category",
        nl_measures=("total sales", "total coupon savings"), nl_levels=("category",),
        nl_filters=("with quantity under 10",), nl_time="in 2002",
    ),
    Intent(
        "ds_14",
        f"SELECT d_quarter, SUM(ss_ext_sales_price) AS sales, SUM(ss_net_profit) AS profit "
        f"FROM store_sales {_JD}{_JI}"
        "WHERE i_category = 'Books' GROUP BY d_quarter",
        nl_measures=("total sales", "total profit"), nl_levels=("quarter",),
        nl_filters=("in category books",),
    ),
]


def build(n_fact: int = 150_000, seed: int = 2) -> Workload:
    schema = build_schema()
    return Workload(
        name="tpcds",
        schema=schema,
        dataset=build_dataset(schema, n_fact=n_fact, seed=seed),
        intents=list(_INTENTS),
        vocab=build_vocab(),
        spatial_ambiguous=(),
    )
