"""Star Schema Benchmark workload (13 canonical intents, §5.1).

Synthetic SSB-shaped data: lineorder fact + date/customer/supplier/part
dimensions with the classic hierarchies (date < month < quarter < year;
city < nation < region; brand < category < mfgr).  Query intents adapt the
13 SSB flights to the paper's §3.1 subset (weeknum predicates become quarter
windows; IN-lists become equality filters) plus COUNT/AVG dashboard intents.
"""
from __future__ import annotations

import datetime as _dt

import numpy as np

from ..core.nl_canon import MeasureSense, NLVocab
from ..core.schema import Column, Dimension, FactTable, Hierarchy, StarSchema
from ..olap.columnar import ColumnData, Dataset, TableData
from .base import Intent, Workload

REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]


def build_schema() -> StarSchema:
    dates = Dimension(
        name="dates", fact_fk="lo_orderdate", pk="d_key",
        columns=(
            Column("d_key", "int"), Column("d_date", "date"),
            Column("d_yearmonth", "str"), Column("d_quarter", "str"),
            Column("d_year", "int"), Column("d_yearmonthnum", "int"),
            Column("d_weeknuminyear", "int"),
        ),
        hierarchies=(Hierarchy("time", ("d_date", "d_yearmonth", "d_quarter", "d_year")),),
        time_kinds=(
            ("d_date", "date"), ("d_year", "year"),
            ("d_yearmonthnum", "yearmonthnum"), ("d_yearmonth", "yearmonth_str"),
            ("d_quarter", "yearquarter_str"),
        ),
    )
    customer = Dimension(
        name="customer", fact_fk="lo_custkey", pk="c_key",
        columns=(
            Column("c_key", "int"), Column("c_city", "str"),
            Column("c_nation", "str"), Column("c_region", "str"),
        ),
        hierarchies=(Hierarchy("geo", ("c_city", "c_nation", "c_region")),),
    )
    supplier = Dimension(
        name="supplier", fact_fk="lo_suppkey", pk="s_key",
        columns=(
            Column("s_key", "int"), Column("s_city", "str"),
            Column("s_nation", "str"), Column("s_region", "str"),
        ),
        hierarchies=(Hierarchy("geo", ("s_city", "s_nation", "s_region")),),
    )
    part = Dimension(
        name="part", fact_fk="lo_partkey", pk="p_key",
        columns=(
            Column("p_key", "int"), Column("p_brand", "str"),
            Column("p_category", "str"), Column("p_mfgr", "str"),
        ),
        hierarchies=(Hierarchy("prod", ("p_brand", "p_category", "p_mfgr")),),
    )
    fact = FactTable(
        name="lineorder",
        columns=(
            Column("lo_orderdate", "int"), Column("lo_custkey", "int"),
            Column("lo_suppkey", "int"), Column("lo_partkey", "int"),
            Column("lo_quantity", "int"), Column("lo_extendedprice", "float"),
            Column("lo_discount", "int"), Column("lo_revenue", "float"),
            Column("lo_supplycost", "float"), Column("lo_date", "date"),
        ),
        date_column="lo_date",
    )
    sch = StarSchema("ssb", fact, (dates, customer, supplier, part), time_dimension="dates")
    sch.validate()
    return sch


def build_dataset(schema: StarSchema, n_fact: int = 120_000, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    # ---- dates: 1992-01-01 .. 1998-12-31
    start = _dt.date(1992, 1, 1)
    days = (
        _dt.date(1998, 12, 31) - start
    ).days + 1
    all_dates = [start + _dt.timedelta(days=i) for i in range(days)]
    mon_names = ["Jan", "Feb", "Mar", "Apr", "May", "Jun",
                 "Jul", "Aug", "Sep", "Oct", "Nov", "Dec"]
    dates = TableData("dates", {
        "d_key": ColumnData("int", np.arange(days)),
        "d_date": ColumnData("date", np.asarray([d.isoformat() for d in all_dates])),
        "d_yearmonth": ColumnData("str", np.asarray(
            [f"{mon_names[d.month - 1]}{d.year}" for d in all_dates])),
        "d_quarter": ColumnData("str", np.asarray(
            [f"{d.year}Q{(d.month - 1) // 3 + 1}" for d in all_dates])),
        "d_year": ColumnData("int", np.asarray([d.year for d in all_dates])),
        "d_yearmonthnum": ColumnData("int", np.asarray(
            [d.year * 100 + d.month for d in all_dates])),
        "d_weeknuminyear": ColumnData("int", np.asarray(
            [d.isocalendar()[1] for d in all_dates])),
    })
    # ---- geography: 5 regions x 5 nations x 10 cities (functional)
    nations = [f"{r[:4]}_NATION_{i}" for r in REGIONS for i in range(5)]
    nation_region = {n: REGIONS[i // 5] for i, n in enumerate(nations)}
    cities = [f"{n}_C{j}" for n in nations for j in range(10)]
    city_nation = {c: nations[i // 10] for i, c in enumerate(cities)}

    def geo_table(name: str, prefix: str, n_rows: int) -> TableData:
        city_idx = rng.integers(0, len(cities), size=n_rows)
        cs = np.asarray(cities)[city_idx]
        ns = np.asarray([city_nation[c] for c in cs])
        rs = np.asarray([nation_region[n] for n in ns])
        return TableData(name, {
            f"{prefix}_key": ColumnData("int", np.arange(n_rows)),
            f"{prefix}_city": ColumnData("str", cs),
            f"{prefix}_nation": ColumnData("str", ns),
            f"{prefix}_region": ColumnData("str", rs),
        })

    customer = geo_table("customer", "c", 3000)
    supplier = geo_table("supplier", "s", 1000)
    # ---- parts: 5 mfgr x 5 categories x 8 brands (functional)
    mfgrs = [f"MFGR#{i+1}" for i in range(5)]
    categories = [f"MFGR#{i+1}{j+1}" for i in range(5) for j in range(5)]
    cat_mfgr = {c: mfgrs[i // 5] for i, c in enumerate(categories)}
    brands = [f"{c}{k+1:02d}" for c in categories for k in range(8)]
    brand_cat = {b: categories[i // 8] for i, b in enumerate(brands)}
    n_part = 1200
    bidx = rng.integers(0, len(brands), size=n_part)
    bs = np.asarray(brands)[bidx]
    part = TableData("part", {
        "p_key": ColumnData("int", np.arange(n_part)),
        "p_brand": ColumnData("str", bs),
        "p_category": ColumnData("str", np.asarray([brand_cat[b] for b in bs])),
        "p_mfgr": ColumnData("str", np.asarray([cat_mfgr[brand_cat[b]] for b in bs])),
    })
    # ---- fact
    od = rng.integers(0, days, size=n_fact)
    qty = rng.integers(1, 51, size=n_fact)
    price = np.round(rng.uniform(100, 10_000, size=n_fact), 2)
    disc = rng.integers(0, 11, size=n_fact)
    revenue = np.round(price * (1 - disc / 100.0), 2)
    cost = np.round(price * rng.uniform(0.4, 0.8, size=n_fact), 2)
    fact = TableData("lineorder", {
        "lo_orderdate": ColumnData("int", od),
        "lo_custkey": ColumnData("int", rng.integers(0, customer.num_rows, size=n_fact)),
        "lo_suppkey": ColumnData("int", rng.integers(0, supplier.num_rows, size=n_fact)),
        "lo_partkey": ColumnData("int", rng.integers(0, n_part, size=n_fact)),
        "lo_quantity": ColumnData("int", qty),
        "lo_extendedprice": ColumnData("float", price),
        "lo_discount": ColumnData("int", disc),
        "lo_revenue": ColumnData("float", revenue),
        "lo_supplycost": ColumnData("float", cost),
        "lo_date": ColumnData("date", dates.columns["d_date"].data[od].copy()),
    })
    return Dataset(schema, fact, {
        "dates": dates, "customer": customer, "supplier": supplier, "part": part,
    })


def build_vocab() -> NLVocab:
    return NLVocab(
        schema="ssb",
        measures={
            "revenue": (MeasureSense("lineorder.lo_revenue", "SUM"),),
            "discounted revenue": (
                MeasureSense("(lineorder.lo_discount*lineorder.lo_extendedprice)", "SUM"),),
            "profit": (
                MeasureSense("(lineorder.lo_revenue-lineorder.lo_supplycost)", "SUM"),),
            "orders": (MeasureSense("*", "COUNT"),),
            "quantity": (MeasureSense("lineorder.lo_quantity", "SUM"),),
            "supply cost": (MeasureSense("lineorder.lo_supplycost", "SUM"),),
        },
        levels={
            "year": ("dates.d_year",),
            "quarter": ("dates.d_quarter",),
            "month": ("dates.d_yearmonth",),
            "customer region": ("customer.c_region",),
            "customer nation": ("customer.c_nation",),
            "customer city": ("customer.c_city",),
            "supplier region": ("supplier.s_region",),
            "supplier nation": ("supplier.s_nation",),
            "supplier city": ("supplier.s_city",),
            "brand": ("part.p_brand",),
            "category": ("part.p_category",),
            "manufacturer": ("part.p_mfgr",),
            # deliberately ambiguous (adversarial use only)
            "region": ("customer.c_region", "supplier.s_region"),
            "nation": ("customer.c_nation", "supplier.s_nation"),
            "city": ("customer.c_city", "supplier.s_city"),
        },
        values={
            # context-qualified phrases keep the controlled workload unambiguous
            **{f"customers in {r.lower()}": (("customer.c_region", r),) for r in REGIONS},
            **{f"suppliers in {r.lower()}": (("supplier.s_region", r),) for r in REGIONS},
            **{f"category mfgr#{i+1}{j+1}": (("part.p_category", f"MFGR#{i+1}{j+1}"),)
               for i in range(5) for j in range(5)},
            "brand mfgr#2239": (("part.p_brand", "MFGR#2308"),),
            "nation asia_nation_0": (("customer.c_nation", "ASIA_NATION_0"),),
            # bare region names are ambiguous customer-vs-supplier (adversarial)
            **{r.lower(): (("customer.c_region", r), ("supplier.s_region", r))
               for r in REGIONS},
        },
        numeric_cols={
            "quantity": "lineorder.lo_quantity",
            "discount": "lineorder.lo_discount",
        },
        agg_ambiguous_nouns=("quantity",),
    )


# canonical SQL intents (adapted SSB flights + dashboard intents)
_INTENTS = [
    Intent(
        "ssb_q1_1",
        "SELECT SUM(lo_extendedprice * lo_discount) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "WHERE d_year = 1993 AND lo_discount BETWEEN 1 AND 3 AND lo_quantity < 25",
        nl_measures=("total discounted revenue",),
        nl_filters=("with discount between 1 and 3", "and quantity under 25"),
        nl_time="in 1993",
    ),
    Intent(
        "ssb_q1_2",
        "SELECT SUM(lo_extendedprice * lo_discount) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "WHERE d_yearmonthnum = 199401 AND lo_discount BETWEEN 4 AND 6 "
        "AND lo_quantity BETWEEN 26 AND 35",
        nl_measures=("total discounted revenue",),
        nl_filters=("with discount between 4 and 6", "and quantity between 26 and 35"),
        nl_time="in january 1994",
    ),
    Intent(
        "ssb_q1_3",
        "SELECT SUM(lo_extendedprice * lo_discount) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "WHERE d_quarter = '1994Q1' AND lo_discount BETWEEN 5 AND 7",
        nl_measures=("total discounted revenue",),
        nl_filters=("with discount between 5 and 7",),
        nl_time="in q1 1994",
    ),
    Intent(
        "ssb_q2_1",
        "SELECT d_year, p_brand, SUM(lo_revenue) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN part ON lineorder.lo_partkey = part.p_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE p_category = 'MFGR#12' AND s_region = 'AMERICA' "
        "GROUP BY d_year, p_brand",
        nl_measures=("total revenue",),
        nl_levels=("year", "brand"),
        nl_filters=("for category mfgr#12", "from suppliers in america"),
    ),
    Intent(
        "ssb_q2_2",
        "SELECT d_year, p_brand, SUM(lo_revenue) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN part ON lineorder.lo_partkey = part.p_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE p_category = 'MFGR#22' AND s_region = 'ASIA' "
        "GROUP BY d_year, p_brand",
        nl_measures=("total revenue",),
        nl_levels=("year", "brand"),
        nl_filters=("for category mfgr#22", "from suppliers in asia"),
    ),
    Intent(
        "ssb_q2_3",
        "SELECT d_year, SUM(lo_revenue) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN part ON lineorder.lo_partkey = part.p_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE p_brand = 'MFGR#2308' AND s_region = 'EUROPE' "
        "GROUP BY d_year",
        nl_measures=("total revenue",),
        nl_levels=("year",),
        nl_filters=("for brand mfgr#2239", "from suppliers in europe"),
    ),
    Intent(
        "ssb_q3_1",
        "SELECT c_nation, s_nation, d_year, SUM(lo_revenue) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN customer ON lineorder.lo_custkey = customer.c_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE c_region = 'ASIA' AND s_region = 'ASIA' AND d_year BETWEEN 1992 AND 1997 "
        "GROUP BY c_nation, s_nation, d_year",
        nl_measures=("total revenue",),
        nl_levels=("customer nation", "supplier nation", "year"),
        nl_filters=("for customers in asia", "and suppliers in asia"),
        nl_time="from 1992 to 1997",
    ),
    Intent(
        "ssb_q3_2",
        "SELECT c_city, s_city, d_year, SUM(lo_revenue) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN customer ON lineorder.lo_custkey = customer.c_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE c_nation = 'ASIA_NATION_0' AND d_year BETWEEN 1992 AND 1997 "
        "GROUP BY c_city, s_city, d_year",
        nl_measures=("total revenue",),
        nl_levels=("customer city", "supplier city", "year"),
        nl_filters=("for nation asia_nation_0",),
        nl_time="from 1992 to 1997",
    ),
    Intent(
        "ssb_q4_1",
        "SELECT d_year, c_nation, SUM(lo_revenue - lo_supplycost) AS profit FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN customer ON lineorder.lo_custkey = customer.c_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE c_region = 'AMERICA' AND s_region = 'AMERICA' "
        "GROUP BY d_year, c_nation",
        nl_measures=("total profit",),
        nl_levels=("year", "customer nation"),
        nl_filters=("for customers in america", "and suppliers in america"),
    ),
    Intent(
        "ssb_q4_2",
        "SELECT d_year, s_nation, SUM(lo_revenue - lo_supplycost) AS profit FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
        "WHERE s_region = 'EUROPE' AND d_year BETWEEN 1997 AND 1998 "
        "GROUP BY d_year, s_nation",
        nl_measures=("total profit",),
        nl_levels=("year", "supplier nation"),
        nl_filters=("from suppliers in europe",),
        nl_time="from 1997 to 1998",
    ),
    Intent(
        "ssb_q5_count",
        "SELECT d_year, COUNT(*) AS n_orders FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "GROUP BY d_year",
        nl_measures=("number of orders",),
        nl_levels=("year",),
    ),
    Intent(
        "ssb_q6_avg",
        "SELECT c_region, AVG(lo_quantity) AS avg_qty FROM lineorder "
        "JOIN customer ON lineorder.lo_custkey = customer.c_key "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "WHERE d_year = 1995 GROUP BY c_region",
        nl_measures=("average quantity",),
        nl_levels=("customer region",),
        nl_time="in 1995",
    ),
    Intent(
        "ssb_q7_monthly",
        "SELECT d_yearmonth, SUM(lo_revenue) AS revenue FROM lineorder "
        "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
        "WHERE d_year = 1996 GROUP BY d_yearmonth",
        nl_measures=("total revenue",),
        nl_levels=("month",),
        nl_time="in 1996",
    ),
]


def build(n_fact: int = 120_000, seed: int = 0) -> Workload:
    schema = build_schema()
    return Workload(
        name="ssb",
        schema=schema,
        dataset=build_dataset(schema, n_fact=n_fact, seed=seed),
        intents=list(_INTENTS),
        vocab=build_vocab(),
        spatial_ambiguous=(
            ("region", ("customer.c_region", "supplier.s_region")),
            ("city", ("customer.c_city", "supplier.s_city")),
        ),
    )
