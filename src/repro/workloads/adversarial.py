"""The 63 adversarial NL queries (§5.3, Table 2).

Five ambiguity types with the paper's exact counts — metric name (15), time
reference (12), dimension (12), aggregation intent (9), compositional (15).
Each query carries a *gold* signature under the conventional reading
(documented per type below); the simulated model sees only the text, hits the
genuine ambiguity, and resolves it with the calibrated error rates, exactly
reproducing the paper's schema-valid-but-semantically-wrong failure mode.

Gold conventions (the paper's annotator choices):
  * 'revenue'  -> gross (trips.total_amount / ss_ext_sales_price), not net,
  * relative time -> anchored at the dashboard's reference date (2024-03-15),
  * 'area'/'zone'/bare borough -> the *pickup* geography at zone granularity,
  * missing aggregation word on count-like nouns -> the noun's default agg,
  * compositional -> every requested measure must be present.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
from typing import Optional

from ..core.signature import Filter, Measure, Signature, TimeWindow

REFERENCE_NOW = _dt.date(2024, 3, 15)


@dataclasses.dataclass(frozen=True)
class AdversarialQuery:
    text: str
    gold: Optional[Signature]  # None => any non-None output is Wrong
    ambiguity: str  # 'metric' | 'time' | 'dimension' | 'aggregation' | 'compositional'
    schema: str


def _sig(schema, measures, levels=(), filters=(), tw=None):
    return Signature(schema=schema, measures=tuple(measures), levels=tuple(levels),
                     filters=tuple(filters), time_window=tw)


def _year(y):
    return TimeWindow(f"{y}-01-01", f"{y + 1}-01-01")


def _last_month_window():  # anchored at REFERENCE_NOW
    return TimeWindow("2024-02-01", "2024-03-01", open_ended=True)


def _last_30d_window():
    return TimeWindow("2024-02-14", "2024-03-15", open_ended=True)


def _this_year_window():
    return TimeWindow("2024-01-01", "2024-03-15", open_ended=True)


def build() -> list[AdversarialQuery]:
    out: list[AdversarialQuery] = []
    TA = lambda: Measure("SUM", "trips.total_amount")  # noqa: E731
    SALES = lambda: Measure("SUM", "store_sales.ss_ext_sales_price")  # noqa: E731

    # ---------------------------------------------------- metric name (N=15)
    metric_texts = [
        ("Show total revenue by pickup borough in 2024",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_borough"], tw=_year(2024))),
        ("What was total revenue by payment type in 2023?",
         _sig("nyc_tlc", [TA()], ["payment.payment_type"], tw=_year(2023))),
        ("total revenue by month in 2024",
         _sig("nyc_tlc", [TA()], ["dates.d_yearmonth"], tw=_year(2024))),
        ("Give me total revenue by pickup zone in q1 2024",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_zone"],
              tw=TimeWindow("2024-01-01", "2024-04-01"))),
        ("Report total revenue by dropoff borough in 2024",
         _sig("nyc_tlc", [TA()], ["zones_do.do_borough"], tw=_year(2024))),
        ("overall revenue by quarter in 2023",
         _sig("nyc_tlc", [TA()], ["dates.d_quarter"], tw=_year(2023))),
        ("Total revenue by payment type in q2 2024",
         _sig("nyc_tlc", [TA()], ["payment.payment_type"],
              tw=TimeWindow("2024-04-01", "2024-07-01"))),
        ("How does total revenue look by pickup borough in 2023?",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_borough"], tw=_year(2023))),
        ("total revenue by category in 2002",
         _sig("tpcds", [SALES()], ["item.i_category"], tw=_year(2002))),
        ("Show total revenue by state in 2002",
         _sig("tpcds", [SALES()], ["store.s_state"], tw=_year(2002))),
        ("total revenue by brand in 2003",
         _sig("tpcds", [SALES()], ["item.i_brand"], tw=_year(2003))),
        ("What is total revenue by channel in 2002?",
         _sig("tpcds", [SALES()], ["promotion.p_channel"], tw=_year(2002))),
        ("total revenue by month in 2001",
         _sig("tpcds", [SALES()], ["date_dim.d_yearmonth"], tw=_year(2001))),
        ("Give total revenue by county in 2002",
         _sig("tpcds", [SALES()], ["store.s_county"], tw=_year(2002))),
        ("Report total revenue by class in 2002",
         _sig("tpcds", [SALES()], ["item.i_class"], tw=_year(2002))),
    ]
    out += [AdversarialQuery(t, g, "metric", g.schema) for t, g in metric_texts]

    # ------------------------------------------------- time reference (N=12)
    time_texts = [
        ("Show total earnings by pickup borough last month",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_borough"], tw=_last_month_window())),
        ("number of trips by payment type last month",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["payment.payment_type"],
              tw=_last_month_window())),
        ("total tips by pickup zone last month",
         _sig("nyc_tlc", [Measure("SUM", "trips.tip_amount")], ["zones_pu.pu_zone"],
              tw=_last_month_window())),
        ("total earnings by month this year",
         _sig("nyc_tlc", [TA()], ["dates.d_yearmonth"], tw=_this_year_window())),
        ("Show total distance by pickup borough for the last 30 days",
         _sig("nyc_tlc", [Measure("SUM", "trips.trip_distance")],
              ["zones_pu.pu_borough"], tw=_last_30d_window())),
        ("number of rides by dropoff borough last quarter",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["zones_do.do_borough"],
              tw=TimeWindow("2023-10-01", "2024-01-01", open_ended=True))),
        ("total earnings by payment type last year",
         _sig("nyc_tlc", [TA()], ["payment.payment_type"],
              tw=TimeWindow("2023-01-01", "2024-01-01", open_ended=True))),
        ("total fares by pickup borough last month",
         _sig("nyc_tlc", [Measure("SUM", "trips.fare_amount")],
              ["zones_pu.pu_borough"], tw=_last_month_window())),
        ("recent trips by pickup borough — how many?",
         None),  # 'recently' with no window is unanswerable; any guess is Wrong
        ("total sales by category last year",
         _sig("tpcds", [SALES()], ["item.i_category"],
              tw=TimeWindow("2023-01-01", "2024-01-01", open_ended=True))),
        ("total profit by state last quarter",
         _sig("tpcds", [Measure("SUM", "store_sales.ss_net_profit")],
              ["store.s_state"], tw=TimeWindow("2023-10-01", "2024-01-01", open_ended=True))),
        ("number of transactions by state this year",
         _sig("tpcds", [Measure("COUNT", "*")], ["store.s_state"],
              tw=_this_year_window())),
    ]
    out += [AdversarialQuery(t, g, "time", g.schema if g else "nyc_tlc")
            for t, g in time_texts]

    # ------------------------------------------------------ dimension (N=12)
    dim_texts = [
        ("Show total earnings by area in 2024",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_zone"], tw=_year(2024))),
        ("number of trips by area in 2023",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["zones_pu.pu_zone"], tw=_year(2023))),
        ("total tips by area in q1 2024",
         _sig("nyc_tlc", [Measure("SUM", "trips.tip_amount")], ["zones_pu.pu_zone"],
              tw=TimeWindow("2024-01-01", "2024-04-01"))),
        ("total earnings by zone in 2024",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_zone"], tw=_year(2024))),
        ("number of rides by zone in q2 2023",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["zones_pu.pu_zone"],
              tw=TimeWindow("2023-04-01", "2023-07-01"))),
        ("total distance by borough in 2024",
         _sig("nyc_tlc", [Measure("SUM", "trips.trip_distance")],
              ["zones_pu.pu_borough"], tw=_year(2024))),
        ("total earnings by borough in 2023",
         _sig("nyc_tlc", [TA()], ["zones_pu.pu_borough"], tw=_year(2023))),
        ("number of trips for manhattan by month in 2024",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["dates.d_yearmonth"],
              [Filter("zones_pu.pu_borough", "=", "Manhattan")], tw=_year(2024))),
        ("total earnings for brooklyn by quarter in 2024",
         _sig("nyc_tlc", [TA()], ["dates.d_quarter"],
              [Filter("zones_pu.pu_borough", "=", "Brooklyn")], tw=_year(2024))),
        ("total fares for queens by month in 2023",
         _sig("nyc_tlc", [Measure("SUM", "trips.fare_amount")], ["dates.d_yearmonth"],
              [Filter("zones_pu.pu_borough", "=", "Queens")], tw=_year(2023))),
        ("total revenue by region in 1997",
         _sig("ssb", [Measure("SUM", "lineorder.lo_revenue")],
              ["customer.c_region"], tw=_year(1997))),
        ("total profit by nation in 1995",
         _sig("ssb", [Measure("SUM", "(lineorder.lo_revenue-lineorder.lo_supplycost)")],
              ["customer.c_nation"], tw=_year(1995))),
    ]
    out += [AdversarialQuery(t, g, "dimension", g.schema) for t, g in dim_texts]

    # ----------------------------------------------------- aggregation (N=9)
    agg_texts = [
        ("trips by payment type in 2024",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["payment.payment_type"],
              tw=_year(2024))),
        ("rides by pickup borough in 2023",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["zones_pu.pu_borough"],
              tw=_year(2023))),
        ("trips by month in 2024",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["dates.d_yearmonth"],
              tw=_year(2024))),
        ("passengers by pickup borough in 2024",
         _sig("nyc_tlc", [Measure("SUM", "trips.passenger_count")],
              ["zones_pu.pu_borough"], tw=_year(2024))),
        ("rides by quarter in 2024",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["dates.d_quarter"], tw=_year(2024))),
        ("trips by dropoff borough in q3 2024",
         _sig("nyc_tlc", [Measure("COUNT", "*")], ["zones_do.do_borough"],
              tw=TimeWindow("2024-07-01", "2024-10-01"))),
        ("passengers by month in 2023",
         _sig("nyc_tlc", [Measure("SUM", "trips.passenger_count")],
              ["dates.d_yearmonth"], tw=_year(2023))),
        ("units sold by category in 2002",
         _sig("tpcds", [Measure("SUM", "store_sales.ss_quantity")],
              ["item.i_category"], tw=_year(2002))),
        ("quantity by customer region in 1994",
         _sig("ssb", [Measure("SUM", "lineorder.lo_quantity")],
              ["customer.c_region"], tw=_year(1994))),
    ]
    out += [AdversarialQuery(t, g, "aggregation", g.schema) for t, g in agg_texts]

    # -------------------------------------------------- compositional (N=15)
    comp_texts = [
        ("Show earnings, tips and distance by pickup borough in 2024",
         _sig("nyc_tlc", [TA(), Measure("SUM", "trips.tip_amount"),
                          Measure("SUM", "trips.trip_distance")],
              ["zones_pu.pu_borough"], tw=_year(2024))),
        ("fares, tips and passengers by payment type in 2024",
         _sig("nyc_tlc", [Measure("SUM", "trips.fare_amount"),
                          Measure("SUM", "trips.tip_amount"),
                          Measure("SUM", "trips.passenger_count")],
              ["payment.payment_type"], tw=_year(2024))),
        ("earnings and trips by month in 2024",
         _sig("nyc_tlc", [TA(), Measure("COUNT", "*")], ["dates.d_yearmonth"],
              tw=_year(2024))),
        ("distance and earnings and tips by pickup zone in q1 2024",
         _sig("nyc_tlc", [Measure("SUM", "trips.trip_distance"), TA(),
                          Measure("SUM", "trips.tip_amount")],
              ["zones_pu.pu_zone"], tw=TimeWindow("2024-01-01", "2024-04-01"))),
        ("tips and fares by dropoff borough in 2023",
         _sig("nyc_tlc", [Measure("SUM", "trips.tip_amount"),
                          Measure("SUM", "trips.fare_amount")],
              ["zones_do.do_borough"], tw=_year(2023))),
        ("earnings, fares, tips by quarter in 2024",
         _sig("nyc_tlc", [TA(), Measure("SUM", "trips.fare_amount"),
                          Measure("SUM", "trips.tip_amount")],
              ["dates.d_quarter"], tw=_year(2024))),
        ("trips and passengers by pickup borough in 2024",
         _sig("nyc_tlc", [Measure("COUNT", "*"),
                          Measure("SUM", "trips.passenger_count")],
              ["zones_pu.pu_borough"], tw=_year(2024))),
        ("distance and passengers by month in 2023",
         _sig("nyc_tlc", [Measure("SUM", "trips.trip_distance"),
                          Measure("SUM", "trips.passenger_count")],
              ["dates.d_yearmonth"], tw=_year(2023))),
        ("sales, profit and coupon savings by category in 2002",
         _sig("tpcds", [SALES(), Measure("SUM", "store_sales.ss_net_profit"),
                        Measure("SUM", "store_sales.ss_coupon_amt")],
              ["item.i_category"], tw=_year(2002))),
        ("profit and sales by state in 2002",
         _sig("tpcds", [Measure("SUM", "store_sales.ss_net_profit"), SALES()],
              ["store.s_state"], tw=_year(2002))),
        ("sales and transactions by brand in 2003",
         _sig("tpcds", [SALES(), Measure("COUNT", "*")], ["item.i_brand"],
              tw=_year(2003))),
        ("units sold and sales by class in 2002",
         _sig("tpcds", [Measure("SUM", "store_sales.ss_quantity"), SALES()],
              ["item.i_class"], tw=_year(2002))),
        ("revenue and profit by year",
         _sig("ssb", [Measure("SUM", "lineorder.lo_revenue"),
                      Measure("SUM", "(lineorder.lo_revenue-lineorder.lo_supplycost)")],
              ["dates.d_year"])),
        ("revenue, quantity and supply cost by customer region in 1996",
         _sig("ssb", [Measure("SUM", "lineorder.lo_revenue"),
                      Measure("SUM", "lineorder.lo_quantity"),
                      Measure("SUM", "lineorder.lo_supplycost")],
              ["customer.c_region"], tw=_year(1996))),
        ("profit and orders by supplier nation in 1997",
         _sig("ssb", [Measure("SUM", "(lineorder.lo_revenue-lineorder.lo_supplycost)"),
                      Measure("COUNT", "*")],
              ["supplier.s_nation"], tw=_year(1997))),
    ]
    out += [AdversarialQuery(t, g, "compositional", g.schema) for t, g in comp_texts]

    assert len(out) == 63, len(out)
    counts = {}
    for q in out:
        counts[q.ambiguity] = counts.get(q.ambiguity, 0) + 1
    assert counts == {"metric": 15, "time": 12, "dimension": 12,
                      "aggregation": 9, "compositional": 15}, counts
    return out


def score(queries, results) -> dict:
    """Classify each (gold, NLResult) as correct / wrong / invalid (Table 2)."""
    per_type: dict[str, dict[str, int]] = {}
    rows = []
    for q, r in zip(queries, results):
        bucket = per_type.setdefault(q.ambiguity, {"correct": 0, "wrong": 0, "invalid": 0})
        if r.signature is None:
            verdict = "invalid"
        elif q.gold is not None and r.signature.key() == q.gold.key():
            verdict = "correct"
        else:
            verdict = "wrong"
        bucket[verdict] += 1
        rows.append((q, r, verdict))
    return {"per_type": per_type, "rows": rows}
