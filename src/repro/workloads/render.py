"""Render Query ASTs back to SQL text with controllable style.

The variant generator (variants.py) composes AST-level rewrites with these
text-level styles to produce the paper's "systematic SQL variants"
(formatting, alias, predicate-order changes — §5.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core import sqlparse as sp


@dataclasses.dataclass(frozen=True)
class Style:
    upper_keywords: bool = True
    newlines: bool = True
    use_as: bool = True  # AS keyword for aliases
    explicit_inner: bool = False  # INNER JOIN vs JOIN
    explicit_asc: bool = False
    leading_comment: Optional[str] = None
    compact: bool = False  # single-space everything
    trailing_semicolon: bool = False


def _kw(style: Style, word: str) -> str:
    return word.upper() if style.upper_keywords else word.lower()


def render_expr(e: sp.Expr, style: Style) -> str:
    if isinstance(e, sp.ColRef):
        return f"{e.table}.{e.column}" if e.table else e.column
    if isinstance(e, sp.Literal):
        if isinstance(e.value, str):
            return "'" + e.value.replace("'", "''") + "'"
        if isinstance(e.value, float) and e.value == int(e.value):
            return str(int(e.value))
        return str(e.value)
    if isinstance(e, sp.BinOp):
        l, r = render_expr(e.left, style), render_expr(e.right, style)
        if isinstance(e.left, sp.BinOp):
            l = f"({l})"
        if isinstance(e.right, sp.BinOp):
            r = f"({r})"
        return f"{l} {e.op} {r}"
    if isinstance(e, sp.AggCall):
        arg = "*" if e.arg is None else render_expr(e.arg, style)
        if e.distinct:
            arg = _kw(style, "distinct") + " " + arg
        return f"{_kw(style, e.func.lower())}({arg})"
    raise TypeError(f"cannot render {e!r}")


def render_predicate(p: sp.Predicate, style: Style) -> str:
    l = render_expr(p.left, style)
    if p.op == "between":
        lo, hi = p.right
        return f"{l} {_kw(style, 'between')} {render_expr(lo, style)} {_kw(style, 'and')} {render_expr(hi, style)}"
    if p.op == "in":
        vals = ", ".join(render_expr(v, style) for v in p.right)
        return f"{l} {_kw(style, 'in')} ({vals})"
    return f"{l} {p.op} {render_expr(p.right, style)}"


def render(q: sp.Query, style: Style = Style()) -> str:
    sep = "\n" if style.newlines and not style.compact else " "
    parts: list[str] = []
    if style.leading_comment:
        # block comments survive single-line layouts; line comments don't
        parts.append(f"/* {style.leading_comment} */")
    sel_items = []
    for item in q.select:
        s = render_expr(item.expr, style)
        if item.alias:
            s += (f" {_kw(style, 'as')} " if style.use_as else " ") + item.alias
        sel_items.append(s)
    parts.append(_kw(style, "select") + " " + ", ".join(sel_items))
    from_part = _kw(style, "from") + " " + q.table
    if q.alias != q.table:
        from_part += (f" {_kw(style, 'as')} " if style.use_as else " ") + q.alias
    parts.append(from_part)
    for j in q.joins:
        jk = _kw(style, "inner join") if style.explicit_inner else _kw(style, "join")
        jt = j.table
        if j.alias != j.table:
            jt += (f" {_kw(style, 'as')} " if style.use_as else " ") + j.alias
        lhs = f"{j.left.table}.{j.left.column}" if j.left.table else j.left.column
        rhs = f"{j.right.table}.{j.right.column}" if j.right.table else j.right.column
        parts.append(f"{jk} {jt} {_kw(style, 'on')} {lhs} = {rhs}")
    if q.where:
        conj = f" {_kw(style, 'and')} ".join(render_predicate(p, style) for p in q.where)
        parts.append(_kw(style, "where") + " " + conj)
    if q.group_by:
        cols = ", ".join(
            (f"{c.table}.{c.column}" if c.table else c.column) for c in q.group_by
        )
        parts.append(_kw(style, "group by") + " " + cols)
    if q.having:
        conj = f" {_kw(style, 'and')} ".join(render_predicate(p, style) for p in q.having)
        parts.append(_kw(style, "having") + " " + conj)
    if q.order_by:
        items = []
        for e, desc in q.order_by:
            s = render_expr(e, style)
            if desc:
                s += " " + _kw(style, "desc")
            elif style.explicit_asc:
                s += " " + _kw(style, "asc")
            items.append(s)
        parts.append(_kw(style, "order by") + " " + ", ".join(items))
    if q.limit is not None:
        parts.append(_kw(style, "limit") + f" {q.limit}")
    sql = sep.join(parts)
    if style.trailing_semicolon:
        sql += ";"
    return sql
