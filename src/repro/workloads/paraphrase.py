"""NL paraphrase generation (§5.1: 10 manually-authored paraphrases per intent).

Paraphrases are controlled rewrites of known-correct intents with unambiguous
references (the paper's wording) — they rotate synonym templates over the
intent's NL building blocks.  Ambiguity is introduced *only* by the
adversarial / BIRD-like sets, matching the paper's evaluation split.
"""
from __future__ import annotations

import random

from .base import Intent

_TEMPLATES = [
    "Show {measures} {by} {filters} {time} {extra}",
    "What is {measures} {by} {filters} {time}? {extra}",
    "{measures} {by} {time} {filters} {extra}",
    "Give me {measures} {filters} {by} {time} {extra}",
    "I need {measures} {by} {filters} {time} {extra}",
    "Report {measures} {time} {by} {filters} {extra}",
    "Can you display {measures} {by} {filters} {time}? {extra}",
    "Compute {measures} {filters} {time} {by} {extra}",
    "{measures} please, {by} {filters} {time} {extra}",
    "Looking for {measures} {by} {time} {filters} {extra}",
    "Break out {measures} {by} {filters} {time} {extra}",
    "Dashboard needs {measures} {by} {filters} {time} {extra}",
]

_BY_WORDS = ["by", "per", "broken down by", "grouped by", "for each"]
_JOINERS = [" and ", " and ", ", "]


def gen_paraphrases(intent: Intent, n: int = 10, seed: int = 0) -> list[str]:
    rnd = random.Random(seed)
    out: list[str] = []
    for i in range(n):
        tpl = _TEMPLATES[(i + seed) % len(_TEMPLATES)]
        joiner = _JOINERS[i % len(_JOINERS)]
        measures = joiner.join(intent.nl_measures)
        by = ""
        if intent.nl_levels:
            by = _BY_WORDS[(i + seed) % len(_BY_WORDS)] + " " + " and ".join(intent.nl_levels)
        filters = " ".join(intent.nl_filters)
        time = intent.nl_time or ""
        extra = intent.nl_extra or ""
        s = tpl.format(measures=measures, by=by, filters=filters, time=time, extra=extra)
        s = " ".join(s.split())  # collapse whitespace
        s = s.replace(" ?", "?").replace(" ,", ",").rstrip()
        if s.endswith(","):
            s = s[:-1]
        out.append(s)
    return out
