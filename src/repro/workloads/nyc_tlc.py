"""NYC TLC trip-record workload (18 canonical intents, §5.1).

Dashboard-oriented star schema over taxi trips.  Role-playing zone joins
(pickup vs dropoff) are declared as *separate* dimensions with distinct fact
FKs, which keeps join paths unique (§3.3); the paper's dimension-ambiguity
adversarial cases ('area' -> zone vs borough) come from this schema's vocab.
"""
from __future__ import annotations

import datetime as _dt

import numpy as np

from ..core.nl_canon import MeasureSense, NLVocab
from ..core.schema import Column, Dimension, FactTable, Hierarchy, StarSchema
from ..olap.columnar import ColumnData, Dataset, TableData
from .base import Intent, Workload

BOROUGHS = ["Manhattan", "Brooklyn", "Queens", "Bronx", "Staten Island", "EWR"]
PAYMENTS = ["Credit card", "Cash", "No charge", "Dispute", "Unknown"]


def build_schema() -> StarSchema:
    dates = Dimension(
        name="dates", fact_fk="pickup_date_key", pk="d_key",
        columns=(
            Column("d_key", "int"), Column("d_date", "date"),
            Column("d_yearmonth", "str"), Column("d_quarter", "str"),
            Column("d_year", "int"),
        ),
        hierarchies=(Hierarchy("time", ("d_date", "d_yearmonth", "d_quarter", "d_year")),),
        time_kinds=(
            ("d_date", "date"), ("d_year", "year"),
            ("d_yearmonth", "yearmonth_str"), ("d_quarter", "yearquarter_str"),
        ),
    )
    zones_pu = Dimension(
        name="zones_pu", fact_fk="pu_zone_key", pk="zpu_key",
        columns=(
            Column("zpu_key", "int"), Column("pu_zone", "str"), Column("pu_borough", "str"),
        ),
        hierarchies=(Hierarchy("geo", ("pu_zone", "pu_borough")),),
    )
    zones_do = Dimension(
        name="zones_do", fact_fk="do_zone_key", pk="zdo_key",
        columns=(
            Column("zdo_key", "int"), Column("do_zone", "str"), Column("do_borough", "str"),
        ),
        hierarchies=(Hierarchy("geo", ("do_zone", "do_borough")),),
    )
    payment = Dimension(
        name="payment", fact_fk="payment_key", pk="pay_key",
        columns=(Column("pay_key", "int"), Column("payment_type", "str")),
    )
    fact = FactTable(
        name="trips",
        columns=(
            Column("pickup_date_key", "int"), Column("pu_zone_key", "int"),
            Column("do_zone_key", "int"), Column("payment_key", "int"),
            Column("fare_amount", "float"), Column("tip_amount", "float"),
            Column("total_amount", "float"), Column("trip_distance", "float"),
            Column("passenger_count", "int"), Column("trip_date", "date"),
        ),
        date_column="trip_date",
    )
    sch = StarSchema("nyc_tlc", fact, (dates, zones_pu, zones_do, payment),
                     time_dimension="dates")
    sch.validate()
    return sch


def build_dataset(schema: StarSchema, n_fact: int = 150_000, seed: int = 1) -> Dataset:
    rng = np.random.default_rng(seed)
    start = _dt.date(2023, 1, 1)
    days = (_dt.date(2024, 12, 31) - start).days + 1
    all_dates = [start + _dt.timedelta(days=i) for i in range(days)]
    mon = ["Jan", "Feb", "Mar", "Apr", "May", "Jun",
           "Jul", "Aug", "Sep", "Oct", "Nov", "Dec"]
    dates = TableData("dates", {
        "d_key": ColumnData("int", np.arange(days)),
        "d_date": ColumnData("date", np.asarray([d.isoformat() for d in all_dates])),
        "d_yearmonth": ColumnData("str", np.asarray(
            [f"{mon[d.month - 1]}{d.year}" for d in all_dates])),
        "d_quarter": ColumnData("str", np.asarray(
            [f"{d.year}Q{(d.month - 1) // 3 + 1}" for d in all_dates])),
        "d_year": ColumnData("int", np.asarray([d.year for d in all_dates])),
    })
    zones = [f"{b.replace(' ', '')}_Zone_{i:03d}" for b in BOROUGHS for i in range(12)]
    zone_borough = {z: BOROUGHS[i // 12] for i, z in enumerate(zones)}

    def zone_table(name: str, prefix: str) -> TableData:
        return TableData(name, {
            f"z{prefix}_key": ColumnData("int", np.arange(len(zones))),
            f"{prefix}_zone": ColumnData("str", np.asarray(zones)),
            f"{prefix}_borough": ColumnData("str", np.asarray(
                [zone_borough[z] for z in zones])),
        })

    payment = TableData("payment", {
        "pay_key": ColumnData("int", np.arange(len(PAYMENTS))),
        "payment_type": ColumnData("str", np.asarray(PAYMENTS)),
    })
    dk = rng.integers(0, days, size=n_fact)
    dist = np.round(rng.gamma(2.0, 1.8, size=n_fact), 2)
    fare = np.round(3.0 + dist * 2.6 + rng.normal(0, 2, size=n_fact).clip(-2, 8), 2)
    tip = np.round(np.where(rng.random(n_fact) < 0.65, fare * rng.uniform(0, 0.3, n_fact), 0), 2)
    fact = TableData("trips", {
        "pickup_date_key": ColumnData("int", dk),
        "pu_zone_key": ColumnData("int", rng.integers(0, len(zones), size=n_fact)),
        "do_zone_key": ColumnData("int", rng.integers(0, len(zones), size=n_fact)),
        "payment_key": ColumnData("int", rng.choice(
            len(PAYMENTS), size=n_fact, p=[0.62, 0.30, 0.03, 0.02, 0.03])),
        "fare_amount": ColumnData("float", fare),
        "tip_amount": ColumnData("float", tip),
        "total_amount": ColumnData("float", np.round(fare + tip + 1.75, 2)),
        "trip_distance": ColumnData("float", dist),
        "passenger_count": ColumnData("int", rng.integers(1, 7, size=n_fact)),
        "trip_date": ColumnData("date", dates.columns["d_date"].data[dk].copy()),
    })
    return Dataset(schema, fact, {
        "dates": dates, "zones_pu": zone_table("zones_pu", "pu"),
        "zones_do": zone_table("zones_do", "do"), "payment": payment,
    })


def build_vocab() -> NLVocab:
    return NLVocab(
        schema="nyc_tlc",
        measures={
            "earnings": (MeasureSense("trips.total_amount", "SUM"),),
            "fare": (MeasureSense("trips.fare_amount", "SUM"),),
            "tip": (MeasureSense("trips.tip_amount", "SUM"),),
            "trips": (MeasureSense("*", "COUNT"),),
            "rides": (MeasureSense("*", "COUNT"),),
            "distance": (MeasureSense("trips.trip_distance", "SUM"),),
            "passengers": (MeasureSense("trips.passenger_count", "SUM"),),
            # adversarial: 'revenue' is net-vs-gross ambiguous on this schema
            "revenue": (
                MeasureSense("trips.total_amount", "SUM"),
                MeasureSense("trips.fare_amount", "SUM"),
            ),
        },
        levels={
            "year": ("dates.d_year",),
            "quarter": ("dates.d_quarter",),
            "month": ("dates.d_yearmonth",),
            "pickup borough": ("zones_pu.pu_borough",),
            "dropoff borough": ("zones_do.do_borough",),
            "pickup zone": ("zones_pu.pu_zone",),
            "dropoff zone": ("zones_do.do_zone",),
            "payment type": ("payment.payment_type",),
            # adversarial dimension ambiguity
            "borough": ("zones_pu.pu_borough", "zones_do.do_borough"),
            "zone": ("zones_pu.pu_zone", "zones_do.do_zone"),
            "area": ("zones_pu.pu_zone", "zones_pu.pu_borough"),
        },
        values={
            **{f"picked up in {b.lower()}": (("zones_pu.pu_borough", b),) for b in BOROUGHS},
            **{f"dropped off in {b.lower()}": (("zones_do.do_borough", b),) for b in BOROUGHS},
            "paid by credit card": (("payment.payment_type", "Credit card"),),
            "paid in cash": (("payment.payment_type", "Cash"),),
            # bare borough names: pickup-vs-dropoff ambiguous (adversarial)
            **{b.lower(): (("zones_pu.pu_borough", b), ("zones_do.do_borough", b))
               for b in BOROUGHS},
        },
        numeric_cols={
            "distance": "trips.trip_distance",
            "passenger count": "trips.passenger_count",
        },
        agg_ambiguous_nouns=("trips", "rides", "passengers"),
    )


_J = "JOIN dates ON trips.pickup_date_key = dates.d_key "
_JPU = "JOIN zones_pu ON trips.pu_zone_key = zones_pu.zpu_key "
_JDO = "JOIN zones_do ON trips.do_zone_key = zones_do.zdo_key "
_JPAY = "JOIN payment ON trips.payment_key = payment.pay_key "

_INTENTS = [
    Intent(
        "tlc_01",
        f"SELECT pu_borough, SUM(total_amount) AS earnings FROM trips {_JPU}{_J}"
        "WHERE d_year = 2024 GROUP BY pu_borough",
        nl_measures=("total earnings",), nl_levels=("pickup borough",), nl_time="in 2024",
    ),
    Intent(
        "tlc_02",
        f"SELECT d_yearmonth, SUM(total_amount) AS earnings FROM trips {_J}"
        "WHERE d_year = 2024 GROUP BY d_yearmonth",
        nl_measures=("total earnings",), nl_levels=("month",), nl_time="in 2024",
    ),
    Intent(
        "tlc_03",
        f"SELECT payment_type, COUNT(*) AS n FROM trips {_JPAY}{_J}"
        "WHERE d_quarter = '2024Q1' GROUP BY payment_type",
        nl_measures=("number of trips",), nl_levels=("payment type",), nl_time="in q1 2024",
    ),
    Intent(
        "tlc_04",
        f"SELECT pu_zone, SUM(tip_amount) AS tips FROM trips {_JPU}{_J}"
        "WHERE d_yearmonth = 'Jun2024' GROUP BY pu_zone",
        nl_measures=("total tips",), nl_levels=("pickup zone",), nl_time="in june 2024",
    ),
    Intent(
        "tlc_05",
        f"SELECT d_year, AVG(fare_amount) AS avg_fare FROM trips {_J}"
        "GROUP BY d_year",
        nl_measures=("average fare",), nl_levels=("year",),
    ),
    Intent(
        "tlc_06",
        f"SELECT do_borough, COUNT(*) AS n FROM trips {_JDO}{_J}"
        "WHERE d_year = 2023 GROUP BY do_borough",
        nl_measures=("number of rides",), nl_levels=("dropoff borough",), nl_time="in 2023",
    ),
    Intent(
        "tlc_07",
        f"SELECT pu_borough, SUM(trip_distance) AS dist FROM trips {_JPU}{_J}"
        "WHERE d_year = 2024 GROUP BY pu_borough",
        nl_measures=("total distance",), nl_levels=("pickup borough",), nl_time="in 2024",
    ),
    Intent(
        "tlc_08",
        f"SELECT d_quarter, SUM(total_amount) AS earnings FROM trips {_J}{_JPU}"
        "WHERE pu_borough = 'Manhattan' GROUP BY d_quarter",
        nl_measures=("total earnings",), nl_levels=("quarter",),
        nl_filters=("picked up in manhattan",),
    ),
    Intent(
        "tlc_09",
        f"SELECT payment_type, SUM(tip_amount) AS tips FROM trips {_JPAY}{_J}"
        "WHERE d_year = 2024 GROUP BY payment_type",
        nl_measures=("total tips",), nl_levels=("payment type",), nl_time="in 2024",
    ),
    Intent(
        "tlc_10",
        f"SELECT pu_zone, SUM(total_amount) AS earnings FROM trips {_JPU}{_J}"
        "WHERE d_yearmonth = 'Jul2024' GROUP BY pu_zone "
        "ORDER BY SUM(total_amount) DESC LIMIT 10",
        nl_measures=("total earnings",), nl_levels=("pickup zone",),
        nl_time="in july 2024", nl_extra="top 10",
    ),
    Intent(
        "tlc_11",
        f"SELECT d_yearmonth, COUNT(*) AS n FROM trips {_J}{_JPU}"
        "WHERE pu_borough = 'Brooklyn' AND d_year = 2024 GROUP BY d_yearmonth",
        nl_measures=("number of trips",), nl_levels=("month",),
        nl_filters=("picked up in brooklyn",), nl_time="in 2024",
    ),
    Intent(
        "tlc_12",
        f"SELECT pu_borough, do_borough, COUNT(*) AS n FROM trips {_JPU}{_JDO}{_J}"
        "WHERE d_quarter = '2024Q2' GROUP BY pu_borough, do_borough",
        nl_measures=("number of trips",),
        nl_levels=("pickup borough", "dropoff borough"), nl_time="in q2 2024",
    ),
    Intent(
        "tlc_13",
        f"SELECT d_year, SUM(passenger_count) AS pax FROM trips {_J}"
        "GROUP BY d_year",
        nl_measures=("total passengers",), nl_levels=("year",),
    ),
    Intent(
        "tlc_14",
        f"SELECT pu_borough, AVG(trip_distance) AS avg_dist FROM trips {_JPU}{_J}"
        "WHERE d_year = 2024 GROUP BY pu_borough",
        nl_measures=("average distance",), nl_levels=("pickup borough",), nl_time="in 2024",
    ),
    Intent(
        "tlc_15",
        f"SELECT d_yearmonth, SUM(fare_amount) AS fares FROM trips {_J}{_JPAY}"
        "WHERE payment_type = 'Cash' AND d_year = 2024 GROUP BY d_yearmonth",
        nl_measures=("total fares",), nl_levels=("month",),
        nl_filters=("paid in cash",), nl_time="in 2024",
    ),
    Intent(
        "tlc_16",
        f"SELECT do_zone, SUM(total_amount) AS earnings FROM trips {_JDO}{_J}"
        "WHERE d_yearmonth = 'Dec2023' GROUP BY do_zone",
        nl_measures=("total earnings",), nl_levels=("dropoff zone",),
        nl_time="in december 2023",
    ),
    Intent(
        "tlc_17",
        f"SELECT d_quarter, COUNT(*) AS n FROM trips {_J}"
        "WHERE trip_distance > 10 GROUP BY d_quarter",
        nl_measures=("number of trips",), nl_levels=("quarter",),
        nl_filters=("with distance over 10",),
    ),
    Intent(
        "tlc_18",
        f"SELECT pu_borough, SUM(fare_amount) AS fares, SUM(tip_amount) AS tips "
        f"FROM trips {_JPU}{_J}"
        "WHERE d_year = 2024 GROUP BY pu_borough",
        nl_measures=("total fares", "total tips"), nl_levels=("pickup borough",),
        nl_time="in 2024",
    ),
]


def build(n_fact: int = 150_000, seed: int = 1) -> Workload:
    schema = build_schema()
    return Workload(
        name="nyc_tlc",
        schema=schema,
        dataset=build_dataset(schema, n_fact=n_fact, seed=seed),
        intents=list(_INTENTS),
        vocab=build_vocab(),
        spatial_ambiguous=(
            ("area", ("zones_pu.pu_zone", "zones_pu.pu_borough")),
            ("zone", ("zones_pu.pu_zone", "zones_do.do_zone")),
            ("borough", ("zones_pu.pu_borough", "zones_do.do_borough")),
        ),
    )


QUALIFIED_PHRASES = (
    "pickup zone", "dropoff zone", "pickup borough", "dropoff borough",
)
