"""SSB hierarchical drill workload (RQ4, §5.5).

Dashboard drill sessions over SSB's explicit hierarchies (time, customer
geography, product).  Each 10-query session keeps WHERE fixed and walks
GROUP BY granularities:

    1 x fine-grain query            (cold miss; populates the cache)
    4 x coarser roll-up queries     (derivation hits when roll-up is enabled)
    4 x exact repeats               (exact hits either way)
    1 x drill-down to a finer level (always a miss: query-level caching
                                     lacks the detail data — §3.6)

=> hit rate 8/10 with derivations vs 4/10 without, reproducing the paper's
37% -> 80% structure.  TPC-DS and NYC TLC lack systematic hierarchy
traversal, so derivations are evaluated on SSB by design (paper §5.5).
"""
from __future__ import annotations

from .base import Query

_JD = "JOIN dates ON lineorder.lo_orderdate = dates.d_key "
_JC = "JOIN customer ON lineorder.lo_custkey = customer.c_key "
_JS = "JOIN supplier ON lineorder.lo_suppkey = supplier.s_key "
_JP = "JOIN part ON lineorder.lo_partkey = part.p_key "

# (hierarchy name, needed join, drill path fine -> coarse, drill-down level)
_HIERARCHIES = [
    ("time", _JD, ["d_yearmonth", "d_quarter", "d_year"], "d_date"),
    ("cust_geo", _JD + _JC, ["c_city", "c_nation", "c_region"], None),
    ("prod", _JD + _JP, ["p_brand", "p_category", "p_mfgr"], None),
    ("supp_geo", _JD + _JS, ["s_city", "s_nation", "s_region"], None),
]

_FILTERS = [
    "d_year = 1992", "d_year = 1993", "d_year = 1994", "d_year = 1995",
    "d_year = 1996", "d_year = 1997",
]


def _q(joins: str, level_list: list[str], where: str) -> str:
    cols = ", ".join(level_list)
    return (
        f"SELECT {cols}, SUM(lo_revenue) AS revenue FROM lineorder {joins}"
        f"WHERE {where} GROUP BY {cols}"
    )


def build_stream(n_sessions: int = 20) -> list[Query]:
    """The drill-session query stream (SQL only, matching the paper's RQ4)."""
    out: list[Query] = []
    for s in range(n_sessions):
        hname, joins, path, drill = _HIERARCHIES[s % len(_HIERARCHIES)]
        # unique (hierarchy, filter) pair per session — sessions must not
        # alias each other's cache entries
        where = _FILTERS[(s // len(_HIERARCHIES)) % len(_FILTERS)]
        fine, mid, coarse = path
        sid = f"hier_{s:02d}_{hname}"

        fine_q = _q(joins, [fine, mid], where)  # e.g. (city, nation)
        roll_1 = _q(joins, [mid], where)  # drop + coarsen
        roll_2 = _q(joins, [coarse], where)
        roll_3 = _q(joins, [fine], where)  # drop a level, keep fine
        roll_4 = _q(joins, [mid, coarse], where)
        if drill is not None:
            drill_q = _q(_JD, [drill], where)  # finer than anything cached
        else:
            # different hierarchy's fine level: not derivable from this session;
            # region varies per session so drills never alias across sessions
            region = ["ASIA", "AMERICA", "EUROPE", "AFRICA", "MIDDLE EAST"][s % 5]
            drill_q = _q(_JD + _JS, ["s_city", "s_nation"],
                         where + f" AND s_region = '{region}'")

        seq = [
            (fine_q, "fine"),
            (roll_1, "rollup"), (roll_2, "rollup"),
            (roll_1, "repeat"), (roll_2, "repeat"),
            (roll_3, "rollup"), (roll_4, "rollup"),
            (roll_3, "repeat"), (fine_q, "repeat"),
            (drill_q, "drilldown"),
        ]
        for i, (sql, role) in enumerate(seq):
            out.append(Query("ssb_hier", f"{sid}_{role}", "sql", sql, i))
    return out
