"""BIRD-style human-authored questions (§5.3: 150 OLAP-compatible questions).

The BIRD dev set is unavailable offline; this module synthesizes 150
human-style questions with the same character: a mix of clean requests and
requests carrying realistic ambiguity (synonyms, implicit time references,
underspecified dimensions) absent from the controlled paraphrases — which is
exactly what explains the paper's 51.3% accuracy gap.  Each question carries
a gold signature under the conventional readings of adversarial.py.
"""
from __future__ import annotations

import random

from ..core.signature import Filter, Measure, Signature, TimeWindow
from .adversarial import AdversarialQuery

_CLEAN = [  # (text, schema, gold-builder)
    ("Show total earnings by pickup borough in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.total_amount")],
                  ["zones_pu.pu_borough"], _yw(y))),
    ("How many trips were there by payment type in {y}?", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("COUNT", "*")], ["payment.payment_type"], _yw(y))),
    ("total tips by dropoff borough in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.tip_amount")],
                  ["zones_do.do_borough"], _yw(y))),
    ("average fare by year", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("AVG", "trips.fare_amount")], ["dates.d_year"], None)),
    ("What is total sales by category in {y}?", "tpcds",
     lambda y: _s("tpcds", [_m("SUM", "store_sales.ss_ext_sales_price")],
                  ["item.i_category"], _yw(y))),
    ("total profit by state in {y}", "tpcds",
     lambda y: _s("tpcds", [_m("SUM", "store_sales.ss_net_profit")],
                  ["store.s_state"], _yw(y))),
    ("number of transactions by channel in {y}", "tpcds",
     lambda y: _s("tpcds", [_m("COUNT", "*")], ["promotion.p_channel"], _yw(y))),
    ("total revenue by customer nation in {y}", "ssb",
     lambda y: _s("ssb", [_m("SUM", "lineorder.lo_revenue")],
                  ["customer.c_nation"], _yw(y))),
    ("total profit by supplier region in {y}", "ssb",
     lambda y: _s("ssb", [_m("SUM", "(lineorder.lo_revenue-lineorder.lo_supplycost)")],
                  ["supplier.s_region"], _yw(y))),
    ("number of orders by year", "ssb",
     lambda y: _s("ssb", [_m("COUNT", "*")], ["dates.d_year"], None)),
]

_AMBIGUOUS = [
    # metric: 'revenue' is net-vs-gross on nyc_tlc / tpcds
    ("Show total revenue by pickup borough in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.total_amount")],
                  ["zones_pu.pu_borough"], _yw(y))),
    ("total revenue by month in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.total_amount")],
                  ["dates.d_yearmonth"], _yw(y))),
    ("What was total revenue by state in {y}?", "tpcds",
     lambda y: _s("tpcds", [_m("SUM", "store_sales.ss_ext_sales_price")],
                  ["store.s_state"], _yw(y))),
    # dimension: area/zone/borough underspecified
    ("total earnings by area in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.total_amount")],
                  ["zones_pu.pu_zone"], _yw(y))),
    ("number of trips by zone in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("COUNT", "*")], ["zones_pu.pu_zone"], _yw(y))),
    ("total distance by borough in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.trip_distance")],
                  ["zones_pu.pu_borough"], _yw(y))),
    ("total revenue by region in {y}", "ssb",
     lambda y: _s("ssb", [_m("SUM", "lineorder.lo_revenue")],
                  ["customer.c_region"], _yw(y))),
    # time: implicit/relative references
    ("total earnings by payment type last month", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.total_amount")],
                  ["payment.payment_type"],
                  TimeWindow("2024-02-01", "2024-03-01", open_ended=True))),
    ("number of rides by pickup borough last year", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("COUNT", "*")], ["zones_pu.pu_borough"],
                  TimeWindow("2023-01-01", "2024-01-01", open_ended=True))),
    ("total sales by brand this year", "tpcds",
     lambda y: _s("tpcds", [_m("SUM", "store_sales.ss_ext_sales_price")],
                  ["item.i_brand"],
                  TimeWindow("2024-01-01", "2024-03-15", open_ended=True))),
    # aggregation: count-like nouns without an aggregation word
    ("trips by pickup borough in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("COUNT", "*")], ["zones_pu.pu_borough"], _yw(y))),
    ("passengers by month in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.passenger_count")],
                  ["dates.d_yearmonth"], _yw(y))),
    ("quantity by customer region in {y}", "ssb",
     lambda y: _s("ssb", [_m("SUM", "lineorder.lo_quantity")],
                  ["customer.c_region"], _yw(y))),
    # compositional with a bare noun
    ("earnings and trips and distance by month in {y}", "nyc_tlc",
     lambda y: _s("nyc_tlc", [_m("SUM", "trips.total_amount"), _m("COUNT", "*"),
                              _m("SUM", "trips.trip_distance")],
                  ["dates.d_yearmonth"], _yw(y))),
    ("sales and profit and coupon savings by category in {y}", "tpcds",
     lambda y: _s("tpcds", [_m("SUM", "store_sales.ss_ext_sales_price"),
                            _m("SUM", "store_sales.ss_net_profit"),
                            _m("SUM", "store_sales.ss_coupon_amt")],
                  ["item.i_category"], _yw(y))),
]

_YEARS = {"nyc_tlc": [2023, 2024], "tpcds": [2001, 2002, 2003], "ssb": [1994, 1995, 1996, 1997]}


def _m(agg, expr):
    return Measure(agg, expr)


def _s(schema, measures, levels, tw):
    return Signature(schema=schema, measures=tuple(measures), levels=tuple(levels),
                     time_window=tw)


def _yw(y):
    return TimeWindow(f"{y}-01-01", f"{y + 1}-01-01")


def build(n: int = 150, clean_frac: float = 0.27, seed: int = 5) -> list[AdversarialQuery]:
    rnd = random.Random(seed)
    out: list[AdversarialQuery] = []
    n_clean = int(n * clean_frac)
    pools = [(_CLEAN, n_clean), (_AMBIGUOUS, n - n_clean)]
    for pool, count in pools:
        for i in range(count):
            text_tpl, schema, gold_fn = pool[i % len(pool)]
            y = rnd.choice(_YEARS[schema])
            text = text_tpl.format(y=y)
            out.append(AdversarialQuery(text, gold_fn(y), "birdlike", schema))
    return out[:n]
