"""Serving launcher: the full paper pipeline with the in-framework LLM.

Boots a workload (schema + data + OLAP backend), a canonicalizer LLM served
by our engine (optionally restored from a training checkpoint), and the
semantic cache middleware — then replays a query stream and reports cache
statistics.  ``--simulated-llm`` swaps in the calibrated SimulatedLLM
(no model inference), which is what the paper-table benchmarks use.

Usage:
    python -m repro.launch.serve --workload ssb --queries 100 --simulated-llm
    python -m repro.launch.serve --workload ssb --ckpt-dir ckpts/canon
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="ssb", choices=["ssb", "nyc_tlc", "tpcds"])
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--n-fact", type=int, default=50_000)
    ap.add_argument("--order", default="sequential")
    ap.add_argument("--simulated-llm", action="store_true")
    ap.add_argument("--model", default="gpt-4o-mini")
    ap.add_argument("--arch", default="canonicalizer-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--capacity", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8,
                    help="requests per submit_batch (dashboard refresh size)")
    args = ap.parse_args()

    import jax

    from ..core import MemoizedNL, SafetyPolicy, SemanticCache, SimulatedLLM
    from ..olap.executor import OlapExecutor
    from ..service import CacheService, QueryRequest
    from ..workloads import nyc_tlc, ssb, tpcds

    wl = {"ssb": ssb, "nyc_tlc": nyc_tlc, "tpcds": tpcds}[args.workload].build(
        n_fact=args.n_fact)

    if args.simulated_llm:
        nl = MemoizedNL(SimulatedLLM(wl.vocab, model=args.model))
    else:
        from ..configs.registry import get, reduced
        from ..serving.engine import CanonicalizerService, ServingEngine
        from ..training.checkpoint import restore_latest
        from ..training.tokenizer import build_tokenizer

        cfg = reduced(args.arch) if args.reduced else get(args.arch)
        tok = build_tokenizer([wl])
        mod = cfg.build()
        params = mod.init_params(cfg, jax.random.PRNGKey(0))
        if args.ckpt_dir:
            restored, step, _ = restore_latest(args.ckpt_dir, {"p": params})
            if restored is not None:
                params = restored["p"]
                print(f"[serve] restored canonicalizer from step {step}")
        engine = ServingEngine(cfg, params, tok)
        nl = MemoizedNL(CanonicalizerService(engine, wl.schema.name))

    backend = OlapExecutor(wl.dataset)
    cache = SemanticCache(wl.schema, capacity=args.capacity,
                          level_mapper=wl.dataset.level_mapper())
    svc = CacheService()
    tenant = svc.register_tenant(
        args.workload, schema=wl.schema, backend=backend, cache=cache, nl=nl,
        policy=SafetyPolicy.balanced(wl.spatial_ambiguous))

    stream = wl.queries(order=args.order)[: args.queries]
    # submit in refresh-sized batches: misses within a batch share one
    # backend scan and identical in-flight intents are deduped
    reqs = [QueryRequest(sql=q.text, tenant=args.workload) if q.kind == "sql"
            else QueryRequest(nl=q.text, tenant=args.workload) for q in stream]
    for i in range(0, len(reqs), args.batch):
        svc.submit_batch(reqs[i:i + args.batch])
    s = cache.stats
    n = len(stream)
    print(f"[serve] {n} queries (batch={args.batch}) | hit rate {s.hit_rate:.3f} "
          f"(exact {s.hits_exact}, rollup {s.hits_rollup}, "
          f"filterdown {s.hits_filterdown}) | misses {s.misses} "
          f"| bypasses {tenant.stats.bypasses} "
          f"| batched misses {tenant.stats.batched_misses} "
          f"| deduped {tenant.stats.deduped_misses} "
          f"| backend execs {backend.executions} "
          f"| rows scanned {backend.rows_scanned:,}")


if __name__ == "__main__":
    main()
