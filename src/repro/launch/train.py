"""Training launcher.

Two modes:
  * real run (CPU/TPU): train the canonicalizer model on NL->signature pairs
    (the end-to-end driver; examples/train_canonicalizer.py wraps this),
  * ``--dryrun-mesh``: lower the distributed train step for an assigned arch
    on the production mesh (delegates to launch/dryrun.py machinery).

Usage:
    python -m repro.launch.train --arch canonicalizer-100m --steps 300
    python -m repro.launch.train --arch qwen3-32b --dryrun-mesh multi
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="canonicalizer-100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=192)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config of the arch")
    ap.add_argument("--dryrun-mesh", choices=["single", "multi"], default=None)
    args = ap.parse_args()

    if args.dryrun_mesh:
        from .dryrun import run_cell

        res = run_cell(args.arch, "train_4k", args.dryrun_mesh)
        print(res)
        return

    import jax

    from ..configs.registry import get, reduced
    from ..training.data import BatchIterator, build_pairs
    from ..training.tokenizer import build_tokenizer
    from ..training.train_lib import TrainConfig, train
    from ..workloads import nyc_tlc, ssb, tpcds

    cfg = reduced(args.arch) if args.reduced else get(args.arch)
    wls = [ssb.build(n_fact=1000), nyc_tlc.build(n_fact=1000), tpcds.build(n_fact=1000)]
    tok = build_tokenizer(wls)
    if cfg.vocab < tok.vocab_size:
        raise SystemExit(f"arch vocab {cfg.vocab} < tokenizer {tok.vocab_size}")
    pairs = build_pairs(wls)
    print(f"[train] {len(pairs)} NL->signature pairs, vocab {tok.vocab_size}")
    batches = BatchIterator(pairs, tok, args.batch, args.seq_len)
    tcfg = TrainConfig(steps=args.steps, microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, grad_compression=args.grad_compression)
    out = train(cfg, tcfg, batches, key=jax.random.PRNGKey(0))
    print(f"[train] done; final loss {out['history'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
