"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data x model).
Multi-pod: 2x16x16 = 512 chips (pod x data x model); the 'pod' axis carries
only data parallelism + gradient reduction, keeping TP traffic intra-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (CPU smoke/tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
