import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from placeholder host devices, lowers the real step function
(train_step with AdamW+ZeRO-1, prefill, or decode_step) under pjit with the
full sharding rules, compiles it, and records memory_analysis / cost_analysis
/ per-collective byte totals for the roofline (EXPERIMENTS.md §Dry-run).

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs.registry import ASSIGNED, SUBQUADRATIC, get  # noqa: E402
from ..configs.shapes import SHAPES, input_specs, sds  # noqa: E402
from ..distributed.sharding import (  # noqa: E402
    batch_axes, sharding_hints, tree_param_specs,
)
from ..models.model import ModelConfig, shapes_to_struct  # noqa: E402
from ..training.optimizer import AdamWConfig, adamw_update, init_opt_state, opt_state_specs  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# ------------------------------------------------------- collective parsing

_SHAPE_RE = re.compile(r"(pred|s4|s8|s16|s32|s64|u8|u16|u32|u64|f8\w*|bf16|f16|f32|f64)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_BYTES = {"pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
          "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}


_COMP_RE = re.compile(r"^(?:ENTRY )?(%[\w.-]+) \([^)]*\) -> ", re.M)
_WHILE_RE = re.compile(
    r"while\([^)]*\),[^\n]*?body=(%[\w.-]+)[^\n]*?known_trip_count[^\d]*(\d+)")


def _shape_bytes(blob: str) -> int:
    total = 0
    for sm in _SHAPE_RE.finditer(blob):
        dtype, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        key = dtype if not dtype.startswith("f8") else "s8"
        total += n * _BYTES.get(key, 2)
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective, multiplying ops inside
    while-loop bodies by their known trip counts (XLA cost/text represents a
    loop body once; the scanned layer stack would otherwise be undercounted
    by n_layers)."""
    # split into computations, attribute each collective to its computation
    comp_spans: list[tuple[str, int]] = [("<prelude>", 0)]
    for m in _COMP_RE.finditer(hlo_text):
        comp_spans.append((m.group(1), m.start()))
    comp_spans.append(("<end>", len(hlo_text)))

    def comp_of(pos: int) -> str:
        name = comp_spans[0][0]
        for cname, start in comp_spans[:-1]:
            if start <= pos:
                name = cname
            else:
                break
        return name

    # while nesting -> multiplier per computation
    mult: dict[str, int] = {}
    parents: list[tuple[str, str, int]] = []  # (parent comp, body comp, trip)
    for m in _WHILE_RE.finditer(hlo_text):
        parents.append((comp_of(m.start()), m.group(1), int(m.group(2))))
    changed = True
    passes = 0
    while changed and passes < 8:
        changed = False
        passes += 1
        for parent, body, trip in parents:
            want = trip * mult.get(parent, 1)
            if mult.get(body) != want:
                mult[body] = want
                changed = True

    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_blob, kind = m.group(1), m.group(2)
        k = mult.get(comp_of(m.start()), 1)
        out[kind] = out.get(kind, 0) + _shape_bytes(shapes_blob) * k
        count[kind] = count.get(kind, 0) + k
    return {"bytes_by_kind": out, "count_by_kind": count,
            "total_bytes": sum(out.values()),
            "loop_multipliers": {k: v for k, v in mult.items() if v > 1}}


# ----------------------------------------------------------- cell execution


def activation_hints(cfg: ModelConfig, mesh, baxes) -> dict:
    """Baseline activation-sharding hints (the perf pass iterates on these)."""
    model_size = mesh.shape["model"]
    hints = {"residual": P(baxes, None, None)}
    if cfg.n_heads % model_size == 0 and cfg.kind in ("dense", "moe", "hybrid"):
        hints["attn_heads"] = P(baxes, "model", None, None)
    if cfg.d_ff and cfg.d_ff % model_size == 0:
        hints["mlp_hidden"] = P(baxes, None, "model")
    return hints


def cache_specs(cfg: ModelConfig, caches_shape, baxes, mesh, long_context: bool,
                kv_seq_shard: bool = False):
    """Sharding specs for decode caches.  KV caches shard batch normally; the
    long_500k (batch=1) shape shards the sequence axis across the whole mesh.
    ``kv_seq_shard`` (perf variant): additionally shard the KV sequence axis
    over 'model' — flash-decode style — so the model axis reads its own cache
    slice instead of all-gathering the cache when kv_heads < model shards."""
    model_size = mesh.shape["model"]
    all_axes = tuple(mesh.axis_names)

    def spec_for(path: str, leaf) -> P:
        shape = leaf.shape
        batch_ok = shape[1] % _axes_size(mesh, baxes) == 0
        b_ax = baxes if batch_ok else None
        if "state" in path:  # (L, B, H, P, N)
            if cfg.ssm_heads % model_size == 0:
                return P(None, b_ax, "model", None, None)
            return P(None, b_ax, None, None, None)
        if "conv" in path:  # (L, B, K-1, di)
            if cfg.d_inner % model_size == 0:
                return P(None, b_ax, None, "model")
            return P(None, b_ax, None, None)
        # KV caches: (L, B, Hkv, S, Dh)
        if long_context:
            return P(None, None, None, all_axes, None)
        if kv_seq_shard:
            return P(None, b_ax, None, "model", None)
        return P(None, b_ax, None, None, None)

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, path + "/" + k) for k, v in tree.items()}
        if isinstance(tree, tuple):
            return tuple(walk(v, path) for v in tree)
        return spec_for(path, tree)

    return walk(caches_shape)


def _axes_size(mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def run_cell(arch: str, shape_name: str, mesh_kind: str, with_opt: bool = True,
             hint_overrides: dict | None = None, variant: str = "baseline") -> dict:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    baxes = batch_axes(mesh.axis_names)
    baxes = baxes if len(baxes) > 1 else baxes[0]
    model_size = mesh.shape["model"]
    mod = cfg.build()

    pshapes = cfg.param_shapes()
    pstruct = shapes_to_struct(pshapes, cfg.dtype)
    pspecs = tree_param_specs(pshapes, model_size,
                              stacked_prefixes=("layers", "dense_layers", "mamba"))
    if variant.startswith("zero3_params"):
        # ZeRO-3-lite: params *stored* data+model sharded; XLA gathers the
        # stacked weights once per step in bf16, and the updated params are
        # written back sharded (no output gather at all)
        from ..training.optimizer import opt_state_specs as _oss

        _dax = batch_axes(mesh.axis_names)
        pspecs = _oss(pspecs, shapes_to_struct(pshapes, cfg.dtype),
                      _dax, _axes_size(mesh, _dax))["m"]
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))

    ins = input_specs(cfg, shape)
    hints = activation_hints(cfg, mesh, baxes)
    if "sp" in variant and shape.kind != "decode":
        # Megatron-style sequence parallelism: residual stream sharded over
        # 'model' on the sequence axis between blocks
        hints["residual"] = P(baxes, "model", None)
    if hint_overrides:
        hints.update(hint_overrides)

    t0 = time.time()
    with mesh, sharding_hints(hints):
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            ostruct = jax.eval_shape(init_opt_state, pstruct)
            data_size = _axes_size(mesh, baxes if isinstance(baxes, tuple) else (baxes,))
            if variant == "no_zero1":
                ospecs = {"m": pspecs, "v": pspecs, "step": P()}
            elif variant.startswith("zero3_params"):
                # params already carry the data axis; moments share their specs
                ospecs = {"m": pspecs, "v": pspecs, "step": P()}
            else:
                ospecs = opt_state_specs(pspecs, pstruct,
                                         baxes if isinstance(baxes, tuple) else (baxes,),
                                         data_size)
            oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                                  is_leaf=lambda x: isinstance(x, P))
            bspec = {k: NamedSharding(mesh, P(baxes, *([None] * (len(v.shape) - 1))))
                     for k, v in ins.items()}

            mspecs = oshard["m"] if variant == "zero1_bf16_gather" else None

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: mod.loss_fn(cfg, p, batch))(params)
                new_p, new_o, gnorm = adamw_update(opt_cfg, params, grads, opt_state,
                                                   moment_specs=mspecs)
                return loss, gnorm, new_p, new_o

            jitted = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, bspec),
                out_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                               pshard, oshard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(pstruct, ostruct, ins)
        elif shape.kind == "prefill":
            bspec = {k: NamedSharding(mesh, P(baxes, *([None] * (len(v.shape) - 1))))
                     for k, v in ins.items()}

            def prefill_step(params, batch):
                return mod.prefill(cfg, params, cache_len=shape.seq_len, **batch)

            jitted = jax.jit(prefill_step, in_shardings=(pshard, bspec))
            lowered = jitted.lower(pstruct, ins)
        else:  # decode
            long_ctx = shape_name == "long_500k"
            cspecs = cache_specs(cfg, ins["caches"], baxes, mesh, long_ctx,
                                 kv_seq_shard=(variant == "kv_seq_shard"))
            cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                  is_leaf=lambda x: isinstance(x, P))
            tok_spec = NamedSharding(mesh, P(baxes) if shape.global_batch >= 32 else P(None))

            def decode(params, token, caches, pos):
                return mod.decode_step(cfg, params, token, caches, pos)

            logits_spec = (NamedSharding(mesh, P(None, "model"))
                           if variant == "kv_seq_shard" and cfg.vocab % model_size == 0
                           else NamedSharding(mesh, P(None, None)))
            jitted = jax.jit(
                decode,
                in_shardings=(pshard, tok_spec, cshard, tok_spec),
                out_shardings=(logits_spec, cshard, tok_spec),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(pstruct, ins["token"], ins["caches"], ins["pos"])
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

        # cost probe: unrolled lowering (no compile) — XLA's HloCostAnalysis
        # counts loop bodies once, so the scanned module undercounts FLOPs by
        # ~n_layers; the unrolled module gives complete *global* FLOPs/bytes.
        t2 = time.time()
        from ..models.model import unrolled_scans

        try:
            with unrolled_scans():
                # fresh jit wrapper: the scan-unroll contextvar is not part of
                # jax's trace cache key, so the probe must force a re-trace
                if shape.kind == "train":
                    probe = jax.jit(lambda p, o, b: train_step(p, o, b))
                    unrolled = probe.lower(pstruct, ostruct, ins)
                elif shape.kind == "prefill":
                    probe = jax.jit(lambda p, b: prefill_step(p, b))
                    unrolled = probe.lower(pstruct, ins)
                else:
                    probe = jax.jit(lambda p, t, c, g: decode(p, t, c, g))
                    unrolled = probe.lower(pstruct, ins["token"], ins["caches"], ins["pos"])
            ucost = unrolled.cost_analysis() or {}
        except Exception as e:  # cost probe is best-effort
            ucost = {"error": f"{type(e).__name__}: {e}"}
        probe_s = time.time() - t2

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "variant": variant,
        "status": "ok", "lower_s": round(lower_s, 2), "compile_s": round(compile_s, 2),
        "probe_s": round(probe_s, 2),
        "flops_global": ucost.get("flops", 0.0),
        "bytes_global": ucost.get("bytes accessed", 0.0),
        "cost_probe_error": ucost.get("error"),
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": coll,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }


def cells(include_long: bool = True):
    for arch in ASSIGNED:
        for shape_name in SHAPES:
            if shape_name == "long_500k" and arch not in SUBQUADRATIC:
                continue  # full attention @524k context: skipped per DESIGN.md
            if shape_name == "long_500k" and not include_long:
                continue
            for mesh_kind in ("single", "multi"):
                yield arch, shape_name, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--variant", default="baseline",
                    help="baseline | kv_seq_shard | no_zero1 | zero3_params | *_sp")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    todo = (list(cells()) if args.all
            else [(args.arch, args.shape, args.mesh)])
    for arch, shape_name, mesh_kind in todo:
        key = f"{arch}|{shape_name}|{mesh_kind}"
        if args.variant != "baseline":
            key += f"|{args.variant}"
        if key in results and results[key].get("status") == "ok" and not args.force:
            print(f"SKIP {key}")
            continue
        print(f"RUN  {key} ...", flush=True)
        try:
            res = run_cell(arch, shape_name, mesh_kind, variant=args.variant)
        except Exception as e:  # record failures; they are bugs to fix
            res = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        status = res["status"]
        extra = (f"compile={res.get('compile_s')}s flops/dev={res.get('flops_per_device'):.3e}"
                 if status == "ok" else res.get("error", "")[:200])
        print(f"DONE {key}: {status} {extra}", flush=True)

    ok = sum(1 for r in results.values() if r.get("status") == "ok")
    print(f"\n{ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
