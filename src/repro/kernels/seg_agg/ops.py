"""Dispatching wrappers for grouped aggregation.

Implementation selection (shared convention for all kernels in this repo):

* ``REPRO_KERNELS=pallas``     — compiled Pallas (TPU),
* ``REPRO_KERNELS=interpret``  — Pallas interpret mode (CPU correctness),
* ``REPRO_KERNELS=xla``        — pure-jnp reference (XLA lowering),
* unset                        — pallas on TPU, xla elsewhere.

Three entry points:

* ``seg_agg``        — plain (N, M) grouped aggregation with an explicit mask
  (the seed per-measure path keeps using this);
* ``seg_agg_fused``  — filter-fused variant: the mask is built on-device from
  encoded predicate range bounds (no HBM mask round-trip on the Pallas path);
* ``seg_agg_batch``  — shared-scan batch: S signatures' bounds against one
  value block, one kernel launch, returns (S, num_groups, M);
* ``seg_agg_batch_blocks`` — one launch for a whole shared-scan group: the
  fused SUM block plus the optional MIN/MAX block, sharing the per-signature
  masks and rect gathers between the two reduces (the service miss
  planner's entry point).

Every dispatcher call counts as one kernel launch in a module-level probe
(``launch_count``/``reset_launch_count``) so tests can assert the executor's
single-launch property.  The multi-pod dry-run lowers the XLA path; kernels
are validated against ref.py in interpret mode by the test suite.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .kernel import seg_agg_fused_pallas, seg_agg_pallas
from .ref import bounds_mask_ref, seg_agg_fused_ref, seg_agg_ref

_LAUNCHES = {"n": 0}


def launch_count() -> int:
    """Number of seg_agg dispatcher calls since the last reset (test probe)."""
    return _LAUNCHES["n"]


def reset_launch_count() -> None:
    _LAUNCHES["n"] = 0


def _record_launch() -> None:
    _LAUNCHES["n"] += 1


def kernel_impl() -> str:
    env = os.environ.get("REPRO_KERNELS", "").lower()
    if env in ("pallas", "interpret", "xla"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def seg_agg(values, ids, mask, num_groups: int, op: str = "sum", impl: str | None = None):
    """Grouped aggregation: (N, M) values + (N,) ids -> (num_groups, M)."""
    impl = impl or kernel_impl()
    _record_launch()
    if impl == "xla":
        return seg_agg_ref(values, ids, mask, num_groups, op)
    return seg_agg_pallas(
        values, ids, mask, num_groups, op, interpret=(impl == "interpret")
    )


@functools.partial(jax.jit, static_argnames=("num_groups", "op"))
def _fused_ref_jit(values, ids, pred_cols, bounds, num_groups, op):
    return seg_agg_fused_ref(values, ids, pred_cols, bounds, num_groups, op)


def _pallas_nan_safe_sum(v, ids, num_groups, interpret):
    """NaN-safe all-rows sum on the plain Pallas kernel: its one-hot matmul
    spreads any NaN across the whole group tile (0 * NaN), so reduce cleaned
    values and NaN indicators side by side in one launch, then re-poison
    exactly the groups whose rows carried NaNs."""
    m = v.shape[1]
    nan = jnp.isnan(v)
    stacked = jnp.concatenate([jnp.where(nan, 0.0, v), nan.astype(jnp.float32)], axis=1)
    ones = jnp.ones(v.shape[0], jnp.float32)
    both = seg_agg_pallas(stacked, ids, ones, num_groups, "sum", interpret=interpret)
    return both[:, :m] + jnp.where(both[:, m:] > 0, jnp.nan, 0.0)


def _rect_reduce(values, mask, rect_idx, op):
    """Gather-based segment reduce over a precomputed (G, R) row-index
    rectangle (rows of group g, padded with out-of-range indices).  Avoids
    XLA's serial scatter on CPU — the hot reduce becomes a vectorized gather
    + axis reduce — and tree-reduces instead of sequentially accumulating
    (tighter f32 error).  Pad cells read mask=False, so they contribute the
    op identity; NaNs stay confined to their own group cell."""
    mrect = jnp.take(mask, rect_idx, axis=0, mode="fill", fill_value=False)
    vrect = jnp.take(values, rect_idx, axis=0, mode="fill", fill_value=0.0)
    if op == "sum":
        return jnp.sum(jnp.where(mrect[..., None], vrect, 0.0), axis=1)
    ident = jnp.inf if op == "min" else -jnp.inf
    vrect = jnp.where(mrect[..., None], vrect, ident)
    return jnp.min(vrect, axis=1) if op == "min" else jnp.max(vrect, axis=1)


@functools.partial(jax.jit, static_argnames=("op",))
def _fused_rect_jit(values, pred_cols, bounds, rect_idx, op):
    mask = bounds_mask_ref(pred_cols, bounds)
    return _rect_reduce(jnp.asarray(values, jnp.float32), mask, rect_idx, op)


def seg_agg_fused(values, ids, pred_cols, bounds, num_groups: int,
                  op: str = "sum", impl: str | None = None, rect_idx=None):
    """Filter-fused grouped aggregation (single launch).

    values (N, M), ids (N,), pred_cols (N, P) f32, bounds (P, K, 2) f32
    inclusive [lo, hi] ranges (OR over K, AND over P) -> (num_groups, M).
    With P == 0 (no predicates) this degrades to a plain all-rows reduce.
    ``rect_idx`` (optional, XLA path) is a cached (num_groups, R) row-index
    rectangle for these ids; when given, the reduce is gather-based instead
    of scatter-based (much faster on CPU backends).
    """
    impl = impl or kernel_impl()
    _record_launch()
    p = int(bounds.shape[0])
    if impl == "xla":
        b = jnp.asarray(bounds, jnp.float32)
        if rect_idx is not None:
            return _fused_rect_jit(values, pred_cols, b, rect_idx, op)
        return _fused_ref_jit(values, ids, pred_cols, b, num_groups, op)
    if p == 0:
        interp = impl == "interpret"
        if op == "sum":
            return _p0_sum_jit(jnp.asarray(values, jnp.float32),
                               jnp.asarray(ids, jnp.int32), num_groups, interp)
        # min/max select through the one-hot: NaNs stay in their own group
        ones = jnp.ones(values.shape[0], jnp.float32)
        return seg_agg_pallas(values, ids, ones, num_groups, op,
                              interpret=interp)
    b = jnp.asarray(bounds, jnp.float32)
    flat = jnp.concatenate([b[:, :, 0], b[:, :, 1]], axis=1)  # (P, 2K)
    return seg_agg_fused_pallas(values, ids, pred_cols, flat, num_groups, op,
                                interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("num_groups", "interpret"))
def _p0_sum_jit(values, ids, num_groups, interpret):
    return _pallas_nan_safe_sum(values, ids, num_groups, interpret)


# unrolled per-group GEMM below this many groups; einsum (one fused
# batched-dot) above it, where unrolling would bloat the program
_BATCH_GEMM_UNROLL_MAX_G = 64


def _rect_batch_masks(pred_cols, bounds, rect_idx):
    """(S, G, R) per-signature mask rectangles, built in one vmapped pass
    over the batch's (S, P, K, 2) bounds."""
    masks = jax.vmap(lambda b: bounds_mask_ref(pred_cols, b))(bounds)  # (S, N)
    return jnp.take(masks, rect_idx, axis=1, mode="fill", fill_value=False)


def _rect_batch_sum(mrect, values, rect_idx):
    """Batched masked segment-sum on the rect layout.

    The (G, R, M) value gather does not depend on the signature, so it is
    done once and shared by all S masks; the reduce is then a G-batched
    (S, R) x (R, 2M) matmul over [NaN-cleaned values | NaN indicators]
    (GEMM instead of S separate where+sum sweeps over the rectangle), with
    groups whose selected rows carried NaNs re-poisoned afterwards — the
    same NaN contract as ``seg_agg_fused``.
    """
    values = jnp.asarray(values, jnp.float32)
    m = values.shape[1]
    vrect = jnp.take(values, rect_idx, axis=0, mode="fill", fill_value=0.0)  # (G,R,M)
    nan = jnp.isnan(vrect)
    stacked = jnp.concatenate(
        [jnp.where(nan, 0.0, vrect), nan.astype(jnp.float32)], axis=-1)
    mf = mrect.astype(jnp.float32)
    g = stacked.shape[0]
    if g <= _BATCH_GEMM_UNROLL_MAX_G:
        both = jnp.stack([mf[:, i, :] @ stacked[i] for i in range(g)], axis=1)
    else:
        both = jnp.einsum("sgr,grm->sgm", mf, stacked)
    return both[..., :m] + jnp.where(both[..., m:] > 0, jnp.nan, 0.0)


def _rect_batch_minmax(mrect, values, rect_idx, op):
    """Batched masked min/max on the rect layout: values are gathered once
    in (M, G, R) layout so each signature's reduce runs over the contiguous
    last axis (a strided (G, R, M) reduce is ~2x slower on CPU)."""
    ident = jnp.inf if op == "min" else -jnp.inf
    red = jnp.min if op == "min" else jnp.max
    vrect_t = jnp.take(jnp.asarray(values, jnp.float32).T, rect_idx,
                       axis=1, mode="fill", fill_value=ident)  # (M, G, R)
    outs = [red(jnp.where(mrect[i][None], vrect_t, ident), axis=2)  # (M, G)
            for i in range(mrect.shape[0])]
    return jnp.stack(outs).transpose(0, 2, 1)  # (S, G, M)


@functools.partial(jax.jit, static_argnames=("op",))
def _batch_rect_jit(values, pred_cols, bounds, rect_idx, op):
    mrect = _rect_batch_masks(pred_cols, bounds, rect_idx)
    if op == "sum":
        return _rect_batch_sum(mrect, values, rect_idx)
    return _rect_batch_minmax(mrect, values, rect_idx, op)


@jax.jit
def _batch_blocks_rect_jit(sum_block, mm_block, pred_cols, bounds, rect_idx):
    mrect = _rect_batch_masks(pred_cols, bounds, rect_idx)
    return (_rect_batch_sum(mrect, sum_block, rect_idx),
            _rect_batch_minmax(mrect, mm_block, rect_idx, "min"))


@functools.partial(jax.jit, static_argnames=("op",))
def _masked_rect_jit(values, mask, rect_idx, op):
    return _rect_reduce(jnp.asarray(values, jnp.float32), mask > 0.5, rect_idx, op)


@functools.partial(jax.jit, static_argnames=("num_groups", "op", "impl"))
def _masked_jit(values, ids, mask, num_groups, op, impl):
    values = jnp.asarray(values, jnp.float32)
    sel = mask > 0.5
    if op == "sum":
        v = jnp.where(sel[:, None], values, 0.0)
        if impl == "xla":
            return jax.ops.segment_sum(v, ids, num_segments=num_groups)
        return _pallas_nan_safe_sum(v, ids, num_groups, impl == "interpret")
    ident = jnp.inf if op == "min" else -jnp.inf
    v = jnp.where(sel[:, None], values, ident)
    if impl == "xla":
        seg = jax.ops.segment_min if op == "min" else jax.ops.segment_max
        return seg(v, ids, num_segments=num_groups)
    ones = jnp.ones(values.shape[0], jnp.float32)
    return seg_agg_pallas(v, ids, ones, num_groups, op,
                          interpret=(impl == "interpret"))


def seg_agg_masked(values, ids, mask, num_groups: int, op: str = "sum",
                   impl: str | None = None, rect_idx=None):
    """Fused grouped aggregation with an explicit row mask (single launch).

    Same NaN contract as ``seg_agg_fused`` (masked-out rows contribute the
    op identity; NaNs stay in their own group — unlike the seed ``seg_agg``,
    whose mask-multiply lets masked-out NaNs poison their group).  Used when
    predicates need exact host-side evaluation (values outside the f32-exact
    range) but the aggregation should stay fused and device-side.
    """
    impl = impl or kernel_impl()
    _record_launch()
    mask = jnp.asarray(mask, jnp.float32)
    if impl == "xla" and rect_idx is not None:
        return _masked_rect_jit(values, mask, rect_idx, op)
    return _masked_jit(values, ids, mask, num_groups, op, impl)


@functools.partial(jax.jit, static_argnames=("num_groups", "op", "impl"))
def _batch_jit(values, ids, pred_cols, bounds, num_groups, op, impl):
    s = bounds.shape[0]
    n, m = values.shape
    # one vmapped bounds pass (as in _rect_batch_masks) instead of unrolling
    # S copies of the mask computation into the program
    masks = jax.vmap(lambda b: bounds_mask_ref(pred_cols, b))(bounds).T  # (N, S)
    if op == "sum":
        v = jnp.where(masks[:, :, None], values[:, None, :], 0.0)
    else:
        ident = jnp.inf if op == "min" else -jnp.inf
        v = jnp.where(masks[:, :, None], values[:, None, :], ident)
    v = v.reshape(n, s * m)
    if impl == "xla":
        seg = {"sum": jax.ops.segment_sum, "min": jax.ops.segment_min,
               "max": jax.ops.segment_max}[op]
        out = seg(v, ids, num_segments=num_groups)
    elif op == "sum":
        out = _pallas_nan_safe_sum(v, ids, num_groups, impl == "interpret")
    else:
        # min/max select through the one-hot, so NaNs stay in their own group
        ones = jnp.ones(n, jnp.float32)
        out = seg_agg_pallas(v, ids, ones, num_groups, op,
                             interpret=(impl == "interpret"))
    return out.reshape(num_groups, s, m).transpose(1, 0, 2)


def seg_agg_batch(values, ids, pred_cols, bounds, num_groups: int,
                  op: str = "sum", impl: str | None = None, rect_idx=None):
    """Shared-scan batched aggregation for S signatures (one launch).

    values (N, M), ids (N,), pred_cols (N, P) over the union of the batch's
    predicate columns, bounds (S, P, K, 2) per-signature ranges ->
    (S, num_groups, M).  Rows are scanned once; each signature's mask selects
    its slice of the expanded value block.  Masked-out rows are replaced by
    the op identity before reducing (NaN-safe, same contract as
    ``seg_agg_fused``).  ``rect_idx`` as in ``seg_agg_fused``.
    """
    impl = impl or kernel_impl()
    _record_launch()
    if impl == "xla" and rect_idx is not None:
        return _batch_rect_jit(values, jnp.asarray(pred_cols, jnp.float32),
                               jnp.asarray(bounds, jnp.float32), rect_idx, op)
    return _batch_jit(values, ids, jnp.asarray(pred_cols, jnp.float32),
                      jnp.asarray(bounds, jnp.float32), num_groups, op, impl)


def seg_agg_batch_blocks(sum_block, mm_block, ids, pred_cols, bounds,
                         num_groups: int, impl: str | None = None,
                         rect_idx=None):
    """One launch for a whole shared-scan group: the fused SUM/COUNT/AVG
    block plus the (optional) fused MIN/MAX block, sharing the per-signature
    masks and rect gathers between the two reduces instead of rebuilding
    them per block.  This is the service miss planner's entry point — a
    dashboard refresh is one call here, whatever its measure mix.

    Returns ``(sums (S, G, 1+Ms), mm (S, G, Mm) | None)``; MAX columns are
    pre-negated by the caller so the mm reduce is always a min.  On the
    xla+rect path both blocks genuinely share one jitted computation (one
    recorded launch); the pallas/interpret and scatter fallbacks dispatch
    one kernel per block and record launches accordingly.
    """
    impl = impl or kernel_impl()
    _record_launch()
    pred_cols = jnp.asarray(pred_cols, jnp.float32)
    b = jnp.asarray(bounds, jnp.float32)
    if impl == "xla" and rect_idx is not None:
        if mm_block is None:
            return _batch_rect_jit(sum_block, pred_cols, b, rect_idx, "sum"), None
        return _batch_blocks_rect_jit(sum_block, mm_block, pred_cols, b, rect_idx)
    sums = _batch_jit(sum_block, ids, pred_cols, b, num_groups, "sum", impl)
    mm = None
    if mm_block is not None:
        _record_launch()  # second kernel dispatch on the per-block fallback
        mm = _batch_jit(mm_block, ids, pred_cols, b, num_groups, "min", impl)
    return sums, mm
