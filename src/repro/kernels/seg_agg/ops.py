"""Dispatching wrapper for grouped aggregation.

Implementation selection (shared convention for all kernels in this repo):

* ``REPRO_KERNELS=pallas``     — compiled Pallas (TPU),
* ``REPRO_KERNELS=interpret``  — Pallas interpret mode (CPU correctness),
* ``REPRO_KERNELS=xla``        — pure-jnp reference (XLA lowering),
* unset                        — pallas on TPU, xla elsewhere.

The multi-pod dry-run lowers the XLA path; kernels are validated against
ref.py in interpret mode by the test suite.
"""
from __future__ import annotations

import os

import jax

from .kernel import seg_agg_pallas
from .ref import seg_agg_ref


def kernel_impl() -> str:
    env = os.environ.get("REPRO_KERNELS", "").lower()
    if env in ("pallas", "interpret", "xla"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def seg_agg(values, ids, mask, num_groups: int, op: str = "sum", impl: str | None = None):
    """Grouped aggregation: (N, M) values + (N,) ids -> (num_groups, M)."""
    impl = impl or kernel_impl()
    if impl == "xla":
        return seg_agg_ref(values, ids, mask, num_groups, op)
    return seg_agg_pallas(
        values, ids, mask, num_groups, op, interpret=(impl == "interpret")
    )
