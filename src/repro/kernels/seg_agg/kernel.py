"""Pallas TPU kernel: grouped aggregation as a one-hot MXU matmul.

GPU engines do group-by aggregation with hash tables + atomic scatter-adds.
TPU has no fast scatter, so we restructure for the memory hierarchy and the
systolic MXU: stream (TN, M) value tiles HBM->VMEM, build a (TN, TG) one-hot
of group ids *in VMEM*, and accumulate partial aggregates with
``one_hot.T @ values`` on the MXU.  The output tile (TG, M) stays resident in
VMEM across the whole N sweep (grid minor axis) and is written back once per
group tile.

Arithmetic intensity: the matmul spends 2·G flops per loaded value vs a 4-byte
HBM read, so the kernel stays memory-bound (the roofline optimum for a
reduction) for G up to ~800 groups per tile at v5e ratios — exactly the
dashboard regime (grouping cardinalities of tens to hundreds).  MIN/MAX use a
masked select-and-reduce on the VPU instead of the matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TN = 1024  # fact rows per tile
DEFAULT_TG = 512  # groups per tile; one-hot tile = TN*TG*4B = 2 MiB VMEM


def _seg_agg_kernel(values_ref, ids_ref, mask_ref, out_ref, *, op: str, tg: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        if op == "sum":
            out_ref[...] = jnp.zeros_like(out_ref)
        elif op == "min":
            out_ref[...] = jnp.full_like(out_ref, jnp.inf)
        else:
            out_ref[...] = jnp.full_like(out_ref, -jnp.inf)

    gb = pl.program_id(0)
    values = values_ref[...]  # (TN, M) f32
    ids = ids_ref[...][:, 0]  # (TN,)
    mask = mask_ref[...][:, 0] > 0.5  # (TN,)
    tn = values.shape[0]
    local = ids - gb * tg
    onehot = (local[:, None] == jax.lax.broadcasted_iota(jnp.int32, (tn, tg), 1)) & mask[:, None]
    if op == "sum":
        oh = onehot.astype(jnp.float32)
        out_ref[...] += jax.lax.dot_general(
            oh, values, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # (TG, M)
    else:
        ident = jnp.inf if op == "min" else -jnp.inf
        m = values.shape[1]
        # VPU path: per-measure masked reduce over the row axis
        for j in range(m):
            vj = jnp.where(onehot, values[:, j][:, None], ident)  # (TN, TG)
            red = jnp.min(vj, axis=0) if op == "min" else jnp.max(vj, axis=0)
            cur = out_ref[:, j]
            out_ref[:, j] = jnp.minimum(cur, red) if op == "min" else jnp.maximum(cur, red)


@functools.partial(jax.jit, static_argnames=("num_groups", "op", "tn", "tg", "interpret"))
def seg_agg_pallas(
    values,
    ids,
    mask,
    num_groups: int,
    op: str = "sum",
    tn: int = DEFAULT_TN,
    tg: int = DEFAULT_TG,
    interpret: bool = False,
):
    """values (N, M) f32, ids (N,) int32, mask (N,) -> (num_groups, M) f32."""
    n, m = values.shape
    values = jnp.asarray(values, jnp.float32)
    ids = jnp.asarray(ids, jnp.int32)
    mask = jnp.asarray(mask, jnp.float32)
    tn = min(tn, max(8, n))
    tg = min(tg, max(8, num_groups))
    n_pad = (-n) % tn
    g_pad = (-num_groups) % tg
    if n_pad:
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
        ids = jnp.pad(ids, (0, n_pad))
        mask = jnp.pad(mask, (0, n_pad))
    gp = num_groups + g_pad
    grid = (gp // tg, (n + n_pad) // tn)
    out = pl.pallas_call(
        functools.partial(_seg_agg_kernel, op=op, tg=tg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, m), lambda gb, nb: (nb, 0)),
            pl.BlockSpec((tn, 1), lambda gb, nb: (nb, 0)),
            pl.BlockSpec((tn, 1), lambda gb, nb: (nb, 0)),
        ],
        out_specs=pl.BlockSpec((tg, m), lambda gb, nb: (gb, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, m), jnp.float32),
        interpret=interpret,
    )(values, ids[:, None], mask[:, None])
    return out[:num_groups]


# ------------------------------------------------------------- filter-fused


def _seg_agg_fused_kernel(values_ref, ids_ref, pred_ref, bounds_ref, out_ref,
                          *, op: str, tg: int, nk: int, tn: int, n: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        if op == "sum":
            out_ref[...] = jnp.zeros_like(out_ref)
        elif op == "min":
            out_ref[...] = jnp.full_like(out_ref, jnp.inf)
        else:
            out_ref[...] = jnp.full_like(out_ref, -jnp.inf)

    gb = pl.program_id(0)
    values = values_ref[...]  # (TN, M)
    ids = ids_ref[...][:, 0]  # (TN,)
    pred = pred_ref[...]  # (TN, P)
    bounds = bounds_ref[...]  # (P, 2K): [:, :K] = lo, [:, K:] = hi
    p = pred.shape[1]
    # build the predicate mask inside the tile (no HBM mask round-trip):
    # AND over predicates of OR over that predicate's [lo, hi] ranges
    # (NaN-sentinel ranges match NaN values, see ref.bounds_mask_ref).
    # Static unrolled loops — P and K are small (dashboard filters).
    # N-padding rows are cut by the global row-index guard.
    mask = (nb * tn + jax.lax.broadcasted_iota(jnp.int32, (tn,), 0)) < n
    for j in range(p):
        x = pred[:, j]
        mj = None
        for k in range(nk):
            lo, hi = bounds[j, k], bounds[j, nk + k]
            within = ((x >= lo) & (x <= hi)) | (jnp.isnan(x) & jnp.isnan(lo))
            mj = within if mj is None else (mj | within)
        mask = mask & mj
    local = ids - gb * tg
    onehot = (local[:, None] == jax.lax.broadcasted_iota(jnp.int32, (tn, tg), 1)) & mask[:, None]
    if op == "sum":
        # NaN-safe accumulate: a NaN anywhere in the tile would poison every
        # group through 0 * NaN in the matmul, so reduce cleaned values and
        # route NaNs to exactly the groups whose qualifying rows carry them
        # (second matmul is ~free: the kernel is memory-bound)
        finite = ~jnp.isnan(values)
        vals = jnp.where(mask[:, None] & finite, values, 0.0)
        nan_ind = (mask[:, None] & ~finite).astype(jnp.float32)
        oh = onehot.astype(jnp.float32)
        acc = jax.lax.dot_general(
            oh, vals, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        hits = jax.lax.dot_general(
            oh, nan_ind, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        out_ref[...] += acc + jnp.where(hits > 0, jnp.nan, 0.0)
    else:
        ident = jnp.inf if op == "min" else -jnp.inf
        m = values.shape[1]
        for j in range(m):
            vj = jnp.where(onehot, values[:, j][:, None], ident)  # (TN, TG)
            red = jnp.min(vj, axis=0) if op == "min" else jnp.max(vj, axis=0)
            cur = out_ref[:, j]
            out_ref[:, j] = jnp.minimum(cur, red) if op == "min" else jnp.maximum(cur, red)


@functools.partial(jax.jit, static_argnames=("num_groups", "op", "tn", "tg", "interpret"))
def seg_agg_fused_pallas(
    values,
    ids,
    pred_cols,
    bounds,
    num_groups: int,
    op: str = "sum",
    tn: int = DEFAULT_TN,
    tg: int = DEFAULT_TG,
    interpret: bool = False,
):
    """Filter-fused grouped aggregation.

    values (N, M) f32, ids (N,) int32, pred_cols (N, P) f32,
    bounds (P, 2K) f32 ([:, :K] lo / [:, K:] hi inclusive range pairs, OR
    within a predicate, AND across predicates) -> (num_groups, M) f32.

    The predicate mask is built inside the Pallas tile from the encoded
    bounds, so no (N,) mask is ever materialized in HBM.  Validated against
    ``ref.bounds_mask_ref`` + ``ref.seg_agg_fused_ref`` in interpret mode.
    """
    n, m = values.shape
    p = pred_cols.shape[1]
    nk = bounds.shape[1] // 2
    values = jnp.asarray(values, jnp.float32)
    ids = jnp.asarray(ids, jnp.int32)
    pred_cols = jnp.asarray(pred_cols, jnp.float32)
    bounds = jnp.asarray(bounds, jnp.float32)
    tn = min(tn, max(8, n))
    tg = min(tg, max(8, num_groups))
    n_pad = (-n) % tn
    g_pad = (-num_groups) % tg
    if n_pad:
        # pad rows are cut in-tile by the global row-index guard
        values = jnp.pad(values, ((0, n_pad), (0, 0)))
        ids = jnp.pad(ids, (0, n_pad))
        pred_cols = jnp.pad(pred_cols, ((0, n_pad), (0, 0)))
    gp = num_groups + g_pad
    grid = (gp // tg, (n + n_pad) // tn)
    out = pl.pallas_call(
        functools.partial(_seg_agg_fused_kernel, op=op, tg=tg, nk=nk, tn=tn, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, m), lambda gb, nb: (nb, 0)),
            pl.BlockSpec((tn, 1), lambda gb, nb: (nb, 0)),
            pl.BlockSpec((tn, p), lambda gb, nb: (nb, 0)),
            pl.BlockSpec((p, 2 * nk), lambda gb, nb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tg, m), lambda gb, nb: (gb, 0)),
        out_shape=jax.ShapeDtypeStruct((gp, m), jnp.float32),
        interpret=interpret,
    )(values, ids[:, None], pred_cols, bounds)
    return out[:num_groups]
