"""Pure-jnp oracle for grouped aggregation (segment reduce)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

IDENTITY = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def seg_agg_ref(values, ids, mask, num_groups: int, op: str = "sum"):
    """Grouped aggregation oracle.

    values: (N, M) float; ids: (N,) int32 group ids in [0, num_groups);
    mask: (N,) {0,1} row validity.  Returns (num_groups, M).  Empty groups
    hold the op identity (0 / +inf / -inf); callers use a COUNT column to
    drop them, matching SQL semantics where empty groups are absent.
    """
    values = jnp.asarray(values, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    if op == "sum":
        return jax.ops.segment_sum(values * mask[:, None], ids, num_segments=num_groups)
    if op == "min":
        v = jnp.where(mask[:, None] > 0.5, values, jnp.inf)
        return jax.ops.segment_min(v, ids, num_segments=num_groups)
    if op == "max":
        v = jnp.where(mask[:, None] > 0.5, values, -jnp.inf)
        return jax.ops.segment_max(v, ids, num_segments=num_groups)
    raise ValueError(f"unknown op {op!r}")
