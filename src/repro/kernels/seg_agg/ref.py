"""Pure-jnp oracle for grouped aggregation (segment reduce)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

IDENTITY = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def seg_agg_ref(values, ids, mask, num_groups: int, op: str = "sum"):
    """Grouped aggregation oracle.

    values: (N, M) float; ids: (N,) int32 group ids in [0, num_groups);
    mask: (N,) {0,1} row validity.  Returns (num_groups, M).  Empty groups
    hold the op identity (0 / +inf / -inf); callers use a COUNT column to
    drop them, matching SQL semantics where empty groups are absent.
    """
    values = jnp.asarray(values, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    if op == "sum":
        return jax.ops.segment_sum(values * mask[:, None], ids, num_segments=num_groups)
    if op == "min":
        v = jnp.where(mask[:, None] > 0.5, values, jnp.inf)
        return jax.ops.segment_min(v, ids, num_segments=num_groups)
    if op == "max":
        v = jnp.where(mask[:, None] > 0.5, values, -jnp.inf)
        return jax.ops.segment_max(v, ids, num_segments=num_groups)
    raise ValueError(f"unknown op {op!r}")


def bounds_mask_ref(pred_cols, bounds):
    """Predicate mask from encoded range bounds (the filter-fused oracle).

    pred_cols: (N, P) f32 fact-aligned physical predicate columns;
    bounds: (P, K, 2) f32, a disjunction of K inclusive [lo, hi] ranges per
    predicate.  A row qualifies iff every predicate has some range containing
    its value (CNF over ranges: ``=`` is [v,v], ``<=`` is [-inf,v], IN-lists
    are one range per member).  Special ranges:

    * pad (lo=+inf, hi=-inf): never matches;
    * NaN sentinel (lo=hi=NaN): matches exactly the NaN column values.
      ``!=`` encodes as two open ranges *plus* the sentinel (numpy
      semantics: ``NaN != v`` is True), and batch fillers for columns a
      signature doesn't constrain as [(-inf, inf)] plus the sentinel (no
      filter at all accepts every row);
    * ordinary comparison ranges reject NaN values, matching numpy.
    """
    pred_cols = jnp.asarray(pred_cols, jnp.float32)
    if pred_cols.shape[1] == 0:
        return jnp.ones(pred_cols.shape[0], dtype=bool)
    bounds = jnp.asarray(bounds, jnp.float32)
    x = pred_cols[:, :, None]  # (N, P, 1)
    lo = bounds[None, :, :, 0]  # (1, P, K)
    hi = bounds[None, :, :, 1]
    within = ((x >= lo) & (x <= hi)) | (jnp.isnan(x) & jnp.isnan(lo))
    return jnp.all(jnp.any(within, axis=-1), axis=-1)


def seg_agg_fused_ref(values, ids, pred_cols, bounds, num_groups: int, op: str = "sum"):
    """Filter-fused oracle: build the mask from encoded bounds, then do a
    NaN-safe masked segment reduce.  Unlike ``seg_agg_ref`` (which multiplies
    by the mask, so NaNs in masked-out rows poison their group), masked-out
    rows are replaced by the op identity *before* reducing — a NaN only
    reaches a group if a qualifying row carries it, matching the host oracle.
    """
    values = jnp.asarray(values, jnp.float32)
    mask = bounds_mask_ref(pred_cols, bounds)
    if op == "sum":
        v = jnp.where(mask[:, None], values, 0.0)
        return jax.ops.segment_sum(v, ids, num_segments=num_groups)
    ident = jnp.inf if op == "min" else -jnp.inf
    v = jnp.where(mask[:, None], values, ident)
    seg = jax.ops.segment_min if op == "min" else jax.ops.segment_max
    if op not in ("min", "max"):
        raise ValueError(f"unknown op {op!r}")
    return seg(v, ids, num_segments=num_groups)
