"""Pure-jnp oracle: causal GQA attention (prefill/training path)."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh) with H % Hkv == 0.

    Returns (B, H, S, Dh).  float32 accumulation, bf16-friendly inputs.
    """
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)
