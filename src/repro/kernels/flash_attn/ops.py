"""Dispatching wrapper for causal GQA attention (see seg_agg/ops.py for the
REPRO_KERNELS convention)."""
from __future__ import annotations

from ..seg_agg.ops import kernel_impl
from .kernel import flash_attention_pallas
from .ref import mha_ref


def flash_attention(q, k, v, causal: bool = True, scale: float | None = None,
                    impl: str | None = None):
    impl = impl or kernel_impl()
    if impl == "xla":
        return mha_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, interpret=(impl == "interpret")
    )
