"""Pallas TPU kernel: causal flash attention (blockwise online softmax).

Tiling for the TPU memory hierarchy: Q tiles of (TQ, Dh) stay VMEM-resident
while K/V tiles of (TK, Dh) stream HBM->VMEM; the (TQ, TK) logits tile feeds
the MXU; the online-softmax running max/denominator live in VREGs/VMEM
scratch.  Causality is exploited structurally: K tiles strictly above the
diagonal are skipped via ``pl.when`` on the grid index, halving the work — the
TPU equivalent of the CUDA early-exit.

Grid: (B*Hq, Sq/TQ, Skv/TK) — KV minor so each Q tile accumulates in place.
GQA is handled by the index_map: q head h reads kv head h // group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 512
DEFAULT_TK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, tq: int, tk: int, kv_len: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal structural skip: this K tile is entirely in the future
    run = (not causal) or (kb * tk <= qb * tq + tq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (TQ, Dh)
        k = k_ref[0].astype(jnp.float32)  # (TK, Dh)
        v = v_ref[0].astype(jnp.float32)  # (TK, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (TQ, TK)
        cols = kb * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        s = jnp.where(cols < kv_len, s, NEG_INF)  # mask padded keys
        if causal:
            rows = qb * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]  # (TQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)  # (TQ, TK)
        alpha = jnp.exp(m_prev - m_new)  # (TQ, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "tq", "tk", "interpret"),
)
def flash_attention_pallas(q, k, v, causal: bool = True, scale: float | None = None,
                           tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                           interpret: bool = False):
    """q: (B, H, S, Dh); k, v: (B, Hkv, S, Dh) -> (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    tq = min(tq, s)
    tk = min(tk, s)
    if s % tq or s % tk:  # pad sequence to tile multiple
        pad = (-s) % max(tq, tk)
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        out = _flash_padded(q, k, v, causal, scale, tq, tk, interpret, kv_len=s)
        return out[:, :, :s]
    return _flash_padded(q, k, v, causal, scale, tq, tk, interpret, kv_len=s)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "tq", "tk", "interpret", "kv_len"),
)
def _flash_padded(q, k, v, causal, scale, tq, tk, interpret, kv_len):
    b, h, s, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    qr = q.reshape(b * h, s, dh)
    kr = k.reshape(b * hkv, s, dh)
    vr = v.reshape(b * hkv, s, dh)
    grid = (b * h, s // tq, s // tk)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal, tq=tq, tk=tk,
                          kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, dh), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, tk, dh), lambda bh, qb, kb, g=group, hh=h, hk=hkv:
                         ((bh // hh) * hk + (bh % hh) // g, kb, 0)),
            pl.BlockSpec((1, tk, dh), lambda bh, qb, kb, g=group, hh=h, hk=hkv:
                         ((bh // hh) * hk + (bh % hh) // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, dh), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), q.dtype),
        scratch_shapes=[
            _vmem((tq, 1)),  # running max
            _vmem((tq, 1)),  # running denominator
            _vmem((tq, dh)),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, dh)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
