"""Dispatching wrapper for KV-cache decode attention."""
from __future__ import annotations

from ..seg_agg.ops import kernel_impl
from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q, k, v, pos, scale: float | None = None, impl: str | None = None):
    impl = impl or kernel_impl()
    if impl == "xla":
        return decode_attention_ref(q, k, v, pos, scale=scale)
    return decode_attention_pallas(q, k, v, pos, scale=scale,
                                   interpret=(impl == "interpret"))
