"""Pure-jnp oracle: single-token GQA attention over a KV cache.

Written in GQA-grouped form — (B, Hkv, G, Dh) query against (B, Hkv, S, Dh)
cache — with no materialized head ``repeat`` and no f32 copy of the cache:
f32 happens in the dot accumulator (``preferred_element_type``).  This
matters under SPMD: the naive repeat+astype forces XLA to materialize (and,
when kv_heads < model shards, all-gather) a full-precision copy of the whole
cache; the grouped form keeps the cache read in place and shards cleanly
over the sequence axis (flash-decode style), with only softmax statistics
crossing shards.
"""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, scale: float | None = None):
    """q: (B, H, Dh); k, v: (B, Hkv, S, Dh); pos: (B,) valid cache lengths.

    Attends to cache positions [0, pos_b) per batch row.  Returns (B, H, Dh).
    """
    b, h, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(b, hkv, group, dh).astype(q.dtype)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k,
                        preferred_element_type=jnp.float32)  # (B, Hkv, G, S)
    mask = jnp.arange(s)[None, None, None, :] < pos[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bksd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, dh).astype(q.dtype)
