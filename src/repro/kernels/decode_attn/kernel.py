"""Pallas TPU kernel: KV-cache decode attention (memory-bound streaming).

One new token attends over a long cache: arithmetic intensity is O(1) flops
per cache byte, so the kernel is a pure HBM-bandwidth stream.  All G = H/Hkv
query heads of a KV group are processed together against each streamed
(TK, Dh) cache tile — the cache is read exactly once, the roofline optimum.
Online softmax state (m, l, acc) lives in VMEM scratch across the KV sweep.

Grid: (B * Hkv, S / TK).  Dynamic cache lengths are handled with a per-row
``pos`` operand masking cols >= pos.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TK = 1024
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, tk: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0, 0]
    # skip tiles entirely past the valid length
    @pl.when(kb * tk < pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (G, Dh)
        k = k_ref[0].astype(jnp.float32)  # (TK, Dh)
        v = v_ref[0].astype(jnp.float32)  # (TK, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, TK)
        cols = kb * tk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kb == pl.num_programs(1) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "tk", "interpret"))
def decode_attention_pallas(q, k, v, pos, scale: float | None = None,
                            tk: int = DEFAULT_TK, interpret: bool = False):
    """q: (B, H, Dh); k, v: (B, Hkv, S, Dh); pos: (B,) -> (B, H, Dh)."""
    b, h, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = h // hkv
    scale = scale if scale is not None else dh ** -0.5
    tk = min(tk, s)
    pad = (-s) % tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        s = s + pad
    qr = q.reshape(b, hkv, group, dh).reshape(b * hkv, group, dh)
    kr = k.reshape(b * hkv, s, dh)
    vr = v.reshape(b * hkv, s, dh)
    pos_r = jnp.broadcast_to(pos[:, None], (b, hkv)).reshape(b * hkv, 1).astype(jnp.int32)
    grid = (b * hkv, s // tk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, group, dh), lambda bh, kb: (bh, 0, 0)),
            pl.BlockSpec((1, tk, dh), lambda bh, kb: (bh, kb, 0)),
            pl.BlockSpec((1, tk, dh), lambda bh, kb: (bh, kb, 0)),
            pl.BlockSpec((1, 1), lambda bh, kb: (bh, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, dh), lambda bh, kb: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, dh), q.dtype),
        scratch_shapes=[_vmem((group, 1)), _vmem((group, 1)), _vmem((group, dh))],
        interpret=interpret,
    )(qr, kr, vr, pos_r)
    return out.reshape(b, hkv, group, dh).reshape(b, h, dh)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
