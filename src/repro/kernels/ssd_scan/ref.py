"""Pure-jnp oracle for the Mamba2 SSD recurrence (sequential scan).

State-space duality (arXiv:2405.21060): per head h with state (P, N),

    h_t = exp(A_h * dt_t) * h_{t-1} + dt_t * (x_t  outer  B_t)
    y_t = h_t @ C_t

x: (B, S, H, P); dt: (B, S, H) > 0; A: (H,) < 0; Bm, Cm: (B, S, N) (one state
group, as in Mamba2).  Returns y: (B, S, H, P) and final state (B, H, P, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    state0 = (jnp.zeros((b, h, p, n), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(Af[None, :] * dtt)  # (B,H)
        upd = dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        state = state * decay[..., None, None] + upd  # (B,H,P,N)
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,H,P)
    return y, final


def ssd_chunked_xla(x, dt, A, Bm, Cm, chunk: int = 128):
    """Vectorized chunked SSD in plain jnp — the XLA lowering used by the
    dry-run (mirrors the Pallas kernel's math and FLOP structure: per-chunk
    (L,L) masked matmuls + an O(S/L) inter-chunk scan)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk
    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, chunk, n)
    Af = A.astype(jnp.float32)

    adt = Af[None, None, None, :] * dtf  # (B,NC,L,H)
    cum = jnp.cumsum(adt, axis=2)  # inclusive
    total = cum[:, :, -1, :]  # (B,NC,H)

    # intra-chunk
    g = jnp.einsum("bcln,bcsn->bcls", Cf, Bf)  # (B,NC,L,L)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,NC,L,L,H)
    m = jnp.where(mask[None, None, :, :, None], decay * dtf[:, :, None, :, :], 0.0)
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", g, m, xf)

    # chunk state contributions
    w = Bf[:, :, :, None, :] * (dtf * jnp.exp(total[:, :, None, :] - cum))[..., None]
    chunk_states = jnp.einsum("bclhn,bclhp->bchpn", w, xf)  # (B,NC,H,P,N)

    # inter-chunk scan over NC (short: S/L steps)
    def step(state, inp):
        tot, cs = inp  # (B,H), (B,H,P,N)
        new = state * jnp.exp(tot)[..., None, None] + cs
        return new, state  # emit the *previous* state for this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(chunk_states, 1, 0)))
    prev = jnp.moveaxis(prev_states, 0, 1)  # (B,NC,H,P,N)

    y_inter = jnp.einsum("bcln,bchpn->bclhp", Cf, prev) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype)


def ssd_final_state(x, dt, A, Bm, Cm=None):
    """Final SSM state after the full sequence (for prefill cache seeding):
    state = sum_t exp(cum_S - cum_t) * dt_t * (x_t outer B_t)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    adt = A.astype(jnp.float32)[None, None, :] * dtf  # (B,S,H)
    cum = jnp.cumsum(adt, axis=1)
    w = dtf * jnp.exp(cum[:, -1:, :] - cum)  # (B,S,H)
    return jnp.einsum("bshp,bsh,bsn->bhpn", xf, w, Bm.astype(jnp.float32))


def ssd_decode_step(state, xt, dtt, A, bt, ct):
    """Single decode step: state (B,H,P,N) -> (y (B,H,P), new state)."""
    decay = jnp.exp(A[None, :].astype(jnp.float32) * dtt.astype(jnp.float32))
    upd = (dtt[..., None, None].astype(jnp.float32)
           * xt.astype(jnp.float32)[..., None]
           * bt.astype(jnp.float32)[:, None, None, :])
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
    return y.astype(xt.dtype), state
