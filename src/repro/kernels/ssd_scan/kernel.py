"""Pallas TPU kernel: chunked Mamba2 SSD scan (state-space duality).

The SSD insight: within a chunk of L timesteps the recurrence collapses to
dense matmuls (an attention-like (L, L) masked product on the MXU) while the
O(S) part reduces to a once-per-chunk state update.  We map it to TPU as:

  grid = (B*H, S/L), chunk index minor — the (P, N) head state lives in VMEM
  scratch and is carried *sequentially across grid steps*, so the whole scan
  is one kernel launch with no HBM state traffic between chunks.

Per chunk (all in fp32 on MXU/VPU):
  cum_t   = cumsum(A * dt)                      (decay exponents)
  y_intra = ((C B^T) * M) x        with  M[t,s] = exp(cum_t - cum_s)·dt_s·1[s<=t]
  y_inter = (C @ state) * exp(cum)
  state  <- exp(cum_L) * state + (B * dt * exp(cum_L - cum))^T x
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                *, chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0].astype(jnp.float32)  # (L, 1)
    a = a_ref[0, 0]  # scalar decay rate for this head
    bm = b_ref[0].astype(jnp.float32)  # (L, N)
    cm = c_ref[0].astype(jnp.float32)  # (L, N)

    adt = a * dt  # (L, 1), negative
    cum = jnp.cumsum(adt, axis=0)  # inclusive cumsum (L, 1)

    # intra-chunk: masked decay attention on the MXU
    g = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum - cum.T)  # exp(cum_t - cum_s)
    m = jnp.where(rows >= cols, decay * dt.T, 0.0)  # (L, L)
    y = jax.lax.dot_general(g * m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk: contribution of the carried state
    state = state_ref[...]  # (N, P)
    y += jnp.exp(cum) * jax.lax.dot_general(
        cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update for the next chunk
    total = jnp.exp(cum[-1, 0])
    w = bm * (dt * jnp.exp(cum[-1:] - cum))  # (L, N) weights
    state_ref[...] = total * state + jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, Bm, Cm, chunk: int = DEFAULT_CHUNK,
                    interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N) -> y: (B,S,H,P).

    S must not be tiny; it is padded to a chunk multiple (dt=0 padding is a
    no-op on the state).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    # layout: (B*H, S, ·) with chunk-minor grid carrying state across chunks
    xr = jnp.moveaxis(x, 2, 1).reshape(b * h, sp, p)
    dtr = jnp.moveaxis(dt, 2, 1).reshape(b * h, sp, 1)
    ar = jnp.tile(A.astype(jnp.float32)[None, :], (b, 1)).reshape(b * h, 1)
    br = jnp.repeat(Bm, h, axis=0).reshape(b, h, sp, n).reshape(b * h, sp, n)
    cr = jnp.repeat(Cm, h, axis=0).reshape(b, h, sp, n).reshape(b * h, sp, n)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, 1), lambda bh, cb: (bh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, cb: (bh, cb, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh, cb: (bh, cb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, p), x.dtype),
        scratch_shapes=[_vmem((n, p))],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    y = y.reshape(b, h, sp, p)[:, :, :s]
    return jnp.moveaxis(y, 1, 2)  # (B, S, H, P)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
