"""Dispatching wrapper for the Mamba2 SSD scan."""
from __future__ import annotations

from ..seg_agg.ops import kernel_impl
from .kernel import ssd_scan_pallas
from .ref import ssd_chunked_xla


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128, impl: str | None = None):
    """Chunked SSD scan: returns y (B, S, H, P)."""
    impl = impl or kernel_impl()
    if impl == "xla":
        return ssd_chunked_xla(x, dt, A, Bm, Cm, chunk=chunk)
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=(impl == "interpret"))
