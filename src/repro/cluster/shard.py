"""One cache shard: a :class:`SemanticCache` behind a fine-grained lock.

A shard is the cluster's unit of concurrency and eviction: it owns a plain
single-threaded ``SemanticCache`` (per-shard behavior is bit-identical to a
standalone cache), an ``RLock`` serializing every cache operation, and the
single-flight registry for misses routed to it.  Lock hold times are the
length of one cache operation — lookups on different shards never contend,
which is where the cluster's multi-thread throughput comes from.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..analysis.sanitizer import make_lock
from ..core.cache import CacheEntry, LookupResult, SemanticCache
from ..core.signature import Signature
from ..core.table import ResultTable
from .flight import Flight


class CacheShard:
    """A locked ``SemanticCache`` + the single-flight registry for its keys."""

    def __init__(self, index: int, cache: SemanticCache):
        # index is rewritten only by the stop-the-world rebalance, which
        # holds every shard lock
        self.index = index  # guarded-by: external[cluster rebalance holds all shard locks]
        self.cache = cache
        self.lock = make_lock("CacheShard.lock", reentrant=True)
        self._inflight: dict[str, Flight] = {}  # guarded-by: self.lock

    # -------------------------------------------------------------- lookups
    def lookup(self, sig: Signature, request_origin: str = "sql") -> LookupResult:
        with self.lock:
            return self.cache.lookup(sig, request_origin)

    def lookup_batch(
        self, items: Sequence[tuple[Signature, str]]
    ) -> list[LookupResult]:
        """One lock acquisition for a whole shard-local batch."""
        with self.lock:
            return [self.cache.lookup(sig, origin) for sig, origin in items]

    def peek_stale(self, sig: Signature):
        """Degraded-serving read: a possibly-stale table for this signature
        (hot even-if-expired, cold payload, or the TTL morgue), or None."""
        with self.lock:
            return self.cache.peek_stale(sig)

    def lookup_or_flight(
        self, sig: Signature, request_origin: str = "sql"
    ) -> tuple[LookupResult, Optional[Flight], bool]:
        """Atomic lookup + single-flight registration.

        Returns ``(result, flight, leader)``: a hit carries no flight; a miss
        either *creates* a flight (``leader=True`` — the caller must execute
        and resolve it) or *joins* an existing one (``leader=False`` — the
        caller waits on it instead of executing).
        """
        with self.lock:
            lr = self.cache.lookup(sig, request_origin)
            if lr.status != "miss":
                return lr, None, False
            key = sig.key()
            flight = self._inflight.get(key)
            if flight is not None:
                return lr, flight, False
            flight = Flight(key, self)
            self._inflight[key] = flight
            return lr, flight, True

    def lookup_or_flight_batch(
        self, items: Sequence[tuple[Signature, str]]
    ) -> list[tuple[LookupResult, Optional[Flight], bool]]:
        with self.lock:
            return [self.lookup_or_flight(sig, origin) for sig, origin in items]

    # ------------------------------------------------------- flight lifecycle
    def complete_flight(self, flight: Flight, table: Optional[ResultTable]) -> None:
        with self.lock:
            self._inflight.pop(flight.key, None)
            flight._resolve(table, None)

    def fail_flight(self, flight: Flight, error: BaseException) -> None:
        with self.lock:
            self._inflight.pop(flight.key, None)
            flight._resolve(None, error)

    def inflight(self) -> int:
        with self.lock:
            return len(self._inflight)

    # ------------------------------------------------------------- mutation
    def put(self, sig: Signature, table: ResultTable, origin: str = "sql",
            snapshot_id: str = "snap0", *, cost_ms: float = 0.0,
            ttl_s: Optional[float] = None) -> str:
        with self.lock:
            return self.cache.put(sig, table, origin, snapshot_id,
                                  cost_ms=cost_ms, ttl_s=ttl_s)

    def drop(self, key: str) -> bool:
        with self.lock:
            return self.cache.drop(key)

    def refresh_entry(self, key: str, table: ResultTable, snapshot_id: str,
                      merged: bool = True) -> None:
        with self.lock:
            self.cache.refresh_entry(key, table, snapshot_id, merged)

    def invalidate_snapshot(self, updated_start: Optional[str] = None,
                            updated_end: Optional[str] = None) -> int:
        with self.lock:
            return self.cache.invalidate_snapshot(updated_start, updated_end)

    def invalidate_schema_change(self) -> int:
        with self.lock:
            return self.cache.invalidate_schema_change()

    def ensure_loaded(self, key: str) -> Optional[CacheEntry]:
        """Entry with its table resident, promoting from the cold tier if
        demoted (refresh merges need the actual table)."""
        with self.lock:
            return self.cache.ensure_loaded(key)

    # -------------------------------------------------------- introspection
    def contains(self, key: str) -> bool:
        with self.lock:
            return (key in self.cache._entries
                    or key in self.cache._cold)

    def entry(self, key: str) -> Optional[CacheEntry]:
        with self.lock:
            return self.cache.entry(key)

    def affected_keys(self, updated_start: Optional[str] = None,
                      updated_end: Optional[str] = None) -> list[str]:
        with self.lock:
            return self.cache.affected_keys(updated_start, updated_end)

    def keys(self) -> list[str]:
        with self.lock:
            return self.cache.keys()

    def __len__(self) -> int:
        with self.lock:
            return len(self.cache)

    def total_bytes(self) -> int:
        with self.lock:
            return self.cache.total_bytes()

    def tier_stats(self) -> dict:
        with self.lock:
            return self.cache.tier_stats()

    def entries_summary(self, limit: int = 256) -> list[dict]:
        with self.lock:
            return self.cache.entries_summary(limit)
