"""CacheCluster — family-partitioned shards under one cache surface.

The cluster partitions the OLAP Intent Signature key space across N
:class:`CacheShard` s by **derivation-family key** ``(scope, schema,
measure_key)`` — exactly the tier-1 key of the in-cache derivation index.
Every candidate that could ever serve a roll-up / filter-down / compose
derivation for a request shares that triple with it, so derivation families
are *shard-local by construction*: a shard-local lookup sees the same
candidate set as a single global cache, and per-shard behavior stays
bit-identical to a standalone :class:`SemanticCache`.  ``shards=1`` is
therefore a differential oracle for the unsharded path.

The cluster exposes the full cache surface:

* routed ``lookup`` / ``put`` (+ single-flight miss registration, so
  concurrent identical cold signatures execute once — see ``flight.py``);
* **scatter-gather** batch lookup: one lock acquisition per touched shard
  per batch, results reassembled in request order;
* broadcast lifecycle — ``affected_keys`` / ``invalidate_snapshot`` /
  ``invalidate_schema_change`` / ``refresh_entry`` fan out over shards;
* ``add_shard`` / ``remove_shard`` with deterministic key migration:
  entries re-route under the new shard count and are rebuilt preserving
  tables, hit counters, LRU recency order, store order, and derivation-index
  membership (``SemanticCache.rebuild``);
* aggregated ``stats`` (sum over shards plus retired shards' counters) and
  per-shard breakdowns.

Concurrency model: every cache operation holds exactly one shard lock for
the duration of one ``SemanticCache`` call; cross-shard operations
(broadcasts, key probes) take shard locks one at a time and are linearizable
per shard but not atomic across shards.  Rebalancing holds *all* shard locks
(stop-the-world for the cache, not for executing backends).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Sequence

from ..analysis.sanitizer import allow_same_class_order, make_lock
from ..core import derivations as dv
from ..core.cache import (CacheEntry, CacheStats, LookupResult, SemanticCache)
from ..core.schema import StarSchema
from ..core.signature import Signature
from ..core.table import ResultTable
from .flight import DEFAULT_FLIGHT_TIMEOUT_S, Flight
from .shard import CacheShard


def family_key(sig: Signature) -> tuple:
    """The derivation-family routing key: the same ``(scope, schema, measure
    multiset)`` triple the cache's tier-1 derivation index buckets by.  Two
    signatures where one could serve the other through any derivation always
    share it."""
    return (sig.scope, sig.schema, sig.measure_key())


def family_hash(sig: Signature) -> int:
    """Deterministic (process- and run-independent) hash of the family key,
    so a persisted/warmed cluster routes identically across restarts.
    Interned on the (frozen) signature instance like ``key()`` — routing a
    previously seen signature is a dict probe, not a hash computation (the
    benign compute-twice race under threads is idempotent)."""
    h = sig.__dict__.get("_family_hash")
    if h is None:
        scope, schema, measures = family_key(sig)
        blob = json.dumps([scope, schema, [list(m) for m in measures]],
                          separators=(",", ":"), default=str)
        h = int.from_bytes(
            hashlib.blake2b(blob.encode(), digest_size=8).digest(), "big")
        object.__setattr__(sig, "_family_hash", h)
    return h


def _sum_stats(parts: Sequence[CacheStats]) -> CacheStats:
    agg = CacheStats()
    for p in parts:
        for f in dataclasses.fields(CacheStats):
            setattr(agg, f.name, getattr(agg, f.name) + getattr(p, f.name))
    return agg


class CacheCluster:
    """N family-partitioned cache shards behind the SemanticCache surface."""

    def __init__(
        self,
        schema: StarSchema,
        shards: int = 4,
        *,
        capacity: Optional[int] = None,  # TOTAL entry budget, split per shard
        capacity_bytes: Optional[int] = None,  # TOTAL byte budget, split
        enable_rollup: bool = True,
        enable_filterdown: bool = True,
        enable_compose: bool = False,
        level_mapper: Optional[dv.LevelMapper] = None,
        indexed_probes: bool = True,
        single_flight: bool = True,
        flight_timeout: float = DEFAULT_FLIGHT_TIMEOUT_S,
        concurrent_misses: bool = True,
        policy: Optional[str] = None,  # 'lru' | 'cost' | None = auto
        cold_capacity_bytes: Optional[int] = None,  # TOTAL cold budget, split
        ttl_s: Optional[float] = None,
        hit_half_life_s: Optional[float] = None,
        write_through: bool = False,
    ):
        if shards < 1:
            raise ValueError(f"cluster needs >= 1 shard, got {shards}")
        self.schema = schema
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self.enable_rollup = enable_rollup
        self.enable_filterdown = enable_filterdown
        self.enable_compose = enable_compose
        self.level_mapper = level_mapper
        self.indexed_probes = indexed_probes
        self.single_flight = single_flight
        self.flight_timeout = flight_timeout
        self.policy = policy
        self.cold_capacity_bytes = cold_capacity_bytes
        self.ttl_s = ttl_s
        self.hit_half_life_s = hit_half_life_s
        self.write_through = write_through  # guarded-by: self._topology_lock
        # one shared TieredStore per tenant/cluster; bound by attach_store
        # under the topology lock, read by _new_cache (same lock)
        self._store = None  # guarded-by: self._topology_lock
        # advisory to the miss planner: per-shard miss groups may execute
        # concurrently (the backend's plan memos are idempotent)
        self.concurrent_misses = concurrent_misses
        # serializes topology changes; individual operations take only the
        # target shard's lock
        self._topology_lock = make_lock("CacheCluster._topology_lock")
        # the rebalance nests every shard lock (in shard-index order) under
        # the topology lock: register that deterministic same-class order
        allow_same_class_order("CacheShard.lock")
        self._retired_stats = CacheStats()  # guarded-by: self._topology_lock
        # obs-plane audit log, re-applied to every shard cache across
        # reshards (set_audit / set_shards both hold the topology lock)
        self._audit = None  # guarded-by: self._topology_lock
        self._audit_labels: dict = {}  # guarded-by: self._topology_lock
        # rebound only by set_shards under the topology lock; lock-free
        # readers take a consistent list snapshot and re-validate routes
        # after acquiring the target shard's lock (see _shard_op)
        self._shards: list[CacheShard] = [  # guarded-by: self._topology_lock
            CacheShard(i, self._new_cache(shards)) for i in range(shards)
        ]

    @classmethod
    def from_template(cls, cache: SemanticCache, shards: int,
                      **kw) -> "CacheCluster":
        """Build a cluster whose shards inherit a template cache's config
        (the ``register_tenant(cache=..., shards=N)`` path)."""
        return cls(
            cache.schema, shards,
            capacity=cache.capacity, capacity_bytes=cache.capacity_bytes,
            enable_rollup=cache.enable_rollup,
            enable_filterdown=cache.enable_filterdown,
            enable_compose=cache.enable_compose,
            level_mapper=cache.level_mapper,
            indexed_probes=cache.indexed_probes,
            policy=cache.policy,
            cold_capacity_bytes=cache.cold_capacity_bytes,
            ttl_s=cache.ttl_s,
            hit_half_life_s=cache.hit_half_life_s,
            write_through=cache.write_through, **kw)

    def _new_cache(self, n_shards: int) -> SemanticCache:
        kw = {}
        if self.hit_half_life_s is not None:
            kw["hit_half_life_s"] = self.hit_half_life_s
        return SemanticCache(
            self.schema,
            capacity=self._split(self.capacity, n_shards),
            enable_rollup=self.enable_rollup,
            enable_filterdown=self.enable_filterdown,
            enable_compose=self.enable_compose,
            level_mapper=self.level_mapper,
            indexed_probes=self.indexed_probes,
            capacity_bytes=self._split(self.capacity_bytes, n_shards),
            policy=self.policy,
            store=self._store,
            cold_capacity_bytes=self._split(self.cold_capacity_bytes, n_shards),
            ttl_s=self.ttl_s,
            write_through=self.write_through,
            **kw,
        )

    @staticmethod
    def _split(total: Optional[int], n: int) -> Optional[int]:
        # ceil-split so shards=1 gets exactly the single-cache budget and a
        # rebalance can never silently shrink the aggregate budget below it
        return None if total is None else -(-total // n)

    # --------------------------------------------------------------- routing
    @property
    def num_shards(self) -> int:
        return len(self._shards)

    def shard_index(self, sig: Signature) -> int:
        return family_hash(sig) % len(self._shards)

    def shard_for(self, sig: Signature) -> CacheShard:
        shards = self._shards  # consistent snapshot under topology changes
        return shards[family_hash(sig) % len(shards)]

    def _shard_op(self, sig: Signature, fn):
        """Run ``fn(shard)`` under the routed shard's lock, re-validating the
        route after acquiring it: an operation that raced ``set_shards`` may
        have blocked on a shard that was retired or re-routed away from this
        family while it waited (the rebalance holds every shard lock), and
        landing there would strand the write on an unreachable shard.  The
        re-check makes routed operations linearizable with topology changes."""
        while True:
            shards = self._shards
            shard = shards[family_hash(sig) % len(shards)]
            with shard.lock:
                now = self._shards
                if now is not shards \
                        and now[family_hash(sig) % len(now)] is not shard:
                    continue  # topology changed under us: re-route
                return fn(shard)

    def shards(self) -> list[CacheShard]:
        return list(self._shards)

    def _shard_of_key(self, key: str) -> Optional[CacheShard]:
        """Locate the shard holding ``key``.  Keys are signature hashes — the
        family is not recoverable from them — so this probes each shard's
        entry dict (one O(1) membership check per shard)."""
        for shard in self._shards:
            if shard.contains(key):
                return shard
        return None

    # --------------------------------------------------------------- lookups
    def lookup(self, sig: Signature, request_origin: str = "sql") -> LookupResult:
        return self._shard_op(
            sig, lambda shard: shard.lookup(sig, request_origin))

    def lookup_batch(
        self, items: Sequence[tuple[Signature, str]]
    ) -> list[LookupResult]:
        """Scatter-gather: partition by shard, one locked batch per shard,
        gather in request order."""
        return [r[0] for r in self._scatter_gather(items, flights=False)]

    def peek_stale(self, sig: Signature):
        """Degraded-serving read on the routed shard (see
        :meth:`CacheShard.peek_stale`); None when no stale copy exists."""
        # shard.peek_stale re-acquires shard.lock (reentrant) — routed
        # through _shard_op so a racing rebalance can't strand the read
        return self._shard_op(sig, lambda shard: shard.peek_stale(sig))

    def lookup_or_flight(
        self, sig: Signature, request_origin: str = "sql"
    ) -> tuple[LookupResult, Optional[Flight], bool]:
        if not self.single_flight:
            return self.lookup(sig, request_origin), None, False
        return self._shard_op(
            sig, lambda shard: shard.lookup_or_flight(sig, request_origin))

    def lookup_or_flight_batch(
        self, items: Sequence[tuple[Signature, str]]
    ) -> list[tuple[LookupResult, Optional[Flight], bool]]:
        return self._scatter_gather(items, flights=self.single_flight)

    def _scatter_gather(
        self, items: Sequence[tuple[Signature, str]], flights: bool
    ) -> list[tuple[LookupResult, Optional[Flight], bool]]:
        """One lock acquisition per touched shard; items whose route went
        stale while waiting for a shard lock (concurrent rebalance) fall back
        to individually re-routed operations."""
        shards = self._shards
        n = len(shards)
        groups: dict[CacheShard, list[int]] = {}
        for i, (sig, _) in enumerate(items):
            groups.setdefault(shards[family_hash(sig) % n], []).append(i)
        out: list = [None] * len(items)
        stale: list[int] = []
        for shard, idxs in groups.items():
            with shard.lock:
                now = self._shards
                if now is not shards:
                    # re-validate each item's route under the new topology
                    fresh = [i for i in idxs
                             if now[family_hash(items[i][0]) % len(now)] is shard]
                    stale.extend(i for i in idxs if i not in set(fresh))
                    idxs = fresh
                for i in idxs:
                    sig, origin = items[i]
                    out[i] = (shard.lookup_or_flight(sig, origin) if flights
                              else (shard.lookup(sig, origin), None, False))
        for i in stale:
            sig, origin = items[i]
            out[i] = (self.lookup_or_flight(sig, origin) if flights
                      else (self.lookup(sig, origin), None, False))
        return out

    # ------------------------------------------------------ flight lifecycle
    def complete_flight(self, flight: Flight, table: Optional[ResultTable]) -> None:
        flight.shard.complete_flight(flight, table)

    def fail_flight(self, flight: Flight, error: BaseException) -> None:
        flight.shard.fail_flight(flight, error)

    def inflight(self) -> int:
        return sum(s.inflight() for s in self._shards)

    # -------------------------------------------------------------- mutation
    def put(self, sig: Signature, table: ResultTable, origin: str = "sql",
            snapshot_id: str = "snap0", *, cost_ms: float = 0.0,
            ttl_s: Optional[float] = None) -> str:
        return self._shard_op(
            sig, lambda shard: shard.put(sig, table, origin, snapshot_id,
                                         cost_ms=cost_ms, ttl_s=ttl_s))

    def drop(self, key: str) -> bool:
        shard = self._shard_of_key(key)
        return shard.drop(key) if shard is not None else False

    def refresh_entry(self, key: str, table: ResultTable, snapshot_id: str,
                      merged: bool = True) -> None:
        shard = self._shard_of_key(key)
        if shard is None:
            raise KeyError(f"cannot refresh unknown entry {key!r}")
        shard.refresh_entry(key, table, snapshot_id, merged)

    def ensure_loaded(self, key: str) -> Optional[CacheEntry]:
        shard = self._shard_of_key(key)
        return shard.ensure_loaded(key) if shard is not None else None

    # ------------------------------------------------------- store lifecycle
    @property
    def store(self):
        return self._store

    def attach_store(self, store, entries: Sequence[CacheEntry] = (),
                     write_through: Optional[bool] = None) -> int:
        """Attach one shared cold-tier store to every shard and route the
        replayed cold metas to their owning shards by family hash (the same
        deterministic modulus as live traffic, so warm-restarted entries land
        exactly where lookups will probe for them)."""
        adopted = 0
        with self._topology_lock:
            self._store = store
            if write_through is not None:
                self.write_through = write_through
            shards = self._shards
            n = len(shards)
            groups: dict[int, list[CacheEntry]] = {i: [] for i in range(n)}
            for e in entries:
                groups[family_hash(e.signature) % n].append(e)
            for i, shard in enumerate(shards):
                with shard.lock:
                    adopted += shard.cache.attach_store(
                        store, groups[i], write_through=write_through)
        return adopted

    def set_audit(self, audit, **labels) -> None:
        """Attach the obs plane's lifecycle audit log to every shard cache,
        each labelled with its shard index (plus the caller's labels, e.g.
        ``tenant=``).  Survives resharding: ``set_shards`` re-applies it."""
        with self._topology_lock:
            self._audit = audit
            self._audit_labels = dict(labels)
            for shard in self._shards:
                with shard.lock:
                    shard.cache.set_audit(audit, shard=shard.index, **labels)

    def detach_store(self) -> None:
        with self._topology_lock:
            self._store = None
            for shard in self._shards:
                with shard.lock:
                    shard.cache.detach_store()

    def persist_hot(self) -> int:
        n = 0
        for shard in self._shards:
            with shard.lock:
                n += shard.cache.persist_hot()
        return n

    def tier_stats(self) -> dict:
        """Aggregated per-tier gauges/counters; the shared store's own stats
        are reported once (every shard sees the same engine)."""
        agg = {"hot_entries": 0, "cold_entries": 0, "hot_bytes": 0,
               "cold_bytes": 0, "promotions": 0, "demotions": 0,
               "cold_drops": 0, "ttl_expiries": 0, "policy": None,
               "store": None}
        for shard in self._shards:
            ts = shard.tier_stats()
            for k in ("hot_entries", "cold_entries", "hot_bytes", "cold_bytes",
                      "promotions", "demotions", "cold_drops", "ttl_expiries"):
                agg[k] += ts[k]
            agg["policy"] = ts["policy"]
        if self._store is not None:
            agg["store"] = self._store.stats()
        return agg

    def entries_summary(self, limit: int = 256) -> list[dict]:
        out: list[dict] = []
        for shard in self._shards:
            if len(out) >= limit:
                break
            out.extend(shard.entries_summary(limit - len(out)))
        return out

    # ------------------------------------------------------------- broadcast
    def affected_keys(self, updated_start: Optional[str] = None,
                      updated_end: Optional[str] = None) -> list[str]:
        out: list[str] = []
        for shard in self._shards:
            out.extend(shard.affected_keys(updated_start, updated_end))
        return out

    def invalidate_snapshot(self, updated_start: Optional[str] = None,
                            updated_end: Optional[str] = None) -> int:
        return sum(s.invalidate_snapshot(updated_start, updated_end)
                   for s in self._shards)

    def invalidate_schema_change(self) -> int:
        return sum(s.invalidate_schema_change() for s in self._shards)

    # ------------------------------------------------------------- topology
    def add_shard(self) -> int:
        """Grow the cluster by one shard; entries re-route deterministically
        under the new modulus.  Returns the new shard count."""
        return self.set_shards(len(self._shards) + 1)

    def remove_shard(self) -> int:
        """Shrink the cluster by one shard; the removed shard's entries
        migrate to the survivors and its counters fold into the aggregate
        stats.  Returns the new shard count."""
        if len(self._shards) == 1:
            raise ValueError("cannot remove the last shard")
        return self.set_shards(len(self._shards) - 1)

    def set_shards(self, n: int) -> int:
        """Rebalance to ``n`` shards (stop-the-world: holds every shard lock).

        Migration is deterministic: each entry's new shard is the family hash
        under the new modulus, and each rebuilt shard reconstructs LRU order
        from the entries' global ``lru_stamp`` and derivation-probe MRU order
        from ``store_stamp`` — so entries that stay put keep their exact
        order, and movers interleave by true recency.  Capacity budgets are
        re-split; shrink-induced overflow evicts LRU as usual."""
        if n < 1:
            raise ValueError(f"cluster needs >= 1 shard, got {n}")
        with self._topology_lock:
            old = self._shards
            for shard in old:
                shard.lock.acquire()
            try:
                entries: list[CacheEntry] = []
                for shard in old:
                    entries.extend(shard.cache.export_entries())
                new = old[:n] + [CacheShard(i, self._new_cache(n))
                                 for i in range(len(old), n)]
                for shard in old[n:]:  # fold removed shards' counters
                    folded = dataclasses.replace(shard.cache.stats)
                    # bytes_cached/bytes_cold are gauges, not counters: the
                    # removed shard's entries migrate to survivors, whose own
                    # gauges will account for them
                    folded.bytes_cached = 0
                    folded.bytes_cold = 0
                    self._retired_stats = _sum_stats(
                        [self._retired_stats, folded])
                assign: dict[int, list[CacheEntry]] = {i: [] for i in range(n)}
                for e in entries:
                    assign[family_hash(e.signature) % n].append(e)
                for i, shard in enumerate(new):
                    shard.index = i
                    shard.cache.capacity = self._split(self.capacity, n)
                    shard.cache.capacity_bytes = self._split(
                        self.capacity_bytes, n)
                    if self._audit is not None:
                        # relabel before rebuild so shrink-induced evictions
                        # are audited under the shard's new index
                        shard.cache.set_audit(self._audit, shard=i,
                                              **self._audit_labels)
                    shard.cache.rebuild(assign[i])
                self._shards = new
            finally:
                for shard in old:
                    shard.lock.release()
        return n

    # ---------------------------------------------------------------- stats
    @property
    def stats(self) -> CacheStats:
        """Aggregated counters: the sum over live shards plus the folded
        counters of removed shards (so totals never go backwards)."""
        return _sum_stats([self._retired_stats]
                          + [s.cache.stats for s in self._shards])

    def stats_by_shard(self) -> list[dict]:
        out = []
        for shard in self._shards:
            with shard.lock:
                d = shard.cache.stats.to_dict()
                d["shard"] = shard.index
                d["entries"] = len(shard.cache)
                d["inflight"] = len(shard._inflight)
            out.append(d)
        return out

    def describe(self) -> dict:
        return {
            "shards": len(self._shards),
            "routing": "family:(scope,schema,measure_key)",
            "single_flight": self.single_flight,
            "concurrent_misses": self.concurrent_misses,
            "capacity": self.capacity,
            "capacity_bytes": self.capacity_bytes,
        }

    # -------------------------------------------------------- introspection
    def entry(self, key: str) -> Optional[CacheEntry]:
        shard = self._shard_of_key(key)
        return shard.entry(key) if shard is not None else None

    def keys(self) -> list[str]:
        out: list[str] = []
        for shard in self._shards:
            out.extend(shard.keys())
        return out

    def __len__(self) -> int:
        return sum(len(s) for s in self._shards)

    def total_bytes(self) -> int:
        return sum(s.total_bytes() for s in self._shards)
