"""Sharded concurrent cache cluster.

Partitions the signature key space across N locked :class:`CacheShard` s by
derivation-family key ``(scope, schema, measure_key)`` — keeping roll-up /
filter-down candidates shard-local — with single-flight miss deduplication
and a scatter-gather router exposing the full ``SemanticCache`` surface.
``CacheCluster(shards=1)`` is a differential oracle for the unsharded path.
"""

from .cluster import CacheCluster, family_hash, family_key
from .flight import DEFAULT_FLIGHT_TIMEOUT_S, Flight
from .shard import CacheShard

__all__ = [
    "CacheCluster", "CacheShard", "DEFAULT_FLIGHT_TIMEOUT_S", "Flight",
    "family_hash", "family_key",
]
