"""Single-flight miss deduplication.

When several threads miss on the *same* cold signature concurrently, only
the first (the **leader**) executes the backend; the rest (**followers**)
block on the leader's :class:`Flight` and receive the identical result table
— one scan instead of K racing scans for a popular cold dashboard tile.

A flight is registered under the owning shard's lock at lookup time (the
miss check and the registration are one atomic step, so two threads can
never both become leader), and resolved outside any lock: the leader calls
``complete``/``fail`` through the shard after executing, and followers
``wait`` with a timeout and fall back to executing themselves if the leader
died or aborted — dedup is an optimization, never a correctness dependency.
"""
from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Optional

from ..analysis.sanitizer import note_blocking

if TYPE_CHECKING:  # pragma: no cover
    from ..core.table import ResultTable
    from .shard import CacheShard

DEFAULT_FLIGHT_TIMEOUT_S = 30.0


class Flight:
    """One in-flight miss computation, shared by a leader and its followers."""

    __slots__ = ("key", "shard", "table", "error", "obs_ctx", "_event")

    def __init__(self, key: str, shard: "CacheShard"):
        self.key = key
        self.shard = shard
        # resolved exactly once through the owning shard's complete_flight /
        # fail_flight, which hold the shard lock; read by followers only
        # after the event is set (publication happens-before the wait)
        self.table: Optional["ResultTable"] = None  # guarded-by: self.shard.lock
        self.error: Optional[BaseException] = None  # guarded-by: self.shard.lock
        # the sampled leader's trace context (Trace, span_id): written only
        # by the leader before it resolves the flight, read by followers
        # after wait() returns — the event publication orders the accesses
        self.obs_ctx: Optional[tuple] = None  # guarded-by: external[leader-writes-before-event, followers read after wait()]
        self._event = threading.Event()

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def ok(self) -> bool:
        return self._event.is_set() and self.error is None

    def wait(self, timeout: Optional[float] = DEFAULT_FLIGHT_TIMEOUT_S) -> bool:
        """Block until the leader resolves the flight; False on timeout.

        A follower must never wait while holding a lock the leader needs to
        resolve the flight (the leader stores + completes under the shard
        lock) — the sanitizer's blocking-call check enforces that."""
        note_blocking("Flight.wait")
        return self._event.wait(timeout)

    # resolution happens through the owning shard (shard.complete_flight /
    # shard.fail_flight) so deregistration and result publication stay under
    # one lock; these setters are the shard-internal halves.
    def _resolve(self, table: Optional["ResultTable"],
                 error: Optional[BaseException]) -> None:  # requires-lock: self.shard.lock
        self.table = table
        self.error = error
        self._event.set()
