"""``python -m repro.obs`` — query traces and the cache audit log offline.

Three subcommands over the JSONL sinks the plane writes:

* ``summarize <trace.jsonl>`` — per-trace span trees (wall times, durations,
  attributes), optionally filtered to one trace id;
* ``explain <audit.jsonl> --key <sig>`` — the lifecycle narrative of one
  cache entry: every event it went through, with the policy inputs
  (decayed hits, cost, bytes, benefit score) that drove each decision, and
  a one-line verdict on why it ultimately left the cache (if it did);
* ``false-hits <audit.jsonl>`` — liveness audit: replay the log and report
  any ``hit``/``derivation_hit`` served from a key that was not live in a
  servable tier at serve time (morgue/stale serves are degraded-mode by
  design and excluded).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Iterable, Optional


def _read_jsonl(path: str) -> list[dict]:
    out = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------- summarize


def _span_tree_lines(spans: list[dict]) -> list[str]:
    by_id = {s["span"]: s for s in spans}
    children: dict = {}
    roots = []
    for s in spans:
        p = s.get("parent")
        if p and p in by_id:
            children.setdefault(p, []).append(s)
        else:
            roots.append(s)
    for kids in children.values():
        kids.sort(key=lambda s: s.get("start_s", 0.0))
    roots.sort(key=lambda s: s.get("start_s", 0.0))
    lines: list[str] = []

    def walk(s: dict, depth: int) -> None:
        attrs = s.get("attrs") or {}
        extra = ""
        if attrs:
            kv = ", ".join(f"{k}={attrs[k]}" for k in sorted(attrs))
            extra = f"  [{kv}]"
        lines.append(f"{'  ' * depth}{s['name']}  "
                     f"{s.get('dur_ms', 0.0):.3f}ms{extra}")
        for kid in children.get(s["span"], []):
            walk(kid, depth + 1)

    for r in roots:
        walk(r, 0)
    return lines


def cmd_summarize(args) -> int:
    spans = _read_jsonl(args.path)
    by_trace: dict[str, list[dict]] = {}
    for s in spans:
        by_trace.setdefault(s["trace"], []).append(s)
    ids = [args.trace] if args.trace else list(by_trace)
    if args.trace and args.trace not in by_trace:
        print(f"trace {args.trace} not found "
              f"({len(by_trace)} traces in {args.path})", file=sys.stderr)
        return 1
    for tid in ids:
        tspans = by_trace[tid]
        total = sum(s.get("dur_ms", 0.0) for s in tspans
                    if not s.get("parent"))
        print(f"trace {tid}: {len(tspans)} spans, "
              f"root {total:.3f}ms")
        for line in _span_tree_lines(tspans):
            print(f"  {line}")
    print(f"{len(ids)} trace(s), {len(spans)} span(s) total")
    return 0


# ------------------------------------------------------------------ explain

# events after which the key can still serve from a live tier
_KEEPS_LIVE = {"put", "hit", "derivation_hit", "refresh", "promote",
               "demote", "stale_serve"}
# events after which it cannot (evict is live-leaving only when its
# disposition says dropped; demotions stay servable from the cold tier)
_ENDS_LIVE = {"drop", "ttl_expiry"}


def _leaves_cache(e: dict) -> bool:
    if e["event"] in _ENDS_LIVE:
        return True
    if e["event"] == "evict":
        return e.get("disposition", "drop") == "drop"
    return False


def _policy_bits(e: dict) -> str:
    keys = ("tier", "hits", "decayed_hits", "cost_ms", "nbytes", "score",
            "age_s", "idle_s", "ttl_s", "reason", "disposition", "policy",
            "origin", "snapshot", "src_key", "derivation")
    kv = [f"{k}={e[k]}" for k in keys if k in e and e[k] is not None]
    return ", ".join(kv)


def cmd_explain(args) -> int:
    events = [e for e in _read_jsonl(args.path) if e["key"] == args.key]
    if not events:
        print(f"no audit events for key {args.key!r} in {args.path}",
              file=sys.stderr)
        return 1
    t0 = events[0]["ts"]
    for e in events:
        bits = _policy_bits(e)
        print(f"+{e['ts'] - t0:9.3f}s  {e['event']:<15}"
              f"{('  ' + bits) if bits else ''}")
    live = False
    last_exit = None
    for e in events:  # replay in order: the log is append-ordered
        if _leaves_cache(e):
            live = False
            last_exit = e
        elif e["event"] in ("put", "refresh", "promote", "demote"):
            live = True
    if last_exit is None:
        print(f"verdict: {args.key} never left the cache "
              f"({len(events)} events)")
    else:
        why = last_exit.get("reason") or last_exit["event"]
        bits = _policy_bits(last_exit)
        print(f"verdict: left the cache via {last_exit['event']} ({why})"
              + (f" — {bits}" if bits else ""))
        if live:
            print("         (re-admitted afterwards; currently live)")
    return 0


# --------------------------------------------------------------- false-hits


def cmd_false_hits(args) -> int:
    events = _read_jsonl(args.path)
    live: set = set()
    false_hits: list[dict] = []
    hits = 0
    for e in events:
        kind, key = e["event"], e["key"]
        if kind in ("hit", "derivation_hit"):
            hits += 1
            src = e.get("src_key", key) if kind == "derivation_hit" else key
            if src not in live:
                false_hits.append(e)
        elif kind in ("put", "refresh", "promote", "demote"):
            live.add(key)
        elif _leaves_cache(e):
            live.discard(key)
    for e in false_hits:
        print(f"FALSE HIT  ts={e['ts']:.3f}  {e['event']}  key={e['key']}"
              f"  {_policy_bits(e)}")
    print(f"{hits} hit(s) audited, {len(false_hits)} false, "
          f"{len(live)} key(s) live at end of log")
    return 0 if not false_hits else 2


# --------------------------------------------------------------------- main


def main(argv: Optional[Iterable[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Query observability sinks: trace summaries, "
                    "eviction explanations, false-hit audit.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="print span trees from a trace "
                                         "JSONL sink")
    p.add_argument("path", help="trace JSONL file")
    p.add_argument("--trace", default=None, help="only this trace id")
    p.set_defaults(fn=cmd_summarize)

    p = sub.add_parser("explain", help="narrate one key's cache lifecycle "
                                       "from an audit JSONL sink")
    p.add_argument("path", help="audit JSONL file")
    p.add_argument("--key", required=True, help="signature key to explain")
    p.set_defaults(fn=cmd_explain)

    p = sub.add_parser("false-hits", help="audit that every hit was served "
                                          "from a live key")
    p.add_argument("path", help="audit JSONL file")
    p.set_defaults(fn=cmd_false_hits)

    args = ap.parse_args(list(argv) if argv is not None else None)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
