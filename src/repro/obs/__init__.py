"""Unified observability plane: tracing, metrics, and the cache audit log.

Three substrates behind one config (:class:`ObsConfig`) and one holder
(:class:`ObsPlane`, owned by ``CacheService`` and shared by its tenants):

* :mod:`.trace` — per-request traces of nested spans with head-based
  sampling, a bounded span ring, an optional JSONL sink, and explicit
  cross-thread context propagation (shard-miss pool, scan-plane partition
  pool, single-flight leader→follower links, the storage spill worker);
* :mod:`.metrics` — typed Counter/Gauge/Histogram instruments with label
  sets and Prometheus-text / JSON exposition (``CacheService.metrics()``);
  the log-bucketed :class:`~.metrics.LogHistogram` also backs
  ``TenantStats.stage_percentiles`` directly;
* :mod:`.audit` — structured cache-lifecycle events (put / hit /
  derivation-hit / evict / demote / promote / refresh / TTL-expiry /
  morgue-serve) with policy inputs, queryable via ``python -m repro.obs``.

Everything is off the hot path when disabled: an unsampled request pays one
``is None`` check per stage, an un-audited cache one attribute load per
lifecycle call site, and metrics are mirrored from the existing counters at
exposition time rather than double-bumped per request.

Future serving-plane endpoints (the async front door on the ROADMAP) must
export through this registry and propagate trace context through these
helpers rather than growing new ad-hoc counters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from .audit import DEFAULT_CAPACITY as DEFAULT_AUDIT_CAPACITY
from .audit import EVENTS, AuditLog
from .metrics import (BUCKET_BOUNDS, Counter, Gauge, Histogram, LogHistogram,
                      MetricsRegistry)
from .trace import (DEFAULT_RING_CAPACITY, DEFAULT_SAMPLE_RATE, Trace,
                    Tracer, adopt, child_span, current_ctx, span_ctx)

__all__ = [
    "AuditLog", "BUCKET_BOUNDS", "Counter", "EVENTS", "Gauge", "Histogram",
    "LogHistogram", "MetricsRegistry", "ObsConfig", "ObsPlane",
    "PIPELINE_STAGES", "Trace", "Tracer", "adopt", "child_span",
    "current_ctx", "required_stages", "span_ctx", "trace_completeness",
]

# mirrors pipeline.STAGES (not imported: obs must stay import-light and
# dependency-free so every layer can use it); the pipeline's test suite
# pins the two tuples equal
PIPELINE_STAGES = ("canonicalize", "validate", "gate", "lookup", "plan",
                   "execute", "store")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """One knob bundle for the whole plane.

    The default is *metrics-only*: exposition works (it mirrors existing
    counters on demand) but no request is traced and no audit event is
    emitted — the zero-overhead production baseline.  ``tracing=True``
    samples ``sample_rate`` of requests head-based (the decision is made
    once, before any span exists); ``audit=True`` turns on lifecycle
    events.  The sinks are append-only JSONL paths, ``None`` = in-memory
    ring only."""

    metrics: bool = True
    tracing: bool = False
    sample_rate: float = DEFAULT_SAMPLE_RATE
    ring_capacity: int = DEFAULT_RING_CAPACITY
    trace_sink: Optional[str] = None
    audit: bool = False
    audit_capacity: int = DEFAULT_AUDIT_CAPACITY
    audit_sink: Optional[str] = None

    @classmethod
    def disabled(cls) -> "ObsConfig":
        """Everything off — the bench's control arm."""
        return cls(metrics=False)

    @classmethod
    def full(cls, sample_rate: float = DEFAULT_SAMPLE_RATE,
             **kw) -> "ObsConfig":
        """Metrics + tracing + audit, at the given sample rate."""
        return cls(metrics=True, tracing=True, audit=True,
                   sample_rate=sample_rate, **kw)


class ObsPlane:
    """The service-level holder: one tracer + one registry + one audit log
    shared by every tenant of a :class:`~repro.service.CacheService`."""

    def __init__(self, config: Optional[ObsConfig] = None):
        if config is None:
            config = ObsConfig()
        self.config = config
        self.tracer = Tracer(enabled=config.tracing,
                             sample_rate=config.sample_rate,
                             ring_capacity=config.ring_capacity,
                             sink_path=config.trace_sink)
        self.registry = MetricsRegistry()
        self.audit: Optional[AuditLog] = (
            AuditLog(config.audit_capacity, config.audit_sink)
            if config.audit else None)

    def stats(self) -> dict:
        d = {"config": dataclasses.asdict(self.config),
             "tracer": self.tracer.stats()}
        if self.audit is not None:
            d["audit"] = self.audit.stats()
        return d

    def close(self) -> None:
        self.tracer.close()
        if self.audit is not None:
            self.audit.close()


# A single always-disabled plane shared by tenants whose service predates
# observability configuration (or standalone pipeline tests): every check
# against it short-circuits.
DISABLED_PLANE = ObsPlane(ObsConfig.disabled())


# ------------------------------------------------------ completeness check


def required_stages(provenance: Sequence[str]) -> set:
    """The pipeline stages a result's provenance proves it passed through —
    each must have a matching span in the result's trace."""
    req = set()
    for tok in provenance:
        stage = tok.split(":", 1)[0]
        if stage in PIPELINE_STAGES:
            req.add(stage)
    return req


def trace_completeness(results, tracer: Tracer) -> dict:
    """Audit that every stage named in each traced result's ``provenance``
    has a matching span: the bench's zero-missing-spans criterion, checked
    under both clean and chaos runs.  Results without a ``trace_id``
    (unsampled) are skipped."""
    by_trace: dict[str, set] = {}
    for s in tracer.spans():
        by_trace.setdefault(s["trace"], set()).add(s["name"])
    checked = 0
    missing: list[dict] = []
    for r in results:
        tid = getattr(r, "trace_id", None)
        if tid is None:
            continue
        checked += 1
        names = by_trace.get(tid, set())
        for stage in sorted(required_stages(r.provenance)):
            if stage not in names:
                missing.append({"trace": tid, "stage": stage,
                                "provenance": list(r.provenance),
                                "spans": sorted(names)})
    return {"traces_checked": checked, "missing": missing,
            "missing_count": len(missing), "ok": not missing}
