"""Cache-lifecycle audit log: structured events for every entry decision.

``SemanticCache`` (and the storage-backed tiering inside it) emits one
:class:`AuditLog` event per lifecycle decision — ``put`` / ``hit`` /
``derivation_hit`` / ``evict`` / ``demote`` / ``promote`` / ``refresh`` /
``ttl_expiry`` / ``morgue_serve`` (plus ``stale_serve`` for degraded reads
out of a live tier, and ``drop`` for explicit invalidation) — carrying the
signature key, the tier it happened on, the *policy inputs* that drove it
(decayed hits, recompute cost, bytes, benefit score for evictions and
demotions), and provenance (origin surface, snapshot id).  Together with
request traces this makes the paper's headline claims auditable after the
fact: why an entry was evicted, which cached entry served a derivation hit,
and whether any hit was served from a key that was not live at serve time
(the false-hit audit) are all answerable from the log alone — see
``python -m repro.obs``.

The emitter is deliberately dumb and cheap: a dict append into a bounded
ring, plus an optional JSONL sink.  The cache holds ``audit=None`` by
default, so the disabled hot path pays a single attribute load per call
site.  With no sink attached (the default), the append path is lock-free:
a ``deque.append`` and a ``deque`` snapshot via ``list()`` are both single
C-level operations that never run Python code mid-step, so they are atomic
under the GIL, and the event counter is an ``itertools.count`` (``next()``
is likewise GIL-atomic).  ``hit`` events ride the warm-lookup path, where a
lock round-trip per request is a measurable share of total latency.

Locking: ``AuditLog._lock`` only serializes the optional JSONL sink (and
is a leaf — events are emitted under ``CacheShard.lock`` on the cluster
request path, and nothing is acquired while holding it).
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque
from typing import Optional

from ..analysis.sanitizer import make_lock

__all__ = ["AuditLog", "EVENTS"]

EVENTS = ("put", "hit", "derivation_hit", "evict", "demote", "promote",
          "refresh", "ttl_expiry", "morgue_serve", "stale_serve", "drop")

DEFAULT_CAPACITY = 4096


class AuditLog:
    """Bounded in-memory ring of lifecycle events + optional JSONL sink."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 sink_path: Optional[str] = None):
        self._lock = make_lock("AuditLog._lock")
        # bounded-deque append and list() snapshot are single C-level ops;
        # no invariant spans entries
        self._ring: deque = deque(
            maxlen=capacity)  # guarded-by: external[GIL-atomic deque ops]
        # events ever emitted; next() is GIL-atomic, peeked for stats
        self._emitted = itertools.count()
        self._sink = open(sink_path, "a", encoding="utf-8") \
            if sink_path else None  # guarded-by: self._lock
        self.sink_path = sink_path

    def emit(self, event: str, key: str, **fields) -> None:
        rec = {"ts": time.time(), "event": event, "key": key}
        rec.update(fields)
        self.append(rec)

    def append(self, rec: dict) -> None:
        """Record one pre-built event dict (must carry ``ts``/``event``/
        ``key``).  The hot ``hit`` path builds its record in place and calls
        this directly — with no sink attached this is lock-free (see module
        docstring)."""
        self._ring.append(rec)
        next(self._emitted)
        if self._sink is not None:
            with self._lock:
                self._sink.write(json.dumps(rec, default=str) + "\n")

    # ------------------------------------------------------------- reads
    def events(self, key: Optional[str] = None,
               event: Optional[str] = None) -> list[dict]:
        """Snapshot (oldest first), optionally filtered by key and/or
        event kind."""
        out = list(self._ring)  # atomic under the GIL (see __init__)
        if key is not None:
            out = [e for e in out if e["key"] == key]
        if event is not None:
            out = [e for e in out if e["event"] == event]
        return out

    def counts(self) -> dict:
        out: dict[str, int] = {}
        for e in self.events():
            out[e["event"]] = out.get(e["event"], 0) + 1
        return out

    def stats(self) -> dict:
        # peek the count without consuming (it pickles as count(current))
        emitted = self._emitted.__reduce__()[1][0]
        return {"emitted": emitted, "ring_len": len(self._ring),
                "sink": self.sink_path}

    def flush(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.flush()

    def close(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.flush()
                self._sink.close()
                self._sink = None
