"""Request tracing: per-request traces of nested spans.

A :class:`Tracer` makes the head-based sampling decision once per request
(``start_trace`` returns a :class:`Trace` handle, or ``None`` when the
request is unsampled / tracing is disabled — the whole request then pays a
single ``is None`` check per stage).  Sampled requests carry the handle on
their pipeline state; every span of the request records through it into one
process-wide bounded ring buffer (plus an optional JSONL sink), so traces
survive the request and late spans — the storage spill worker finishing a
write-behind job after the response went out — still land under their
originating trace id.

Two recording styles, matching how the pipeline is instrumented:

* ``trace.record(name, ...)`` — after-the-fact span from a measured
  duration (the per-stage spans are emitted at finalize time from the same
  ``perf_counter`` timings the pipeline already keeps, so tracing adds no
  second clock read per stage);
* ``span_ctx(trace, name, ...)`` — a *live* span context manager that also
  publishes itself as the calling thread's current span context, which is
  how cross-thread propagation works: the scan plane's partition pool, the
  shard-miss pool, and the spill worker each *adopt* the context captured
  at submit time and hang their child spans under it.

Context propagation is explicit-capture + thread-local-adopt:
``current_ctx()`` reads the calling thread's ``(trace, span_id)`` pair,
``adopt(ctx)`` installs one for a worker's body, and ``child_span(name)``
opens a live span under whatever context is installed (a no-op when none
is — disabled tracing costs one thread-local read at each fan-out point,
nothing on the warm-hit path).

Locking: ``Tracer._lock`` is a leaf — emission happens under shard locks
and inside pool threads, and nothing else is ever acquired while holding
it.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional

from ..analysis.sanitizer import make_lock

__all__ = [
    "DEFAULT_SAMPLE_RATE", "Trace", "Tracer", "adopt", "child_span",
    "current_ctx", "span_ctx",
]

DEFAULT_SAMPLE_RATE = 0.01  # head-based: 1 in 100 requests fully traced
DEFAULT_RING_CAPACITY = 4096  # spans retained in memory

# process-wide id source: next() on itertools.count is GIL-atomic, so ids
# are unique across tracers and threads without a lock
_ids = itertools.count(1)
# per-thread current span context: (Trace, span_id) or unset
_tls = threading.local()


def _new_id(prefix: str) -> str:
    return f"{prefix}{next(_ids):010x}"


class Trace:
    """One sampled request's trace handle.

    Thread-safe: followers, pool workers, and the spill worker record spans
    into the leader's trace concurrently (each ``record`` is one append to
    the tracer's lock-guarded ring)."""

    __slots__ = ("tracer", "trace_id", "root_id")

    def __init__(self, tracer: "Tracer", trace_id: str, root_id: str):
        self.tracer = tracer
        self.trace_id = trace_id
        # the root span id is allocated up front so children created *before*
        # the root span is recorded (it lands at finalize) can parent on it
        self.root_id = root_id

    def new_span_id(self) -> str:
        return _new_id("s")

    def record(self, name: str, *, span_id: Optional[str] = None,
               parent_id: Optional[str] = None,
               start_s: Optional[float] = None, dur_ms: float = 0.0,
               attrs: Optional[dict] = None) -> str:
        """Emit one finished span; returns its id."""
        sid = span_id if span_id is not None else self.new_span_id()
        self.tracer.emit({
            "trace": self.trace_id,
            "span": sid,
            "parent": parent_id,
            "name": name,
            "start_s": time.time() if start_s is None else start_s,
            "dur_ms": float(dur_ms),
            "attrs": dict(attrs) if attrs else {},
        })
        return sid

    def ctx(self) -> tuple:
        """The root-span context pair, for ``adopt``/span parenting."""
        return (self, self.root_id)


class Tracer:
    """Sampling decision + the bounded span ring + the optional JSONL sink."""

    def __init__(self, enabled: bool = False,
                 sample_rate: float = DEFAULT_SAMPLE_RATE,
                 ring_capacity: int = DEFAULT_RING_CAPACITY,
                 sink_path: Optional[str] = None):
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self._lock = make_lock("Tracer._lock")
        self._ring: deque = deque(maxlen=ring_capacity)  # guarded-by: self._lock
        self.emitted = 0  # spans ever emitted  # guarded-by: self._lock
        self.sampled = 0  # traces started  # guarded-by: self._lock
        # head sampling as a countdown: one trace per `period` requests
        # (period = round(1/rate); 0 = never).  The pipeline decrements
        # `countdown` inline — per *unsampled* request the whole decision is
        # one integer subtract + compare, the cheapest per-request hook the
        # interpreter allows (even an empty method call measures ~1us in
        # situ on the warm-hit path).  Unlocked by design: a lost decrement
        # under concurrent batches only stretches one sampling interval;
        # stats derive `seen` from (sampled, period, countdown).
        if self.enabled and self.sample_rate > 0.0:
            self.period = (1 if self.sample_rate >= 1.0
                           else max(1, round(1.0 / self.sample_rate)))
        else:
            self.period = 0
        self.countdown = (
            self.period)  # guarded-by: external[benign sampling jitter]
        self._sink = open(sink_path, "a", encoding="utf-8") \
            if sink_path else None  # guarded-by: self._lock
        self.sink_path = sink_path

    # ---------------------------------------------------------- sampling
    def start_trace(self) -> Optional[Trace]:
        """Head-based sampling: the keep/drop decision is made once, here,
        before any span exists.  Returns ``None`` for unsampled requests.
        Deterministic pacing, no RNG: exactly one request per ``period``
        is sampled.

        The batch pipeline inlines this exact countdown (see
        ``run_pipeline``) and only calls :meth:`make_trace` on the sampled
        path; this method is the one-stop form for everything off the warm
        path."""
        if not self.enabled or not self.period:
            return None
        c = self.countdown = self.countdown - 1
        if c > 0:
            return None
        self.countdown = c + self.period
        return self.make_trace()

    def make_trace(self) -> Trace:
        """Allocate a sampled trace handle (the keep decision was already
        made by the caller)."""
        with self._lock:
            self.sampled += 1
        return Trace(self, _new_id("t"), _new_id("s"))

    # ---------------------------------------------------------- emission
    def emit(self, span: dict) -> None:
        line = None if self._sink is None else json.dumps(span, default=str)
        with self._lock:
            self._ring.append(span)
            self.emitted += 1
            if self._sink is not None:
                self._sink.write(line + "\n")

    # ------------------------------------------------------------- reads
    def spans(self, trace_id: Optional[str] = None) -> list[dict]:
        """Snapshot of the retained spans (oldest first), optionally
        filtered to one trace."""
        with self._lock:
            out = list(self._ring)
        if trace_id is not None:
            out = [s for s in out if s["trace"] == trace_id]
        return out

    def trace_ids(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self.spans():
            seen.setdefault(s["trace"])
        return list(seen)

    def stats(self) -> dict:
        with self._lock:
            return {
                "enabled": self.enabled,
                "sample_rate": self.sample_rate,
                "seen": (self.sampled * self.period
                         + (self.period - self.countdown)
                         if self.period else 0),
                "sampled": self.sampled,
                "spans_emitted": self.emitted,
                "ring_len": len(self._ring),
                "sink": self.sink_path,
            }

    def flush(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.flush()

    def close(self) -> None:
        with self._lock:
            if self._sink is not None:
                self._sink.flush()
                self._sink.close()
                self._sink = None


# ------------------------------------------------- cross-thread propagation


def current_ctx() -> Optional[tuple]:
    """The calling thread's current span context ``(Trace, span_id)``, or
    ``None`` — captured at fan-out points and handed to worker threads."""
    return getattr(_tls, "ctx", None)


@contextmanager
def adopt(ctx: Optional[tuple]):
    """Install a captured span context as this thread's current one for the
    body (pool workers adopting their submitter's context).  ``adopt(None)``
    is a no-op shell."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ctx
    try:
        yield
    finally:
        _tls.ctx = prev


@contextmanager
def span_ctx(trace: Optional[Trace], name: str,
             parent_id: Optional[str] = None,
             attrs: Optional[dict] = None):
    """A live span: yields its span id, publishes itself as the thread's
    current context for the body, and records with the measured duration at
    exit.  ``attrs`` is read at exit, so the body may add outcome fields to
    the dict it passed in.  No-op (yields ``None``) when ``trace`` is."""
    if trace is None:
        yield None
        return
    sid = trace.new_span_id()
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = (trace, sid)
    w0 = time.time()
    t0 = time.perf_counter()
    try:
        yield sid
    finally:
        _tls.ctx = prev
        trace.record(name, span_id=sid, parent_id=parent_id, start_s=w0,
                     dur_ms=(time.perf_counter() - t0) * 1e3, attrs=attrs)


@contextmanager
def child_span(name: str, attrs: Optional[dict] = None):
    """A live span under the thread's current context (no-op without one) —
    the one-liner for instrumenting worker bodies."""
    ctx = getattr(_tls, "ctx", None)
    if ctx is None:
        yield None
        return
    with span_ctx(ctx[0], name, parent_id=ctx[1], attrs=attrs) as sid:
        yield sid
