"""Typed metric instruments and the exposition registry.

Three instrument kinds — :class:`Counter` (monotone), :class:`Gauge`
(set-to-value), :class:`Histogram` (log2-bucketed distribution) — each with
a declared label set (``tenant``, ``stage``, ...), registered by name in a
:class:`MetricsRegistry` that renders the whole collection as
Prometheus-text or JSON (``CacheService.metrics()``).

The histogram is the piece the hot path touches: :class:`LogHistogram`
replaces the old ``STAGE_SAMPLE_WINDOW`` deques behind
``TenantStats.stage_percentiles`` — an ``observe`` is one ``frexp`` plus a
list-slot increment (cheaper than a bounded-deque append, and it never
forgets old samples), and quantiles come from bucket interpolation with a
*proper rank* (``q * (n - 1)``), which also fixes the old ``int(len*0.95)``
index bias on small windows.  Buckets are powers of two from 1µs to ~5min
(in ms), so p50/p95 are exact to within one octave across the whole range
the pipeline produces.

Locking: instruments share their registry's single leaf lock (one lock
acquisition per update, none held while rendering a sample's text).
``LogHistogram`` itself is lock-free and caller-locked — ``TenantStats``
updates it under its own ``_lock``, exactly as it did the deques.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

from ..analysis.sanitizer import make_lock

__all__ = ["LogHistogram", "Counter", "Gauge", "Histogram",
           "MetricsRegistry"]

# log2 bucket edges in milliseconds: 2^-10 (~1us) .. 2^18 (~4.4min); values
# above the last edge land in the +Inf overflow bucket
_MIN_EXP, _MAX_EXP = -10, 18
BUCKET_BOUNDS: tuple[float, ...] = tuple(
    2.0 ** e for e in range(_MIN_EXP, _MAX_EXP + 1))
_N_BUCKETS = len(BUCKET_BOUNDS) + 1  # + overflow


def _bucket_index(v: float) -> int:
    """Index of the first bucket whose upper edge is >= v (frexp, not a
    bisect: constant time, no per-observe allocation)."""
    if v <= BUCKET_BOUNDS[0]:
        return 0
    # frexp(v) = (m, e) with v = m * 2**e, 0.5 <= m < 1  =>  2**(e-1) < v <= 2**e
    # (for m == 0.5 exactly, v == 2**(e-1): one octave lower)
    m, e = math.frexp(v)
    if m == 0.5:
        e -= 1
    i = e - _MIN_EXP
    return i if i < _N_BUCKETS else _N_BUCKETS - 1


class LogHistogram:
    """Fixed log2-bucketed histogram: O(1) observe, rank-based quantiles.

    Not self-locking — the owner serializes access (``TenantStats._lock``,
    ``MetricsRegistry._lock``)."""

    __slots__ = ("counts", "count", "total")

    def __init__(self):
        self.counts = [0] * _N_BUCKETS
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.counts[_bucket_index(v)] += 1
        self.count += 1
        self.total += v

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` via the proper rank ``q * (n - 1)``
        (zero-indexed), linearly interpolated inside the owning bucket.
        Unlike the old ``int(len * 0.95)`` index this can never overshoot
        past the maximum rank on small sample counts."""
        n = self.count
        if n == 0:
            return 0.0
        rank = q * (n - 1)  # zero-indexed fractional rank
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            # bucket i covers zero-indexed ranks [cum, cum + c)
            if rank < cum + c:
                lo = 0.0 if i == 0 else BUCKET_BOUNDS[i - 1]
                hi = (BUCKET_BOUNDS[i] if i < len(BUCKET_BOUNDS)
                      else BUCKET_BOUNDS[-1] * 2.0)
                frac = (rank - cum + 1.0) / c  # position within the bucket
                return lo + (hi - lo) * min(frac, 1.0)
            cum += c
        lo = BUCKET_BOUNDS[-1]
        return lo * 2.0

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> "LogHistogram":
        h = LogHistogram()
        h.counts = list(self.counts)
        h.count = self.count
        h.total = self.total
        return h

    def to_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "p50": self.quantile(0.5),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


# ------------------------------------------------------------- instruments


def _check_labels(labelnames: tuple, labels: dict) -> tuple:
    if tuple(sorted(labels)) != tuple(sorted(labelnames)):
        raise ValueError(
            f"expected labels {labelnames}, got {tuple(sorted(labels))}")
    return tuple(labels[n] for n in labelnames)


class _Instrument:
    """Shared shape: name/help/labelnames + a per-labelset value table
    guarded by the owning registry's lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 lock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock  # the owning registry's lock, shared
        self._values: dict = {}  # labelvalues tuple -> value  # guarded-by: self._lock

    def samples(self) -> list[tuple[dict, object]]:
        with self._lock:
            items = list(self._values.items())
        return [(dict(zip(self.labelnames, lv)), v) for lv, v in items]

    def value(self, **labels) -> object:
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            return self._values.get(lv)


class Counter(_Instrument):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            self._values[lv] = self._values.get(lv, 0.0) + amount

    def set_total(self, value: float, **labels) -> None:
        """Overwrite the running total — the mirroring path, where the
        source of truth (``TenantStats`` and friends) already accumulated."""
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            self._values[lv] = float(value)


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            self._values[lv] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            self._values[lv] = self._values.get(lv, 0.0) + amount


class Histogram(_Instrument):
    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            h = self._values.get(lv)
            if h is None:
                h = self._values[lv] = LogHistogram()
            h.observe(value)

    def merge_snapshot(self, hist: LogHistogram, **labels) -> None:
        """Adopt an externally-maintained histogram wholesale (mirroring
        ``TenantStats``' per-stage histograms at exposition time)."""
        lv = _check_labels(self.labelnames, labels)
        with self._lock:
            self._values[lv] = hist.snapshot()


# ---------------------------------------------------------------- registry


def _fmt_labels(labels: dict, extra: Optional[dict] = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"'
                     for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class MetricsRegistry:
    """Name-keyed instrument collection with Prometheus-text and JSON
    exposition.  ``counter``/``gauge``/``histogram`` are get-or-create
    (re-registration with a different type or label set is an error)."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = namespace
        self._lock = make_lock("MetricsRegistry._lock")
        self._metrics: dict = {}  # name -> _Instrument  # guarded-by: self._lock

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Sequence[str]):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labelnames,
                                              self._lock)
                return m
        if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {m.kind} "
                f"with labels {m.labelnames}")
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = ()) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames)

    def instruments(self) -> list:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    # ------------------------------------------------------- exposition
    def render_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        lines: list[str] = []
        for m in self.instruments():
            full = f"{self.namespace}_{m.name}"
            if m.help:
                lines.append(f"# HELP {full} {m.help}")
            lines.append(f"# TYPE {full} {m.kind}")
            for labels, v in m.samples():
                if m.kind == "histogram":
                    cum = 0
                    for i, c in enumerate(v.counts):
                        cum += c
                        if c == 0 and i < len(BUCKET_BOUNDS):
                            continue  # sparse: skip empty interior buckets
                        le = (f"{BUCKET_BOUNDS[i]:g}"
                              if i < len(BUCKET_BOUNDS) else "+Inf")
                        lines.append(
                            f"{full}_bucket"
                            f"{_fmt_labels(labels, {'le': le})} {cum}")
                    lines.append(
                        f"{full}_sum{_fmt_labels(labels)} {v.total:g}")
                    lines.append(
                        f"{full}_count{_fmt_labels(labels)} {v.count}")
                else:
                    lines.append(f"{full}{_fmt_labels(labels)} {v:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def render_json(self) -> dict:
        out = []
        for m in self.instruments():
            samples = []
            for labels, v in m.samples():
                if m.kind == "histogram":
                    samples.append({"labels": labels, **v.to_dict()})
                else:
                    samples.append({"labels": labels, "value": v})
            out.append({"name": f"{self.namespace}_{m.name}",
                        "type": m.kind, "help": m.help, "samples": samples})
        return {"metrics": out}
