"""Word/character hybrid tokenizer for the canonicalizer model.

Deterministic, dependency-free: a fixed vocabulary built from the schema
vocabulary + JSON structural tokens + common words, with character fallback.
Small (< 8k ids) so the canonicalizer-100m LM head stays cheap and the
JSON-constrained decoder can evaluate the whole vocab per step.
"""
from __future__ import annotations

import re
import string

SPECIALS = ["<pad>", "<bos>", "<eos>", "<sep>", "<unk>"]
JSON_TOKENS = list('{}[]":,') + [
    '"schema"', '"measures"', '"levels"', '"filters"', '"time_window"',
    '"agg"', '"expr"', '"col"', '"op"', '"val"', '"start"', '"end"',
    '"SUM"', '"COUNT"', '"MIN"', '"MAX"', '"AVG"', '"="',
]


class Tokenizer:
    def __init__(self, corpus_words: list[str], vocab_size: int = 8192):
        words = sorted(set(corpus_words))
        chars = list(string.printable[:95])
        vocab = SPECIALS + JSON_TOKENS + chars + words
        self.vocab = vocab[:vocab_size]
        self.index = {t: i for i, t in enumerate(self.vocab)}
        self.pad, self.bos, self.eos, self.sep, self.unk = range(5)

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def id_to_str(self, i: int) -> str:
        t = self.vocab[i]
        return "" if t in SPECIALS else t

    def encode(self, text: str, add_bos: bool = False) -> list[int]:
        out = [self.bos] if add_bos else []
        for piece in re.findall(r'"[A-Za-z_.#\- ]*"|\w+|\S|\s', text):
            if piece in self.index:
                out.append(self.index[piece])
            else:
                for ch in piece:
                    out.append(self.index.get(ch, self.unk))
        return out

    def decode(self, ids) -> str:
        return "".join(self.id_to_str(int(i)) for i in ids)


def build_tokenizer(workloads) -> Tokenizer:
    """Vocabulary from workload NL vocab + signature JSON components."""
    words: list[str] = []
    for wl in workloads:
        v = wl.vocab
        words += list(v.measures) + list(v.levels) + list(v.values) + list(v.numeric_cols)
        for senses in v.measures.values():
            words += [f'"{s.expr}"' for s in senses]
        for levels in v.levels.values():
            words += [f'"{lv}"' for lv in levels]
        words += [f'"{wl.name}"']
        for key, pairs in v.values.items():
            words += [f'"{col}"' for col, _ in pairs] + [f'"{val}"' for _, val in pairs]
    words += [w for text in _COMMON for w in text.split()]
    return Tokenizer(words)


_COMMON = [
    "show what is give me report compute display total average number of by per",
    "for each broken down grouped in during from to and with top having over",
    "under between please dashboard needs looking break out can you i need",
]
