"""The training loop: microbatched, checkpointed, restartable.

Composes the substrate: model loss fn -> grad accumulation over microbatches
(compute/communication overlap — each microbatch's reduce-scatter overlaps the
next microbatch's compute under XLA latency hiding) -> AdamW + ZeRO-1 ->
atomic async checkpoints -> deterministic skip-ahead resume.  Optional
error-feedback int8 gradient compression for the cross-pod reduction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig
from .checkpoint import prune_old, restore_latest, save_checkpoint, wait_pending
from .optimizer import AdamWConfig, adamw_update, compress_grads, decompress_grads, init_opt_state


@dataclasses.dataclass
class TrainConfig:
    steps: int = 300
    microbatches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    grad_compression: bool = False
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    mod = cfg.build()

    def train_step(params, opt_state, batch, compress_residual=None):
        if tcfg.microbatches > 1:
            def micro(i, acc):
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tcfg.microbatches),
                        x.shape[0] // tcfg.microbatches, axis=0),
                    batch)
                loss, grads = jax.value_and_grad(
                    lambda p: mod.loss_fn(cfg, p, mb))(params)
                return (acc[0] + loss,
                        jax.tree.map(jnp.add, acc[1], grads))

            zero = (jnp.zeros(()), jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            loss_sum, grads = jax.lax.fori_loop(
                0, tcfg.microbatches, micro, zero)
            loss = loss_sum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: mod.loss_fn(cfg, p, batch))(params)
        new_residual = compress_residual
        if tcfg.grad_compression:
            q, scales, new_residual = compress_grads(grads, compress_residual)
            grads = decompress_grads(q, scales)
        new_p, new_o, gnorm = adamw_update(tcfg.opt, params, grads, opt_state)
        return loss, gnorm, new_p, new_o, new_residual

    return train_step


def train(cfg: ModelConfig, tcfg: TrainConfig, batches, params=None,
          key=None, log: Callable[[str], None] = print) -> dict:
    """Run the loop with restart support.  ``batches`` must expose
    ``batch_at(step)`` (deterministic skip-ahead)."""
    mod = cfg.build()
    if params is None:
        params = mod.init_params(
            cfg, key if key is not None else jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    start_step = 0
    if tcfg.ckpt_dir:
        wait_pending()  # a prior in-process run may still be flushing
        restored, step, _ = restore_latest(tcfg.ckpt_dir, {"p": params, "o": opt_state})
        if restored is not None:
            params, opt_state = restored["p"], restored["o"]
            start_step = step + 1
            log(f"[train] resumed from step {step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    residual = None
    if tcfg.grad_compression:
        residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    history = []
    last_saved = -1
    t0 = time.time()
    for step in range(start_step, tcfg.steps):
        batch = jax.tree.map(jnp.asarray, batches.batch_at(step))
        if tcfg.grad_compression:
            loss, gnorm, params, opt_state, residual = step_fn(
                params, opt_state, batch, residual)
        else:
            loss, gnorm, params, opt_state, _ = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            log(f"[train] step {step} loss {float(loss):.4f} "
                f"gnorm {float(gnorm):.3f} ({time.time() - t0:.1f}s)")
            history.append({"step": step, "loss": float(loss)})
        if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
            save_checkpoint(tcfg.ckpt_dir, step, {"p": params, "o": opt_state},
                            async_save=True)
            last_saved = step
            prune_old(tcfg.ckpt_dir, tcfg.ckpt_keep)
    if tcfg.ckpt_dir and last_saved != tcfg.steps - 1:
        save_checkpoint(tcfg.ckpt_dir, tcfg.steps - 1, {"p": params, "o": opt_state})
    if tcfg.ckpt_dir:
        wait_pending()
    return {"params": params, "opt_state": opt_state, "history": history}
