"""Fault-tolerant sharded checkpointing.

Design for 1000+-node operation:
  * per-leaf .npy files under a step directory + a msgpack manifest carrying
    tree structure, shapes, dtypes, mesh metadata, and per-file checksums;
  * atomic commit: write to ``step_N.tmp``, fsync, rename — a crashed writer
    never corrupts the latest valid checkpoint;
  * ``restore_latest`` scans for the newest *complete* checkpoint (manifest
    present + checksums match) and falls back to older ones — the restart
    path after node failure;
  * async save: the serialized bytes are handed to a background thread so the
    train loop keeps stepping (snapshot-consistent: arrays are fetched to host
    before the thread starts);
  * **elastic re-mesh**: checkpoints store logical arrays, not device layouts,
    so a checkpoint written on a 16x16 mesh restores onto 8x16 (or any other)
    mesh — failed-pod exclusion and rescale are a restore, not a migration.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes numpy can't serialize natively (bfloat16, fp8): stored as raw bytes
_CUSTOM_DTYPES = {"bfloat16": ml_dtypes.bfloat16}
for _name in ("float8_e4m3fn", "float8_e5m2"):
    if hasattr(ml_dtypes, _name):
        _CUSTOM_DTYPES[_name] = getattr(ml_dtypes, _name)


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """Byte-exact encoding for np.save: custom dtypes become uint8 buffers."""
    name = arr.dtype.name
    if name in _CUSTOM_DTYPES:
        return np.ascontiguousarray(arr).view(np.uint8), name
    return arr, name


def _decode(arr: np.ndarray, dtype_name: str, shape) -> np.ndarray:
    if dtype_name in _CUSTOM_DTYPES:
        return arr.reshape(-1).view(_CUSTOM_DTYPES[dtype_name]).reshape(shape)
    return arr


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
                    async_save: bool = False) -> str:
    """Write checkpoint atomically; returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    # unique tmp per writer: concurrent async + sync saves of the same step
    # must not clobber each other's staging directory
    tmp = final + f".tmp.{os.getpid()}.{threading.get_ident()}"
    # snapshot to host memory NOW (so async writes see a consistent state)
    leaves = [(name, np.asarray(leaf)) for name, leaf in _flatten_with_paths(tree)]

    def write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for name, arr in leaves:
            fname = hashlib.md5(name.encode()).hexdigest()[:16] + ".npy"
            fpath = os.path.join(tmp, fname)
            enc, dtype_name = _encode(arr)
            np.save(fpath, enc)
            with open(fpath, "rb") as f:
                digest = hashlib.md5(f.read()).hexdigest()
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape),
                 "dtype": dtype_name, "md5": digest})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
        return final
    write()
    return final


_PENDING: list[threading.Thread] = []


def wait_pending() -> None:
    """Join outstanding async checkpoint writers (call before exit/restore)."""
    while _PENDING:
        _PENDING.pop().join()


def _verify(path: str) -> Optional[dict]:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for leaf in manifest["leaves"]:
            fpath = os.path.join(path, leaf["file"])
            with open(fpath, "rb") as f:
                if hashlib.md5(f.read()).hexdigest() != leaf["md5"]:
                    return None
        return manifest
    except (OSError, json.JSONDecodeError, KeyError):
        return None


def restore_latest(ckpt_dir: str, tree_like, shardings=None):
    """Restore the newest valid checkpoint onto ``tree_like``'s structure.

    ``shardings``: optional NamedSharding pytree — arrays are device_put with
    the *current* mesh's shardings, which is exactly the elastic-rescale path.
    Returns (tree, step, extra) or (None, -1, {}) when nothing valid exists.
    """
    if not os.path.isdir(ckpt_dir):
        return None, -1, {}
    candidates = sorted(
        (d for d in os.listdir(ckpt_dir)
         if d.startswith("step_") and ".tmp" not in d),
        reverse=True,
    )
    for cand in candidates:
        path = os.path.join(ckpt_dir, cand)
        manifest = _verify(path)
        if manifest is None:
            continue  # incomplete/corrupt: fall back to an older checkpoint
        by_name = {l["name"]: l for l in manifest["leaves"]}
        names = [name for name, _ in _flatten_with_paths(tree_like)]
        if set(names) != set(by_name):
            continue  # structure mismatch (e.g. different arch): keep looking
        arrays = {
            name: _decode(np.load(os.path.join(path, by_name[name]["file"])),
                          by_name[name]["dtype"], by_name[name]["shape"])
            for name in names
        }
        flat_named = _flatten_with_paths(tree_like)
        leaves = [arrays[name] for name, _ in flat_named]
        treedef = jax.tree.structure(tree_like)
        tree = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest["step"], manifest.get("extra", {})
    return None, -1, {}


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    done = sorted(d for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and ".tmp" not in d)
    for d in done[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
