"""Training data pipeline for the canonicalizer model.

Supervised pairs (NL question -> intent-signature JSON) generated from the
workload paraphrase machinery — i.e. the data the paper's LLM implicitly
models.  The pipeline is deterministic, shardable by host, and supports
skip-ahead resume (step -> batch mapping is pure), which is what checkpoint
restart and elastic rescale require.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterator

import numpy as np

from ..core.sql_canon import SQLCanonicalizer
from ..workloads.paraphrase import gen_paraphrases


@dataclasses.dataclass
class NLPair:
    text: str
    target_json: str


def build_pairs(workloads, paraphrases_per_intent: int = 30, seed: int = 0) -> list[NLPair]:
    pairs: list[NLPair] = []
    for wl in workloads:
        canon = SQLCanonicalizer(wl.schema)
        for i, intent in enumerate(wl.intents):
            sig = canon.canonicalize(intent.sql)
            tgt = sig.canonical_json()
            for text in gen_paraphrases(intent, n=paraphrases_per_intent,
                                        seed=seed + 31 * i):
                pairs.append(NLPair(text, tgt))
    return pairs


class BatchIterator:
    """Deterministic, host-sharded, step-addressable batch stream."""

    def __init__(self, pairs: list[NLPair], tokenizer, batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, num_hosts: int = 1):
        self.pairs = pairs
        self.tok = tokenizer
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts

    def batch_at(self, step: int) -> dict:
        """Pure function of (step, seed): enables exact skip-ahead on resume."""
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        idx = rng.integers(0, len(self.pairs), size=self.batch * self.num_hosts)
        idx = idx[self.host_id * self.batch:(self.host_id + 1) * self.batch]
        tokens = np.full((self.batch, self.seq_len), self.tok.pad, np.int32)
        labels = np.full((self.batch, self.seq_len), -1, np.int32)
        for r, j in enumerate(idx):
            p = self.pairs[int(j)]
            prompt = self.tok.encode(f"question: {p.text}\nsignature: ", add_bos=True)
            target = self.tok.encode(p.target_json) + [self.tok.eos]
            seq = (prompt + target)[: self.seq_len]
            tokens[r, :len(seq)] = seq
            # next-token labels only over the target span
            start = min(len(prompt), self.seq_len) - 1
            for t in range(start, min(len(seq) - 1, self.seq_len - 1)):
                labels[r, t] = seq[t + 1]
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
