"""AdamW with gradient clipping, plus ZeRO-1 sharding specs and optional
error-feedback int8 gradient compression.

Pure-pytree implementation (no optax dependency).  The optimizer state
carries fp32 master moments; with ZeRO-1 the moments (and the fp32 param
copy, if enabled) are additionally sharded along the 'data' axis on their
largest divisible dimension — the classic optimizer-state partitioning.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params, grads, state, moment_specs=None):
    """``moment_specs``: optional PartitionSpec tree for the (ZeRO-1-sharded)
    moments.  When given, the whole update — including the fp32 math and the
    bf16 downcast — is constrained to the moment sharding, so the param
    all-gather that restores full replicas moves *bf16*, not fp32.  Without
    it GSPMD is free to gather the fp32 update (2x interconnect bytes)."""
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    # global grad-norm clip
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, mspec):
        def shard(x):
            if mspec is None:
                return x
            return jax.lax.with_sharding_constraint(x, mspec)

        gf = shard(g.astype(jnp.float32) * scale)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * shard(p.astype(jnp.float32))
        new_p = shard(p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    if moment_specs is None:
        flat_s = [None] * len(flat_p)
    else:
        flat_s = jax.tree.leaves(
            moment_specs,
            is_leaf=lambda x: isinstance(x, (P, jax.sharding.Sharding)) or x is None)
    out = [upd(p, g, m, v, s)
           for p, g, m, v, s in zip(flat_p, flat_g, flat_m, flat_v, flat_s)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm


# ------------------------------------------------------------------- ZeRO-1


def zero1_spec(spec: P, shape: tuple, data_axes: tuple, data_size: int) -> P:
    """Extend a param's TP spec so optimizer moments also shard over the data
    axes: pick the first dimension that is unsharded and divisible."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % data_size == 0 and dim >= data_size:
            parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            return P(*parts)
    return P(*parts)  # nothing divisible: stay TP-only


def opt_state_specs(param_specs, param_shapes, data_axes: tuple, data_size: int):
    moment = jax.tree.map(
        lambda s, p: zero1_spec(s, p.shape, data_axes, data_size),
        param_specs, param_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"m": moment, "v": moment, "step": P()}


# --------------------------------------- error-feedback int8 compression


def compress_grads(grads, residual: Optional[Any] = None):
    """Error-feedback int8 quantization: returns (q, scales, new_residual).
    Used before cross-pod gradient reduction to cut interconnect bytes 4x
    (bf16 -> int8 + per-tensor scale); the quantization error feeds back into
    the next step so convergence is preserved."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def q(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        err = gf - qi.astype(jnp.float32) * scale
        return qi, scale, err

    flat, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, scales, errs = zip(*[q(g, r) for g, r in zip(flat, flat_r)])
    return (jax.tree.unflatten(treedef, list(qs)),
            jax.tree.unflatten(treedef, list(scales)),
            jax.tree.unflatten(treedef, list(errs)))


def decompress_grads(q, scales):
    return jax.tree.map(lambda qi, s: qi.astype(jnp.float32) * s, q, scales)
