"""KV/SSM cache containers for the serving engine.

Caches are preallocated to a fixed maximum length (``make_cache`` per model
family) and updated functionally inside jitted steps.  This module adds the
host-side bookkeeping: slot allocation for continuous batching and cache
reset between requests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig


@dataclasses.dataclass
class CacheState:
    caches: Any  # model-family cache pytree
    pos: jnp.ndarray  # (B,) current lengths
    max_len: int
    batch: int

    @staticmethod
    def fresh(cfg: ModelConfig, batch: int, max_len: int) -> "CacheState":
        mod = cfg.build()
        return CacheState(
            caches=mod.make_cache(cfg, batch, max_len),
            pos=jnp.zeros((batch,), jnp.int32),
            max_len=max_len,
            batch=batch,
        )

    def reset_rows(self, rows) -> "CacheState":
        """Zero the given batch rows (slot reuse in continuous batching).
        KV content is masked by pos, so resetting pos suffices."""
        pos = self.pos.at[jnp.asarray(rows)].set(0)
        return dataclasses.replace(self, pos=pos)
