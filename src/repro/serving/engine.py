"""Batched serving engine + the JAX-LLM canonicalizer service.

The engine drives any registered architecture through prefill + decode with
continuous batching (slot-based), greedy/temperature sampling, and optional
grammar-constrained JSON decoding.  ``CanonicalizerService`` plugs the engine
behind the middleware's NLCanonicalizer protocol: prompt = schema vocabulary +
NL question, output = intent-signature JSON + confidence (mean token
log-probability through a squashing map — the paper's uncalibrated heuristic
score).
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import json
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.nl_canon import NLResult
from ..core.signature import signature_from_json
from ..models.model import ModelConfig
from .json_decode import JsonSigAutomaton, constrained_sample


@dataclasses.dataclass
class Request:
    text: str
    max_new_tokens: int = 256
    constrained: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, tokenizer, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.tok = tokenizer
        self.max_len = max_len
        self.mod = cfg.build()
        self._prefill = jax.jit(
            lambda p, tokens: self.mod.prefill(cfg, p, tokens=tokens, cache_len=max_len)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos: self.mod.decode_step(cfg, p, t, c, pos)
        )
        self.steps = 0

    def generate(self, prompts: list[str], max_new_tokens: int = 128,
                 constrained: bool = False) -> list[dict]:
        """Batched generation; returns [{'text', 'logprob', 'tokens'}]."""
        b = len(prompts)
        enc = [self.tok.encode(p, add_bos=True)[-self.max_len // 2:] for p in prompts]
        plen = max(len(e) for e in enc)
        tokens = np.full((b, plen), self.tok.pad, np.int32)
        for i, e in enumerate(enc):
            tokens[i, plen - len(e):] = e  # left-pad so last position aligns
        logits, caches, pos = self._prefill(self.params, jnp.asarray(tokens))
        automaton = JsonSigAutomaton()
        vocab = [self.tok.id_to_str(i) for i in range(self.tok.vocab_size)]
        outs = [[] for _ in range(b)]
        texts = [""] * b
        logprobs = [0.0] * b
        done = [False] * b
        for _ in range(max_new_tokens):
            np_logits = np.array(logits, np.float32)  # writable host copy
            # model head may be wider than the tokenizer: drop phantom ids
            np_logits = np_logits[:, :len(vocab)]
            next_ids = np.zeros(b, np.int32)
            for i in range(b):
                if done[i]:
                    next_ids[i] = self.tok.pad
                    continue
                if constrained:
                    nid = constrained_sample(np_logits[i], texts[i], vocab, automaton)
                    if nid < 0:
                        done[i] = True
                        next_ids[i] = self.tok.pad
                        continue
                else:
                    nid = int(np.argmax(np_logits[i]))
                lp = np_logits[i] - _logsumexp(np_logits[i])
                logprobs[i] += float(lp[nid])
                next_ids[i] = nid
                outs[i].append(nid)
                texts[i] += vocab[nid]
                if nid == self.tok.eos or (constrained and automaton.is_complete(texts[i])):
                    done[i] = True
            if all(done):
                break
            logits, caches, pos = self._decode(
                self.params, jnp.asarray(next_ids), caches, pos)
            self.steps += 1
        return [
            {"text": texts[i], "tokens": outs[i],
             "logprob": logprobs[i] / max(len(outs[i]), 1)}
            for i in range(b)
        ]


def _logsumexp(x):
    m = x.max()
    return m + math.log(np.exp(x - m).sum())


class CanonicalizerService:
    """NL -> signature through the in-framework LLM (NLCanonicalizer protocol)."""

    def __init__(self, engine: ServingEngine, schema_name: str,
                 prompt_header: str = "", deadline_s: Optional[float] = None):
        self.engine = engine
        self.schema_name = schema_name
        self.prompt_header = prompt_header
        # soft per-call budget: the engine's decode loop is not preemptible,
        # so the deadline is checked after the pass — an overrun batch
        # reports structured timeout NLResults instead of burning the cache
        # path on answers nobody is waiting for anymore
        self.deadline_s = deadline_s
        self.deadline_overruns = 0

    def canonicalize(self, text: str, now: Optional[_dt.date] = None) -> NLResult:
        return self.canonicalize_batch([text], now)[0]

    def canonicalize_batch(self, texts: list[str],
                           now: Optional[_dt.date] = None) -> list[NLResult]:
        """Pipeline-stage entry point: the whole batch of NL requests is
        decoded by one slot-batched prefill+decode pass of the engine (one
        model launch for a dashboard refresh's NL tiles, not one per tile)."""
        prompts = [f"{self.prompt_header}question: {t}\nsignature: " for t in texts]
        t0 = time.perf_counter()
        outs = self.engine.generate(prompts, constrained=True)
        if self.deadline_s is not None \
                and (time.perf_counter() - t0) > self.deadline_s:
            self.deadline_overruns += 1
            return [NLResult(None, 0.0, "",
                             f"canonicalizer deadline exceeded "
                             f"({self.deadline_s:.3f}s)") for _ in texts]
        results = []
        for out in outs:
            raw = out["text"]
            confidence = 1.0 / (1.0 + math.exp(-(out["logprob"] + 1.0)))  # squashed heuristic
            try:
                obj = json.loads(raw)
                obj.setdefault("schema", self.schema_name)
                sig = signature_from_json(obj)
            except Exception as e:
                results.append(NLResult(None, round(confidence, 3), raw,
                                        f"malformed JSON: {e}"))
                continue
            results.append(NLResult(sig, round(confidence, 3), raw, None))
        return results
