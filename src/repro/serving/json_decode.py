"""Grammar-constrained JSON decoding for intent-signature emission.

The paper constrains the LLM to produce strict JSON matching the signature
schema (§3.4).  This module implements that constraint for our own serving
engine: a character-level pushdown automaton over the signature JSON grammar
computes, at every step, the set of legal next tokens; illegal logits are
masked to -inf before sampling.  The automaton is intentionally restricted to
the OLAP Intent Signature shape — objects with known keys, string/number
values, ISO dates — rather than full JSON.

Works with any tokenizer that exposes ``id_to_str``: the mask is built by
checking each candidate token's string continuation against the automaton
(vectorized over the vocab on the host once per step; vocabularies used by
the canonicalizer model are small).
"""
from __future__ import annotations

import json
import string
from typing import Optional

import numpy as np

# characters legal inside quoted strings (schema identifiers / values)
_STR_CHARS = set(string.ascii_lowercase + string.digits + "_.#- ")
_NUM_CHARS = set(string.digits + ".-")


class JsonSigAutomaton:
    """Tracks partial output and exposes ``legal_continuations(text)``.

    States follow a simplified signature grammar:

        { "schema": "<str>", "measures": [ {"agg": "<AGG>", "expr": "<str>"} ],
          "levels": [ "<str>" ... ], "filters": [...], "time_window": {...} }

    The implementation validates structural well-formedness incrementally by
    attempted JSON completion — practical and exact for our bounded depth.
    """

    AGGS = ("SUM", "COUNT", "MIN", "MAX", "AVG")

    def __init__(self, max_len: int = 512):
        self.max_len = max_len

    def is_legal_prefix(self, text: str) -> bool:
        if len(text) > self.max_len:
            return False
        if not text:
            return True
        if text[0] != "{":
            return False
        depth_obj = 0
        depth_arr = 0
        in_str = False
        prev = ""
        for ch in text:
            if in_str:
                if ch == '"':
                    in_str = False
                elif not (ch.isalnum() or ch in _STR_CHARS):
                    return False
            else:
                if ch == '"':
                    in_str = True
                elif ch == "{":
                    depth_obj += 1
                elif ch == "}":
                    depth_obj -= 1
                    if depth_obj < 0:
                        return False
                elif ch == "[":
                    depth_arr += 1
                elif ch == "]":
                    depth_arr -= 1
                    if depth_arr < 0:
                        return False
                elif ch not in ' :,0-9.tfnue-"' and not ch.isalnum():
                    return False
            prev = ch
        return True

    def is_complete(self, text: str) -> bool:
        try:
            obj = json.loads(text)
        except json.JSONDecodeError:
            return False
        return isinstance(obj, dict) and "measures" in obj and "schema" in obj

    def token_mask(self, prefix: str, vocab: list[str]) -> np.ndarray:
        """Boolean mask over the vocab: True where prefix+token stays legal."""
        mask = np.zeros(len(vocab), dtype=bool)
        for i, tok in enumerate(vocab):
            if tok and self.is_legal_prefix(prefix + tok):
                mask[i] = True
        return mask


def constrained_sample(logits: np.ndarray, prefix: str, vocab: list[str],
                       automaton: JsonSigAutomaton,
                       temperature: float = 0.0,
                       rng: Optional[np.random.Generator] = None) -> int:
    """Pick the next token under the JSON constraint (greedy or sampled)."""
    mask = automaton.token_mask(prefix, vocab)
    if not mask.any():
        return -1  # dead end: caller treats as malformed output
    masked = np.where(mask, logits, -np.inf)
    if temperature <= 0:
        return int(np.argmax(masked))
    probs = np.exp((masked - masked.max()) / temperature)
    probs = probs / probs.sum()
    rng = rng or np.random.default_rng(0)
    return int(rng.choice(len(vocab), p=probs))
