"""Resilience primitives: deadlines, deterministic backoff, circuit breakers.

These are the mechanisms the chaos harness (:mod:`.faults`) proves out:

* :class:`Deadline` — a monotonic per-request budget carried on
  ``QueryRequest.deadline_ms``; stages check it before starting expensive
  work and shed (or serve degraded) instead of burning a dead request's
  backend time.
* :func:`backoff_delays` — exponential backoff with *deterministic* jitter
  (hash-derived, salted by the retried key), so retry schedules are
  replayable under the chaos harness just like the faults themselves.
* :class:`CircuitBreaker` — the classic closed -> open -> half-open state
  machine, one instance per unreliable dependency (canonicalizer, backend,
  cold tier).  ``allow()`` is the admission check; ``record_success`` /
  ``record_failure`` drive the transitions.  After ``recovery_s`` an open
  breaker admits ``half_open_probes`` probe requests: one success closes it,
  one failure re-opens it.
"""
from __future__ import annotations

import hashlib
import time
from typing import Optional

from ..analysis.sanitizer import make_lock


def hash01(salt: str, n: int) -> float:
    """Deterministic uniform draw in [0, 1) from (salt, n)."""
    h = hashlib.sha256(f"{salt}|{n}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


def backoff_delays(attempts: int, base_s: float, max_s: float,
                   salt: str = "") -> list[float]:
    """The ``attempts - 1`` sleep intervals between retry attempts:
    ``min(max_s, base_s * 2**i)`` scaled by jitter in [0.5, 1.5)."""
    out = []
    for i in range(max(attempts - 1, 0)):
        d = min(max_s, base_s * (2.0 ** i))
        out.append(d * (0.5 + hash01(salt, i)))
    return out


class Deadline:
    """A wall-clock budget anchored at creation (monotonic clock)."""

    __slots__ = ("at",)

    def __init__(self, at: float):
        self.at = at

    @classmethod
    def after_ms(cls, ms: float) -> "Deadline":
        return cls(time.monotonic() + ms / 1e3)

    @property
    def expired(self) -> bool:
        return time.monotonic() >= self.at

    def remaining_s(self) -> float:
        return max(self.at - time.monotonic(), 0.0)


class CircuitBreaker:
    """Per-dependency circuit breaker with half-open probing.

    Thread-safe behind a leaf lock (nothing else is acquired while holding
    it).  ``clock`` is injectable so tests can step recovery time without
    sleeping."""

    def __init__(self, name: str, *, failure_threshold: int = 5,
                 recovery_s: float = 1.0, half_open_probes: int = 1,
                 clock=time.monotonic):
        self.name = name
        self.failure_threshold = int(failure_threshold)
        self.recovery_s = float(recovery_s)
        self.half_open_probes = int(half_open_probes)
        self._clock = clock
        self._lock = make_lock("CircuitBreaker._lock")
        self._state = "closed"  # guarded-by: self._lock
        self._failures = 0  # guarded-by: self._lock
        self._opened_at = 0.0  # guarded-by: self._lock
        self._probes = 0  # guarded-by: self._lock
        self.opens = 0  # guarded-by: self._lock
        self.closes = 0  # guarded-by: self._lock
        self.rejections = 0  # guarded-by: self._lock

    # ----------------------------------------------------------- admission
    def allow(self) -> bool:
        """May a request use this dependency right now?  Advances
        open -> half-open once ``recovery_s`` has elapsed."""
        with self._lock:
            if self._state == "closed":
                return True
            if self._state == "open":
                if self._clock() - self._opened_at < self.recovery_s:
                    self.rejections += 1
                    return False
                self._state = "half_open"
                self._probes = 0
            # half-open: admit a bounded number of probes
            if self._probes < self.half_open_probes:
                self._probes += 1
                return True
            self.rejections += 1
            return False

    # --------------------------------------------------------- transitions
    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            if self._state != "closed":
                self._state = "closed"
                self.closes += 1

    def record_failure(self) -> None:
        with self._lock:
            if self._state == "half_open":
                # a failed probe re-opens immediately (fresh recovery window)
                self._state = "open"
                self._opened_at = self._clock()
                self.opens += 1
                return
            self._failures += 1
            if self._state == "closed" and \
                    self._failures >= self.failure_threshold:
                self._state = "open"
                self._opened_at = self._clock()
                self.opens += 1

    # ------------------------------------------------------- introspection
    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "name": self.name,
                "state": self._state,
                "failures": self._failures,
                "opens": self.opens,
                "closes": self.closes,
                "rejections": self.rejections,
            }


def run_with_retry(fn, *, attempts: int, base_s: float, max_s: float,
                   salt: str = "", sleep=time.sleep,
                   retryable=(Exception,),
                   on_retry=None) -> tuple[object, int, Optional[BaseException]]:
    """Run ``fn()`` up to ``attempts`` times with backoff between failures.

    Returns ``(result, retries_used, last_error)``: ``last_error`` is None on
    success.  ``on_retry(attempt, error)`` is called before each re-attempt
    (for counters).  Intended for idempotent stages only."""
    delays = backoff_delays(attempts, base_s, max_s, salt)
    err: Optional[BaseException] = None
    for attempt in range(max(attempts, 1)):
        try:
            return fn(), attempt, None
        except retryable as e:  # noqa: PERF203 — retry loop by design
            err = e
            if attempt + 1 < attempts:
                if on_retry is not None:
                    on_retry(attempt, e)
                sleep(delays[attempt])
    return None, max(attempts, 1) - 1, err
