"""Typed error taxonomy for pipeline failures.

A request that hits a dependency failure never surfaces a raw exception:
it resolves to a ``QueryResult`` whose ``status`` is ``"degraded"`` (a
stale-but-tagged cached answer was served) or ``"error"`` (nothing safe to
serve), carrying a :class:`FailureInfo` that records *where* it failed
(stage), *how* (kind), and what the resilience machinery did about it
(retries used, breaker state, whether a degraded answer was served).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

# failure kinds (the closed vocabulary used by the pipeline):
#   'timeout'       — dependency call exceeded its time budget
#   'deadline'      — the request's own deadline budget expired (shed)
#   'breaker_open'  — failed fast: the dependency's circuit breaker is open
#   'fault'         — an injected chaos-harness failure (FaultError)
#   'io'            — storage/OS-level failure (OSError family)
#   'internal'      — unexpected pipeline-stage exception (contained)
#   'error'         — any other dependency exception
KINDS = ("timeout", "deadline", "breaker_open", "fault", "io", "internal",
         "error")


@dataclasses.dataclass
class FailureInfo:
    """What went wrong for one request, and what resilience did about it."""

    stage: str  # pipeline stage that failed ('canonicalize' | 'execute' | ...)
    kind: str  # one of KINDS
    message: str = ""
    retries: int = 0  # retry attempts spent before giving up
    breaker: Optional[str] = None  # breaker state at failure time, if any
    degraded: bool = False  # a stale/tagged answer was served despite this

    def to_dict(self) -> dict[str, Any]:
        d: dict[str, Any] = {"stage": self.stage, "kind": self.kind}
        if self.message:
            d["message"] = self.message
        if self.retries:
            d["retries"] = self.retries
        if self.breaker is not None:
            d["breaker"] = self.breaker
        if self.degraded:
            d["degraded"] = True
        return d

    def brief(self) -> str:
        return f"{self.stage}:{self.kind}"


def classify(exc: BaseException) -> str:
    """Map an exception to a :data:`KINDS` entry."""
    from .faults import FaultError

    if isinstance(exc, FaultError):
        return "timeout" if exc.point.endswith(".timeout") else "fault"
    if isinstance(exc, TimeoutError):
        return "timeout"
    if isinstance(exc, OSError):
        return "io"
    return "error"
