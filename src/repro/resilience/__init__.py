"""Fault injection & graceful degradation for the cache service.

The paper's contract is *safety-first* — zero false hits under strict
validation — and this package extends that promise off the happy path.  It
has two halves that prove each other out, in the spirit of the PR 7
sanitizer (inject the failure, demonstrate the invariant):

* :mod:`faults` — a deterministic, seedable chaos harness.  Named injection
  points sit on every stage boundary (canonicalize / backend execute /
  storage WAL + payloads + spill worker / cluster single-flight) and are
  activated via ``REPRO_FAULTS="point:rate:seed"``, so every failure test
  and chaos bench run is replayable bit-for-bit.
* :mod:`primitives` + :mod:`policy` — the resilience machinery the
  injections exercise: per-stage deadline budgets, retry with exponential
  backoff + deterministic jitter for idempotent stages (execute, spill,
  cold-tier read), per-dependency circuit breakers (canonicalizer, backend,
  cold tier) with half-open probing, and stale-on-error serving with
  explicit ``degraded:stale`` / ``breaker:open`` provenance — a degraded
  answer is always *tagged*, never a silent wrong answer.

:class:`~repro.resilience.errors.FailureInfo` is the typed error taxonomy
carried on ``QueryResult.error``; ``CacheService.health()`` aggregates the
breaker states and storage error counters.
"""
from . import faults
from .errors import FailureInfo
from .policy import ResiliencePolicy, TenantResilience
from .primitives import CircuitBreaker, Deadline, backoff_delays

__all__ = [
    "CircuitBreaker",
    "Deadline",
    "FailureInfo",
    "ResiliencePolicy",
    "TenantResilience",
    "backoff_delays",
    "faults",
]
