"""Deterministic, seedable fault injection (the chaos harness).

Every failure mode the resilience plane defends against has a **named
injection point** at the exact stage boundary where the real failure would
surface:

====================== ====================================================
point                  where it fires / what it simulates
====================== ====================================================
canonicalize.timeout   the LLM canonicalizer call hangs past its deadline
canonicalize.garbage   the model returns malformed JSON
canonicalize.lowconf   the model returns a far-below-threshold confidence
backend.error          ``execute``/``execute_batch`` raises
backend.latency        a backend latency spike (injected sleep)
backend.partial        one scan-plane partition worker dies mid-batch
flight.leader_death    a single-flight leader dies mid-execute
storage.wal_enospc     WAL append fails with ``OSError(ENOSPC)``
storage.wal_oserror    WAL append fails with a generic ``OSError``
storage.wal_torn       WAL append writes half a frame, then fails (torn line)
storage.sha_corrupt    a cold payload read fails sha verification
storage.spill_error    the spill worker's payload write raises
storage.spill_death    the spill worker thread dies (claim left queued)
coldtier.read_error    a cold-tier payload read raises ``OSError``
====================== ====================================================

Activation is via ``REPRO_FAULTS="point:rate[:seed]"`` (comma-separated for
several points; ``rate`` accepts ``0.1`` or ``10%``; a trailing ``*``
prefix-matches, e.g. ``storage.*:5%:7``), or programmatically via
:func:`install` / :func:`scoped` for tests and benches.

Determinism: draws are **counter-based**, not wall-clock- or RNG-state-
based.  The *n*-th arrival at a point fires iff
``sha256(seed | point | n) < rate`` — so a given (spec, arrival-order)
replays identically, independent of thread scheduling between different
points, and a failure seen once in CI can be reproduced locally from the
spec string alone.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
from typing import Iterator, Optional, Sequence, Union

from ..analysis.sanitizer import make_lock

ENV_VAR = "REPRO_FAULTS"
LATENCY_ENV = "REPRO_FAULT_LATENCY_MS"
DEFAULT_LATENCY_MS = 25.0


class FaultError(RuntimeError):
    """An injected failure.  Carries its injection point so handlers can
    classify it (and tests can assert exactly which point fired)."""

    def __init__(self, point: str, message: Optional[str] = None):
        super().__init__(message or f"injected fault: {point}")
        self.point = point


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule: fire ``point`` at ``rate`` under ``seed``."""

    point: str
    rate: float
    seed: int = 0

    def matches(self, point: str) -> bool:
        if self.point.endswith("*"):
            return point.startswith(self.point[:-1])
        return self.point == point


def parse(text: str) -> tuple[FaultSpec, ...]:
    """Parse ``"point:rate[:seed],point2:rate2[:seed2]"``."""
    specs = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        if len(bits) not in (2, 3):
            raise ValueError(
                f"bad fault spec {part!r}: expected point:rate[:seed]")
        rate_s = bits[1].strip()
        rate = (float(rate_s[:-1]) / 100.0 if rate_s.endswith("%")
                else float(rate_s))
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"bad fault rate {rate_s!r} in {part!r}: "
                             "must be in [0, 1] (or 0%..100%)")
        seed = int(bits[2]) if len(bits) == 3 else 0
        specs.append(FaultSpec(bits[0].strip(), rate, seed))
    return tuple(specs)


def _draw(seed: int, point: str, n: int) -> float:
    h = hashlib.sha256(f"{seed}|{point}|{n}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


class FaultPlan:
    """A compiled set of specs plus per-point arrival counters.

    ``should_fire`` is the single draw primitive: it advances the point's
    arrival counter and evaluates the deterministic hash draw, under a leaf
    lock (no other lock is ever taken while holding it)."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = tuple(specs)
        self._lock = make_lock("FaultPlan._lock")
        self._arrivals: dict[str, int] = {}  # guarded-by: self._lock
        self._fired: dict[str, int] = {}  # guarded-by: self._lock

    def should_fire(self, point: str) -> bool:
        spec = next((s for s in self.specs if s.matches(point)), None)
        if spec is None:
            return False
        with self._lock:
            n = self._arrivals.get(point, 0)
            self._arrivals[point] = n + 1
            fire = spec.rate > 0.0 and _draw(spec.seed, point, n) < spec.rate
            if fire:
                self._fired[point] = self._fired.get(point, 0) + 1
        return fire

    def counts(self) -> dict:
        with self._lock:
            return {"arrivals": dict(self._arrivals),
                    "fired": dict(self._fired)}


_EMPTY = FaultPlan()


class _Registry:
    """Process-wide active plan: an installed plan wins; otherwise the
    ``REPRO_FAULTS`` env var is compiled (and cached per text value, so
    monkeypatched env changes take effect without an explicit install)."""

    def __init__(self):
        self._lock = make_lock("faults._Registry._lock")
        self._installed: Optional[FaultPlan] = None  # guarded-by: self._lock
        self._env_text: Optional[str] = None  # guarded-by: self._lock
        self._env_plan: FaultPlan = _EMPTY  # guarded-by: self._lock

    def plan(self) -> FaultPlan:
        with self._lock:
            if self._installed is not None:
                return self._installed
            text = os.environ.get(ENV_VAR, "")
            if text != self._env_text:
                self._env_text = text
                self._env_plan = FaultPlan(parse(text)) if text else _EMPTY
            return self._env_plan

    def install(self, plan: Optional[FaultPlan]) -> None:
        with self._lock:
            self._installed = plan
            # force an env re-compile on the next plan() after clear(), so
            # stale counters from a previous env plan never leak across tests
            self._env_text = None
            self._env_plan = _EMPTY


_registry = _Registry()


def install(spec: Union[str, Sequence[FaultSpec]]) -> FaultPlan:
    """Programmatically activate a fault plan (overrides the env var)."""
    plan = FaultPlan(parse(spec) if isinstance(spec, str) else spec)
    _registry.install(plan)
    return plan


def clear() -> None:
    """Deactivate any installed plan (the env var becomes authoritative)."""
    _registry.install(None)


@contextlib.contextmanager
def scoped(spec: Union[str, Sequence[FaultSpec]]) -> Iterator[FaultPlan]:
    """``with faults.scoped("backend.error:1.0"): ...`` for tests/benches."""
    plan = install(spec)
    try:
        yield plan
    finally:
        clear()


def active_plan() -> FaultPlan:
    return _registry.plan()


def should_fire(point: str) -> bool:
    """Advance ``point``'s arrival counter and report whether it fires."""
    return _registry.plan().should_fire(point)


def fire(point: str) -> None:
    """Raise :class:`FaultError` when ``point`` fires."""
    if should_fire(point):
        raise FaultError(point)


def fire_os(point: str, err_no: Optional[int] = None) -> None:
    """Raise ``OSError`` (optionally with ``errno``) when ``point`` fires —
    for IO boundaries whose callers catch/classify ``OSError``."""
    if should_fire(point):
        if err_no is not None:
            raise OSError(err_no, f"injected fault: {point}")
        raise OSError(f"injected fault: {point}")


def latency_s(point: str) -> float:
    """Injected latency-spike duration in seconds (0.0 when not firing).
    Magnitude via ``REPRO_FAULT_LATENCY_MS`` (default 25 ms)."""
    if not should_fire(point):
        return 0.0
    try:
        ms = float(os.environ.get(LATENCY_ENV, DEFAULT_LATENCY_MS))
    except ValueError:
        ms = DEFAULT_LATENCY_MS
    return max(ms, 0.0) / 1e3


def counts() -> dict:
    """Arrival/fired counters of the active plan (observability + tests)."""
    return _registry.plan().counts()
