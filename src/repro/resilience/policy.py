"""Per-tenant resilience configuration and runtime state.

:class:`ResiliencePolicy` is the knob set (immutable, passed at tenant
registration); :class:`TenantResilience` is the live state — one circuit
breaker per unreliable tenant dependency (the canonicalizer LLM and the
OLAP backend; the cold tier's breaker lives on the :class:`TieredStore`
that owns the disk).  ``enabled=False`` keeps the error *containment*
(structured results, never raw exceptions) but turns off *recovery*
(retries, breakers, stale-on-error serving) — the chaos bench's
"resilience off" baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .primitives import CircuitBreaker


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Resilience knobs for one tenant."""

    enabled: bool = True
    # retry (idempotent stages: backend execute; spill/cold-read retries are
    # configured on the TieredStore)
    execute_attempts: int = 3
    retry_base_s: float = 0.01
    retry_max_s: float = 0.25
    # per-dependency circuit breakers
    breaker_failures: int = 5
    breaker_recovery_s: float = 1.0
    breaker_half_open_probes: int = 1
    # on backend failure, serve a TTL-expired cached answer with explicit
    # 'degraded:stale' provenance instead of an error (never silently)
    serve_stale: bool = True

    @classmethod
    def disabled(cls) -> "ResiliencePolicy":
        return cls(enabled=False)


class TenantResilience:
    """Live resilience state for one tenant: policy + dependency breakers."""

    def __init__(self, policy: Optional[ResiliencePolicy] = None):
        self.policy = policy if policy is not None else ResiliencePolicy()
        p = self.policy
        self.canonicalizer = CircuitBreaker(
            "canonicalizer", failure_threshold=p.breaker_failures,
            recovery_s=p.breaker_recovery_s,
            half_open_probes=p.breaker_half_open_probes)
        self.backend = CircuitBreaker(
            "backend", failure_threshold=p.breaker_failures,
            recovery_s=p.breaker_recovery_s,
            half_open_probes=p.breaker_half_open_probes)

    def breakers(self) -> dict[str, dict]:
        return {
            "canonicalizer": self.canonicalizer.snapshot(),
            "backend": self.backend.snapshot(),
        }
