"""CacheService — the batch-first, multi-tenant semantic-caching service.

The paper's middleware is a *shared* cache serving many clients over multiple
star schemas.  ``CacheService`` hosts that sharing explicitly: a tenant
registry (schema + backend + cache + safety policy + NL canonicalizer +
governed-metric layer + stats per tenant, with strict key-space isolation),
a batch-first request surface (``submit_batch`` routes all of a dashboard
refresh's cache misses through one shared-scan ``execute_batch`` launch),
and a lifecycle API (``advance_snapshot`` / ``invalidate`` / ``warm``) that
reuses the same staged pipeline as live traffic.

    svc = CacheService()
    svc.register_tenant("analytics", schema=wl.schema,
                        backend=OlapExecutor(wl.dataset), nl=llm)
    results = svc.submit_batch([
        QueryRequest(sql=tile_sql, tenant="analytics") for tile_sql in tiles
    ])
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

from ..analysis.sanitizer import make_lock
from ..cluster import CacheCluster
from ..core.cache import SemanticCache
from ..core.metrics import MetricLayer
from ..core.nl_canon import NLCanonicalizer
from ..core.refresh import merge_tables, refreshable
from ..core.safety import SafetyPolicy
from ..core.schema import StarSchema
from ..core.sql_canon import SQLCanonicalizer
from ..core.validator import SignatureValidator
from ..obs import ObsConfig, ObsPlane
from ..resilience import faults
from ..resilience.policy import ResiliencePolicy, TenantResilience
from .api import (DEFAULT_TENANT, Backend, QueryRequest, QueryResult,
                  ReadWriteGate, RefreshReport, TenantStats)
from .pipeline import run_pipeline


def _accepts_partition(execute_batch) -> bool:
    """True when a backend's ``execute_batch`` supports the ``partition``
    kwarg of the current :class:`BatchBackend` protocol — probed *before*
    appending delta rows, because discovering a pre-partition wrapper via
    TypeError afterwards would leave the grown dataset with a stale cache."""
    if execute_batch is None:
        return False
    import inspect

    try:
        params = inspect.signature(execute_batch).parameters
    except (TypeError, ValueError):  # builtins/C callables: assume current
        return True
    return "partition" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values())


@dataclasses.dataclass
class Tenant:
    """One registered tenant: its schema universe and serving machinery."""

    name: str
    schema: StarSchema
    backend: Backend
    cache: "SemanticCache | CacheCluster"
    nl: Optional[NLCanonicalizer]
    policy: SafetyPolicy
    metrics: Optional[MetricLayer]
    # mutated only by lifecycle operations while they hold the exclusive
    # write gate; request threads read it when tagging stores
    snapshot_id: str  # guarded-by: external[tenant ReadWriteGate.write]
    sql_canon: SQLCanonicalizer
    validator: SignatureValidator
    stats: TenantStats
    # read side held around backend executions; write side held while
    # advance_snapshot mutates the dataset under concurrent request threads
    gate: ReadWriteGate = dataclasses.field(default_factory=ReadWriteGate)
    # resilience plane: per-dependency circuit breakers + the tenant's
    # recovery policy (retries, deadlines, stale-on-error)
    resilience: TenantResilience = dataclasses.field(
        default_factory=TenantResilience)
    # observability plane, shared with the owning service (register_tenant
    # overwrites the default); the pipeline reads its tracer per batch
    obs: ObsPlane = dataclasses.field(default_factory=ObsPlane)


class CacheService:
    def __init__(self, obs: "Optional[ObsPlane | ObsConfig]" = None):
        # registration is rare but may race live traffic (an operator adding
        # a tenant while request threads resolve others): writes serialize
        # on _reg_lock; reads are lock-free dict probes (GIL-atomic)
        self._tenants: dict[str, Tenant] = {}  # guarded-by: self._reg_lock
        self._reg_lock = make_lock("CacheService._reg_lock")
        # warm-restart root directory (one store subdir per tenant); set by
        # open(), cleared by close(); reads are lock-free like _tenants
        self._store_path: Optional[str] = None  # guarded-by: self._reg_lock
        self._write_through = True  # guarded-by: self._reg_lock
        # one observability plane for the whole service: every tenant shares
        # its tracer / metrics registry / audit log
        if isinstance(obs, ObsConfig):
            obs = ObsPlane(obs)
        self.obs: ObsPlane = obs if obs is not None else ObsPlane()

    # ----------------------------------------------------------- tenants
    def register_tenant(
        self,
        name: str = DEFAULT_TENANT,
        *,
        schema: StarSchema,
        backend: Backend,
        cache: "Optional[SemanticCache | CacheCluster]" = None,
        nl: Optional[NLCanonicalizer] = None,
        policy: SafetyPolicy = SafetyPolicy(),
        metrics: Optional[MetricLayer] = None,
        snapshot_id: str = "snap0",
        shards: Optional[int] = None,
        resilience: "Optional[ResiliencePolicy | TenantResilience]" = None,
    ) -> Tenant:
        """Register a tenant.  Tenants are isolated structurally (each has
        its own cache instance) and by key space (request ``scope`` is part
        of the signature hash), so one tenant can never serve another's
        entries.

        ``shards=N`` serves the tenant from an N-shard
        :class:`repro.cluster.CacheCluster` (family-partitioned locks,
        single-flight miss dedup, concurrent per-shard miss execution).  A
        plain ``cache=`` template passed alongside it contributes its
        configuration (capacity, derivation flags, level mapper) to every
        shard; ``shards=1`` is behavior-compatible with the unsharded path.
        A pre-built ``CacheCluster`` may also be passed directly as
        ``cache=``.

        ``resilience=`` takes a :class:`ResiliencePolicy` (or a pre-built
        :class:`TenantResilience`) controlling the tenant's recovery
        behavior — retry budgets, circuit-breaker thresholds, deadline
        shedding, stale-on-error serving.  Error *containment* (structured
        degraded/error results, never raw exceptions from the pipeline) is
        unconditional; ``ResiliencePolicy.disabled()`` turns off only the
        recovery machinery."""
        if isinstance(resilience, ResiliencePolicy):
            resilience = TenantResilience(resilience)
        if shards is not None:
            if isinstance(cache, CacheCluster):
                if cache.num_shards != shards:
                    cache.set_shards(shards)
            elif cache is not None:
                cache = CacheCluster.from_template(cache, shards)
            else:
                cache = CacheCluster(schema, shards)
        t = Tenant(
            name=name, schema=schema, backend=backend,
            cache=cache if cache is not None else SemanticCache(schema),
            nl=nl, policy=policy, metrics=metrics, snapshot_id=snapshot_id,
            sql_canon=SQLCanonicalizer(schema),
            validator=SignatureValidator(schema),
            stats=TenantStats(),
            resilience=(resilience if resilience is not None
                        else TenantResilience()),
            obs=self.obs,
        )
        if self.obs.audit is not None:
            set_audit = getattr(t.cache, "set_audit", None)
            if set_audit is not None:
                set_audit(self.obs.audit, tenant=name)
        with self._reg_lock:
            # check-then-insert must be one atomic step: two concurrent
            # registrations of the same name used to both pass the check
            # and silently overwrite each other
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = t
        if self._store_path is not None:
            # the service is open for warm restart: give the new tenant its
            # cold tier right away (replays any prior run's entries)
            self._attach_store(t)
        return t

    def tenant(self, name: str = DEFAULT_TENANT) -> Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}: registered = "
                           f"{sorted(self._tenants)}")
        return t

    def tenants(self) -> list[str]:
        return sorted(self._tenants)

    # ------------------------------------------------------- warm restart
    def open(self, path: str, *, write_through: bool = True) -> dict:
        """Open the service's durable store root: every registered tenant
        (and every tenant registered later) gets a tiered cold store under
        ``<path>/<tenant>/``, replaying whatever a previous run persisted —
        the warm-restart half of the ``open``/``close`` lifecycle.  With
        ``write_through`` (default) stores/refreshes also spill write-behind,
        so a kill loses at most the in-flight spill window, not the working
        set.  Returns ``{tenant: adopted_entry_count}``."""
        import os

        with self._reg_lock:
            self._store_path = os.path.abspath(path)
            self._write_through = write_through
            tenants = list(self._tenants.values())
        return {t.name: self._attach_store(t) for t in tenants}

    def close(self) -> dict:
        """Graceful shutdown of the durable store: spill every hot entry
        (incremental — clean versions cost a metadata record), drain the
        write-behind queue, compact the manifest, and detach.  Returns
        ``{tenant: persisted_entry_count}``."""
        with self._reg_lock:
            self._store_path = None
            tenants = list(self._tenants.values())
        out = {}
        for t in tenants:
            store = getattr(t.cache, "store", None)
            if store is None:
                out[t.name] = 0
                continue
            with t.gate.write:  # exclusive: no request mid-pipeline
                out[t.name] = t.cache.persist_hot()
                t.cache.detach_store()
            store.flush()
            store.close()
        return out

    def _attach_store(self, t: Tenant) -> int:
        """Build + replay this tenant's tiered store and attach it."""
        import os

        from ..storage.engine import TieredStore

        root = self._store_path
        if root is None:
            return 0
        store = TieredStore(os.path.join(root, t.name))
        entries = store.open()
        with t.gate.write:
            return t.cache.attach_store(
                store, entries,
                write_through=getattr(self, "_write_through", True))

    # ----------------------------------------------------------- requests
    def submit(self, request: QueryRequest) -> QueryResult:
        """Single-request convenience wrapper: a one-element batch."""
        return self.submit_batch([request])[0]

    def submit_batch(self, requests: Sequence[QueryRequest]) -> list[QueryResult]:
        """Run a batch through the staged pipeline, preserving order.

        Requests are partitioned by tenant; each tenant partition flows
        through canonicalize -> validate -> gate -> lookup -> plan ->
        execute -> store as one unit, so misses sharing a dataset are
        deduped and executed by a single shared-scan ``execute_batch``
        launch per agg block.
        """
        requests = list(requests)
        by_tenant: dict[str, list[int]] = {}
        for i, r in enumerate(requests):
            by_tenant.setdefault(r.tenant, []).append(i)
        # resolve every tenant before any partition runs: an unknown tenant
        # must reject the whole batch up front, not halfway through with
        # other tenants' side effects already committed
        tenants = {name: self.tenant(name) for name in by_tenant}
        out: list[Optional[QueryResult]] = [None] * len(requests)
        for name, idxs in by_tenant.items():
            results = run_pipeline(tenants[name], [requests[i] for i in idxs])
            for i, res in zip(idxs, results):
                out[i] = res
        return out  # type: ignore[return-value]

    def warm(self, requests: Sequence[QueryRequest]) -> list[QueryResult]:
        """Prefill the cache through the very same pipeline as live traffic
        (canonicalization, validation, and safety gating all apply — warming
        can never plant an entry a live request couldn't have created).
        ``read_only`` requests are rejected since a warm-up that cannot
        store is a no-op."""
        for r in requests:
            if r.read_only:
                raise ValueError("warm() requests must allow stores "
                                 "(read_only=True is a no-op for warming)")
        return self.submit_batch(requests)

    # ---------------------------------------------------------- lifecycle
    def advance_snapshot(
        self,
        tenant: str = DEFAULT_TENANT,
        snapshot_id: str = "",
        updated_start: Optional[str] = None,
        updated_end: Optional[str] = None,
        *,
        delta: Optional[Mapping] = None,
        refresh: bool = True,
        recompute_fallbacks: bool = True,
    ) -> RefreshReport:
        """New data arrived for a tenant: ingest it and bring the cache
        current.

        Without ``delta`` this is the §6.2 drop rule: entries the update can
        affect (open-ended windows always; closed windows only when they
        intersect [updated_start, updated_end)) are invalidated.

        With ``delta`` — a mapping of fact column name to the new rows'
        values — the rows are appended to the backend dataset and affected
        entries are *refreshed in place* instead of dropped: all composable
        affected signatures are executed as one fused batch over just the
        delta partition and their delta tables merged into the cached tables
        (``core.refresh``), so a live dashboard keeps its working set at a
        cost proportional to the delta.  Non-composable affected entries
        (AVG / COUNT DISTINCT / HAVING / ORDER BY / LIMIT) are recomputed
        over the full table (or just dropped when
        ``recompute_fallbacks=False``).  ``refresh=False`` appends the delta
        but applies the plain drop rule — the pre-incremental behavior, kept
        as the benchmark baseline.

        When no update extent is given it is derived from the delta's date
        column, so closed windows outside the ingested date range survive
        untouched.
        """
        t = self.tenant(tenant)
        if delta is None:
            # the snapshot advance (id bump + drop rule) runs under the
            # exclusive write gate: request threads tag stores with
            # t.snapshot_id, and a torn read during the bump would tag a
            # fresh store with a half-advanced snapshot
            with t.gate.write:
                if snapshot_id:
                    t.snapshot_id = snapshot_id
                rep = RefreshReport(
                    tenant=t.name, snapshot_id=t.snapshot_id,
                    updated_start=updated_start, updated_end=updated_end)
                before = len(t.cache)
                rep.dropped = t.cache.invalidate_snapshot(
                    updated_start, updated_end)
                rep.unaffected = before - rep.dropped
                return rep
        ds = getattr(t.backend, "ds", None)
        if ds is None or not hasattr(ds, "append_rows") \
                or not _accepts_partition(getattr(t.backend, "execute_batch", None)):
            # checked before the append: failing *after* rows committed would
            # leave the cache stale relative to the grown dataset
            raise TypeError(
                "advance_snapshot(delta=...) needs an OlapExecutor-style "
                "backend exposing its Dataset as .ds and a partition-capable "
                "execute_batch")
        with t.gate.write:  # exclusive vs request-thread backend scans
            if snapshot_id:
                t.snapshot_id = snapshot_id
            rep = RefreshReport(tenant=t.name, snapshot_id=t.snapshot_id,
                                updated_start=updated_start,
                                updated_end=updated_end)
            return self._advance_with_delta(
                t, rep, ds, delta, updated_start, updated_end,
                refresh=refresh, recompute_fallbacks=recompute_fallbacks)

    def _advance_with_delta(self, t, rep, ds, delta, updated_start,
                            updated_end, *, refresh, recompute_fallbacks):
        """Dataset-mutating half of :meth:`advance_snapshot`; runs under the
        tenant's exclusive write gate so a concurrent request thread can
        never scan half-appended columns or lose its executor plan memos
        mid-execution."""
        part = ds.append_rows(delta, snapshot_id=t.snapshot_id)
        rep.appended_rows = part.num_rows
        # The delta's actual date extent is ground truth: union it with a
        # caller-supplied range so a too-narrow claim can never leave an
        # intersecting entry stale-but-served (ISO strings compare
        # correctly).  A *half-open* caller range stays as given — one
        # missing bound means unknown extent, and affected_keys treats that
        # conservatively (every entry refreshes).
        if part.date_start is not None:
            if updated_start is None and updated_end is None:
                rep.updated_start, rep.updated_end = part.date_start, part.date_end
            elif updated_start is not None and updated_end is not None:
                rep.updated_start = min(updated_start, part.date_start)
                rep.updated_end = max(updated_end, part.date_end)
        affected = t.cache.affected_keys(rep.updated_start, rep.updated_end)
        rep.unaffected = len(t.cache) - len(affected)
        if not refresh:
            for key in affected:
                t.cache.drop(key)
            rep.dropped = len(affected)
            return rep
        # snapshot the affected entries once: under the sharded cluster,
        # concurrent request threads can evict (or a rebalance can migrate) a
        # key between affected_keys() and this loop — a vanished entry simply
        # no longer needs refreshing.  ensure_loaded promotes demoted (cold-
        # tier) entries so the merge below has the actual table; the table is
        # captured here because a later eviction could demote it again.
        loader = getattr(t.cache, "ensure_loaded", t.cache.entry)
        mergeable, fallback = [], []  # lists of (key, entry, table)
        for k in affected:
            e = loader(k)
            if e is None or e.table is None:
                continue
            (mergeable if refreshable(e.signature)
             else fallback).append((k, e, e.table))

        def try_refresh(key, table, merged):
            try:
                t.cache.refresh_entry(key, table, t.snapshot_id, merged=merged)
                return 1
            except KeyError:  # evicted while we were computing its table
                return 0

        if mergeable:
            sigs = [e.signature for _, e, _ in mergeable]
            rows0 = getattr(t.backend, "rows_scanned", 0)
            deltas = t.backend.execute_batch(
                sigs, partition=(part.start_row, part.end_row))
            rep.delta_rows_scanned = getattr(t.backend, "rows_scanned", 0) - rows0
            t.stats.bump(backend_executions=len(sigs))
            for (key, e, base), sig, dtab in zip(mergeable, sigs, deltas):
                merged = merge_tables(sig, base, dtab)
                rep.refreshed += try_refresh(key, merged, True)
        if fallback:
            if recompute_fallbacks:
                sigs = [e.signature for _, e, _ in fallback]
                rows0 = getattr(t.backend, "rows_scanned", 0)
                tables = t.backend.execute_batch(sigs)
                rep.recompute_rows_scanned = \
                    getattr(t.backend, "rows_scanned", 0) - rows0
                t.stats.bump(backend_executions=len(sigs))
                for (key, _, _), table in zip(fallback, tables):
                    rep.recomputed += try_refresh(key, table, False)
            else:
                for key, _, _ in fallback:
                    t.cache.drop(key)
                rep.dropped = len(fallback)
        return rep

    def invalidate(self, tenant: str = DEFAULT_TENANT, *,
                   schema_change: bool = False,
                   updated_start: Optional[str] = None,
                   updated_end: Optional[str] = None) -> int:
        """Explicit invalidation: full drop on schema change, else the same
        window-intersection rule as ``advance_snapshot``."""
        t = self.tenant(tenant)
        if schema_change:
            return t.cache.invalidate_schema_change()
        return t.cache.invalidate_snapshot(updated_start, updated_end)

    # -------------------------------------------------------------- stats
    def stats(self, tenant: Optional[str] = None, *,
              include_entries: bool = False) -> dict:
        """Structured stats: per-tenant service counters (including per-stage
        p50/p95 pipeline latency), cache counters (including derivation
        candidates-scanned vs plans-attempted), per-tier storage gauges
        (hot/cold bytes, promotions, demotions, spill queue depth), and the
        request-plane front-end counters (SQL template cache, NL memo).
        ``include_entries`` adds a capped per-entry summary (age, decayed
        hits, cost, policy score) so eviction inputs are observable."""
        if tenant is not None:
            t = self.tenant(tenant)
            d = {"service": t.stats.to_dict(), "cache": t.cache.stats.to_dict(),
                 "frontend": {"template_cache": t.sql_canon.template_stats()}}
            if t.nl is not None and hasattr(t.nl, "memo_hits"):
                d["frontend"]["nl_memo"] = {
                    "calls": t.nl.calls, "memo_hits": t.nl.memo_hits}
            if hasattr(t.cache, "tier_stats"):
                ts = t.cache.tier_stats()
                store = ts.get("store")
                d["tiers"] = ts
                d["tiers"]["spill_queue_depth"] = (
                    store["spill_queue_depth"] if store else 0)
            if include_entries and hasattr(t.cache, "entries_summary"):
                d["entries"] = t.cache.entries_summary()
            if hasattr(t.cache, "stats_by_shard"):
                d["cluster"] = t.cache.describe()
                d["cluster"]["by_shard"] = t.cache.stats_by_shard()
            if hasattr(t.backend, "stats"):
                # executor counters: totals, memo sizes, per-partition scan
                # accounting when the partition-parallel scan plane is active
                d["backend"] = t.backend.stats()
            return d
        return {name: self.stats(name, include_entries=include_entries)
                for name in self.tenants()}

    # ------------------------------------------------------------ metrics
    _BREAKER_STATES = {"closed": 0.0, "half_open": 1.0, "open": 2.0}

    def metrics(self, fmt: str = "prometheus"):
        """Exposition endpoint for the observability plane: mirror every
        existing counter surface (per-tenant service counters, stage latency
        histograms, cache counters, tier/store gauges, breaker states,
        cluster shard gauges, fault-injection counters, the tracer's and
        audit log's own counters) onto the shared
        :class:`~repro.obs.MetricsRegistry`, then render it.

        Mirroring happens here, at exposition time, from the sources of
        truth that requests already maintain — the request hot path never
        double-bumps a registry instrument.  ``fmt="prometheus"`` returns
        the text exposition format (v0.0.4); ``fmt="json"`` a structured
        dict."""
        self._mirror_metrics()
        reg = self.obs.registry
        if fmt == "prometheus":
            return reg.render_prometheus()
        if fmt == "json":
            return reg.render_json()
        raise ValueError(f"unknown metrics format {fmt!r} "
                         "(expected 'prometheus' or 'json')")

    def _mirror_metrics(self) -> None:
        reg = self.obs.registry
        with self._reg_lock:
            tenants = list(self._tenants.values())
        for t in tenants:
            self._mirror_tenant(reg, t)
        fc = faults.counts()
        arr = reg.counter("fault_arrivals_total",
                          "arrivals at fault-injection points", ("point",))
        fired = reg.counter("fault_fired_total",
                            "faults actually injected", ("point",))
        for point, n in fc["arrivals"].items():
            arr.set_total(n, point=point)
        for point, n in fc["fired"].items():
            fired.set_total(n, point=point)
        tr = self.obs.tracer.stats()
        reg.counter("traces_seen_total",
                    "requests considered for sampling").set_total(tr["seen"])
        reg.counter("traces_sampled_total",
                    "requests traced").set_total(tr["sampled"])
        reg.counter("trace_spans_total",
                    "spans emitted").set_total(tr["spans_emitted"])
        reg.gauge("trace_ring_len",
                  "spans currently buffered").set(tr["ring_len"])
        if self.obs.audit is not None:
            reg.counter("audit_events_total",
                        "cache lifecycle events emitted").set_total(
                self.obs.audit.stats()["emitted"])

    def _mirror_tenant(self, reg, t: Tenant) -> None:
        name = t.name
        svc = t.stats.to_dict()
        svc.pop("stages_ms", None)
        for k, v in svc.items():
            reg.counter(f"service_{k}_total", f"pipeline counter: {k}",
                        ("tenant",)).set_total(v, tenant=name)
        stage_h = reg.histogram("stage_latency_ms",
                                "per-stage pipeline latency",
                                ("tenant", "stage"))
        for stage, hist in t.stats.stage_histograms().items():
            stage_h.merge_snapshot(hist, tenant=name, stage=stage)
        for k, v in t.cache.stats.to_dict().items():
            if k in ("bytes_cached", "bytes_cold", "hit_rate"):
                reg.gauge(f"cache_{k}", f"cache gauge: {k}",
                          ("tenant",)).set(v, tenant=name)
            else:
                reg.counter(f"cache_{k}_total", f"cache counter: {k}",
                            ("tenant",)).set_total(v, tenant=name)
        for k, v in t.sql_canon.template_stats().items():
            if k in ("templates", "bindings"):
                reg.gauge(f"frontend_template_{k}",
                          f"template cache footprint: {k}",
                          ("tenant",)).set(v, tenant=name)
            else:
                reg.counter(f"frontend_template_{k}_total",
                            f"template cache counter: {k}",
                            ("tenant",)).set_total(v, tenant=name)
        if t.nl is not None and hasattr(t.nl, "memo_hits"):
            reg.counter("frontend_nl_calls_total", "NL canonicalizer calls",
                        ("tenant",)).set_total(t.nl.calls, tenant=name)
            reg.counter("frontend_nl_memo_hits_total", "NL memo hits",
                        ("tenant",)).set_total(t.nl.memo_hits, tenant=name)
        breakers = dict(t.resilience.breakers())
        if hasattr(t.cache, "tier_stats"):
            ts = t.cache.tier_stats()
            for k in ("hot_entries", "cold_entries", "hot_bytes",
                      "cold_bytes"):
                reg.gauge(f"tier_{k}", f"tier gauge: {k}",
                          ("tenant",)).set(ts[k], tenant=name)
            store = ts.get("store")
            if store:
                for k, v in store.items():
                    if isinstance(v, bool) or not isinstance(v, (int, float)):
                        continue
                    reg.gauge(f"store_{k}", f"durable store gauge: {k}",
                              ("tenant",)).set(v, tenant=name)
                cold = store.get("cold_breaker")
                if cold is not None:
                    breakers["cold_tier"] = cold
        bstate = reg.gauge("breaker_state",
                           "circuit breaker state: 0=closed 1=half_open "
                           "2=open", ("tenant", "dependency"))
        bopens = reg.counter("breaker_opens_total", "breaker open events",
                             ("tenant", "dependency"))
        brej = reg.counter("breaker_rejections_total",
                           "calls rejected while open",
                           ("tenant", "dependency"))
        for dep, snap in breakers.items():
            bstate.set(self._BREAKER_STATES.get(snap.get("state"), 0.0),
                       tenant=name, dependency=dep)
            bopens.set_total(snap.get("opens", 0), tenant=name,
                             dependency=dep)
            brej.set_total(snap.get("rejections", 0), tenant=name,
                           dependency=dep)
        if hasattr(t.cache, "stats_by_shard"):
            g_entries = reg.gauge("shard_entries", "entries per shard",
                                  ("tenant", "shard"))
            g_inflight = reg.gauge("shard_inflight",
                                   "single-flight leaders per shard",
                                   ("tenant", "shard"))
            for d in t.cache.stats_by_shard():
                g_entries.set(d["entries"], tenant=name,
                              shard=str(d["shard"]))
                g_inflight.set(d["inflight"], tenant=name,
                               shard=str(d["shard"]))

    def health(self, tenant: Optional[str] = None) -> dict:
        """The resilience plane's health surface: per-tenant circuit-breaker
        snapshots (canonicalizer, backend, and the cold tier's breaker when a
        durable store is attached), degraded/failure/retry/shed counters, and
        storage error gauges (spill retries/drops, WAL + cold-read errors).
        ``status`` is ``ok`` when every breaker is closed and nothing is
        degrading, ``degraded`` otherwise — a load balancer's readiness
        probe, not a liveness one (a degraded tenant still serves)."""
        if tenant is not None:
            t = self.tenant(tenant)
            breakers = t.resilience.breakers()
            d: dict = {
                "policy_enabled": t.resilience.policy.enabled,
                "breakers": breakers,
            }
            svc = t.stats.to_dict()
            d["counters"] = {k: svc.get(k, 0) for k in (
                "retries", "degraded", "shed", "failures", "store_errors")}
            storage: dict = {}
            store = getattr(t.cache, "store", None)
            if store is not None and hasattr(store, "stats"):
                ss = store.stats()
                for k in ("spill_errors", "spill_retries", "spill_last_error",
                          "read_errors", "worker_deaths", "wal_append_errors"):
                    if k in ss:
                        storage[k] = ss[k]
                cold = ss.get("cold_breaker")
                if cold is not None:
                    breakers["cold_tier"] = cold
            if storage:
                d["storage"] = storage
            open_breakers = [name for name, b in breakers.items()
                             if b.get("state") != "closed"]
            degrading = bool(open_breakers) \
                or d["counters"]["degraded"] > 0 \
                or storage.get("spill_last_error") is not None
            d["status"] = "degraded" if degrading else "ok"
            d["open_breakers"] = open_breakers
            return d
        return {name: self.health(name) for name in self.tenants()}
