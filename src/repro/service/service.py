"""CacheService — the batch-first, multi-tenant semantic-caching service.

The paper's middleware is a *shared* cache serving many clients over multiple
star schemas.  ``CacheService`` hosts that sharing explicitly: a tenant
registry (schema + backend + cache + safety policy + NL canonicalizer +
governed-metric layer + stats per tenant, with strict key-space isolation),
a batch-first request surface (``submit_batch`` routes all of a dashboard
refresh's cache misses through one shared-scan ``execute_batch`` launch),
and a lifecycle API (``advance_snapshot`` / ``invalidate`` / ``warm``) that
reuses the same staged pipeline as live traffic.

    svc = CacheService()
    svc.register_tenant("analytics", schema=wl.schema,
                        backend=OlapExecutor(wl.dataset), nl=llm)
    results = svc.submit_batch([
        QueryRequest(sql=tile_sql, tenant="analytics") for tile_sql in tiles
    ])
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..core.cache import SemanticCache
from ..core.metrics import MetricLayer
from ..core.nl_canon import NLCanonicalizer
from ..core.safety import SafetyPolicy
from ..core.schema import StarSchema
from ..core.sql_canon import SQLCanonicalizer
from ..core.validator import SignatureValidator
from .api import DEFAULT_TENANT, Backend, QueryRequest, QueryResult, TenantStats
from .pipeline import run_pipeline


@dataclasses.dataclass
class Tenant:
    """One registered tenant: its schema universe and serving machinery."""

    name: str
    schema: StarSchema
    backend: Backend
    cache: SemanticCache
    nl: Optional[NLCanonicalizer]
    policy: SafetyPolicy
    metrics: Optional[MetricLayer]
    snapshot_id: str
    sql_canon: SQLCanonicalizer
    validator: SignatureValidator
    stats: TenantStats


class CacheService:
    def __init__(self):
        self._tenants: dict[str, Tenant] = {}

    # ----------------------------------------------------------- tenants
    def register_tenant(
        self,
        name: str = DEFAULT_TENANT,
        *,
        schema: StarSchema,
        backend: Backend,
        cache: Optional[SemanticCache] = None,
        nl: Optional[NLCanonicalizer] = None,
        policy: SafetyPolicy = SafetyPolicy(),
        metrics: Optional[MetricLayer] = None,
        snapshot_id: str = "snap0",
    ) -> Tenant:
        """Register a tenant.  Tenants are isolated structurally (each has
        its own cache instance) and by key space (request ``scope`` is part
        of the signature hash), so one tenant can never serve another's
        entries."""
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already registered")
        t = Tenant(
            name=name, schema=schema, backend=backend,
            cache=cache if cache is not None else SemanticCache(schema),
            nl=nl, policy=policy, metrics=metrics, snapshot_id=snapshot_id,
            sql_canon=SQLCanonicalizer(schema),
            validator=SignatureValidator(schema),
            stats=TenantStats(),
        )
        self._tenants[name] = t
        return t

    def tenant(self, name: str = DEFAULT_TENANT) -> Tenant:
        t = self._tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}: registered = "
                           f"{sorted(self._tenants)}")
        return t

    def tenants(self) -> list[str]:
        return sorted(self._tenants)

    # ----------------------------------------------------------- requests
    def submit(self, request: QueryRequest) -> QueryResult:
        """Single-request convenience wrapper: a one-element batch."""
        return self.submit_batch([request])[0]

    def submit_batch(self, requests: Sequence[QueryRequest]) -> list[QueryResult]:
        """Run a batch through the staged pipeline, preserving order.

        Requests are partitioned by tenant; each tenant partition flows
        through canonicalize -> validate -> gate -> lookup -> plan ->
        execute -> store as one unit, so misses sharing a dataset are
        deduped and executed by a single shared-scan ``execute_batch``
        launch per agg block.
        """
        requests = list(requests)
        by_tenant: dict[str, list[int]] = {}
        for i, r in enumerate(requests):
            by_tenant.setdefault(r.tenant, []).append(i)
        # resolve every tenant before any partition runs: an unknown tenant
        # must reject the whole batch up front, not halfway through with
        # other tenants' side effects already committed
        tenants = {name: self.tenant(name) for name in by_tenant}
        out: list[Optional[QueryResult]] = [None] * len(requests)
        for name, idxs in by_tenant.items():
            results = run_pipeline(tenants[name], [requests[i] for i in idxs])
            for i, res in zip(idxs, results):
                out[i] = res
        return out  # type: ignore[return-value]

    def warm(self, requests: Sequence[QueryRequest]) -> list[QueryResult]:
        """Prefill the cache through the very same pipeline as live traffic
        (canonicalization, validation, and safety gating all apply — warming
        can never plant an entry a live request couldn't have created).
        ``read_only`` requests are rejected since a warm-up that cannot
        store is a no-op."""
        for r in requests:
            if r.read_only:
                raise ValueError("warm() requests must allow stores "
                                 "(read_only=True is a no-op for warming)")
        return self.submit_batch(requests)

    # ---------------------------------------------------------- lifecycle
    def advance_snapshot(
        self,
        tenant: str = DEFAULT_TENANT,
        snapshot_id: str = "",
        updated_start: Optional[str] = None,
        updated_end: Optional[str] = None,
    ) -> int:
        """New data arrived for a tenant: bump its snapshot id and drop the
        entries the update can affect (open-ended windows always; closed
        windows only when they intersect [updated_start, updated_end)).
        Returns the number of invalidated entries."""
        t = self.tenant(tenant)
        if snapshot_id:
            t.snapshot_id = snapshot_id
        return t.cache.invalidate_snapshot(updated_start, updated_end)

    def invalidate(self, tenant: str = DEFAULT_TENANT, *,
                   schema_change: bool = False,
                   updated_start: Optional[str] = None,
                   updated_end: Optional[str] = None) -> int:
        """Explicit invalidation: full drop on schema change, else the same
        window-intersection rule as ``advance_snapshot``."""
        t = self.tenant(tenant)
        if schema_change:
            return t.cache.invalidate_schema_change()
        return t.cache.invalidate_snapshot(updated_start, updated_end)

    # -------------------------------------------------------------- stats
    def stats(self, tenant: Optional[str] = None) -> dict:
        """Structured stats: per-tenant service counters + cache counters
        (the ``to_dict`` forms the satellite task asks for)."""
        if tenant is not None:
            t = self.tenant(tenant)
            return {"service": t.stats.to_dict(), "cache": t.cache.stats.to_dict()}
        return {name: self.stats(name) for name in self.tenants()}
