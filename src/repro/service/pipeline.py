"""The staged request pipeline (one tenant, one batch).

Every request — SQL, NL, governed metric, or pre-built signature; live
traffic or cache warm-up — passes through the same explicit stage sequence:

    canonicalize -> validate -> gate (NL safety) -> lookup -> plan ->
    execute -> store

Stages operate on the whole batch at once, which is what makes the service
batch-first rather than a loop over the single-query path:

* **canonicalize** groups NL requests sharing a ``now`` anchor into one
  ``canonicalize_batch`` call when the canonicalizer supports it (the
  serving engine decodes the whole group in one batched prefill/decode);
* **plan** dedups identical in-flight signatures — one backend execution
  serves every requester of the same intent within the batch;
* **execute** routes multi-miss groups through ``Backend.execute_batch``
  (one shared scan, a single fused kernel launch per agg block) instead of
  N serial ``execute`` calls.

When the tenant's cache is a :class:`repro.cluster.CacheCluster`, the
pipeline additionally becomes concurrency-aware:

* **lookup** runs as one scatter-gather batch (one lock acquisition per
  touched shard) and registers **single-flight** miss deduplication: a miss
  whose signature is already being computed by another thread *joins* that
  flight instead of racing the executor;
* **execute** partitions the batch's miss leaders by shard and runs each
  shard group's ``execute_batch`` concurrently (the backend's plan memos are
  idempotent, and its numpy/JAX kernels release the GIL);
* flight **followers** block on the owning flight after local work is done
  and fall back to executing themselves if the leader aborted — coalescing
  is an optimization, never a correctness dependency.

Each stage records its wall time per request; the outcome chain is kept in
``provenance`` so every decision is auditable from the ``QueryResult``.

**Failure containment** (the resilience plane): no dependency failure —
backend execute, canonicalizer call, storage write — escapes
:func:`run_pipeline` as a raw exception.  Failures resolve per-request to a
``status='degraded'`` result (a stale cached answer, explicitly tagged
``degraded:stale``) or a ``status='error'`` result carrying a typed
:class:`FailureInfo` — never a silent wrong answer, never a stack trace for
co-batched innocents.  The tenant's :class:`ResiliencePolicy` adds recovery
on top of containment: retry with backoff for the idempotent execute stage,
per-dependency circuit breakers with half-open probing, per-request deadline
budgets, and stale-on-error serving.  The chaos harness
(:mod:`repro.resilience.faults`) injects failures at each of these
boundaries so every one of those promises is testable deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, TYPE_CHECKING

from ..core.cache import LookupResult
from ..core.nl_canon import NLResult
from ..core.safety import gate_nl, verify_hit_time_window
from ..core.signature import Signature
from ..core.sql_canon import CanonicalizationError
from ..core.sqlparse import SQLSyntaxError, UnsupportedQuery
from ..obs.trace import Trace, adopt, span_ctx
from ..resilience import faults
from ..resilience.errors import FailureInfo, classify
from ..resilience.primitives import Deadline, backoff_delays
from .api import QueryRequest, QueryResult

if TYPE_CHECKING:  # pragma: no cover
    from .service import Tenant

STAGES = ("canonicalize", "validate", "gate", "lookup", "plan", "execute", "store")


@dataclasses.dataclass
class RequestState:
    """Mutable per-request pipeline state threaded through the stages."""

    req: QueryRequest
    origin: str
    sig: Optional[Signature] = None
    nl_res: Optional[NLResult] = None
    status: Optional[str] = None  # None while still flowing; set when decided
    table: object = None
    confidence: Optional[float] = None
    bypass_reason: Optional[str] = None
    source_origin: Optional[str] = None
    source_snapshot: Optional[str] = None
    store: bool = True
    # what the execute stage runs for a bypassed request: the raw SQL text,
    # the (validated) signature, or nothing
    bypass_exec: Optional[str] = None  # 'raw' | 'sig' | None
    batched: bool = False
    deduped: bool = False
    # single-flight state (cluster caches only): the registered flight for a
    # miss, and whether this request owns its computation
    flight: object = None
    flight_leader: bool = False
    stored: bool = False  # entry already put (flight leaders store early)
    # resilience state: the typed failure record (for degraded/error
    # outcomes) and the request's wall-clock budget
    error: Optional[FailureInfo] = None
    deadline: Optional[Deadline] = None
    provenance: list = dataclasses.field(default_factory=list)
    timings: dict = dataclasses.field(default_factory=dict)
    # observability: set when this request was head-sampled.  Stage spans
    # are emitted at finalize time from ``timings``/``provenance`` (no
    # second clock read per stage); ``stage_attrs`` collects extra span
    # attributes stages want on their finalize-time span (adoption links,
    # resilience outcomes)
    trace: Optional[Trace] = None
    trace_wall0: float = 0.0  # wall clock at trace start (span start_s base)
    trace_t0: float = 0.0  # perf_counter at trace start (root span duration)
    stage_attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def pending(self) -> bool:
        return self.status is None

    def add_ms(self, stage: str, ms: float) -> None:
        self.timings[stage] = self.timings.get(stage, 0.0) + ms

    def bypass(self, reason: str, exec_mode: Optional[str] = None) -> None:
        self.status = "bypass"
        self.bypass_reason = reason
        self.bypass_exec = exec_mode
        self.store = False
        self.provenance.append(f"bypass:{reason.split(';')[0][:60]}")


def run_pipeline(tenant: "Tenant", requests: list[QueryRequest]) -> list[QueryResult]:
    states = [RequestState(req=r, origin=r.kind) for r in requests]
    for s in states:
        if s.req.deadline_ms is not None:
            s.deadline = Deadline.after_ms(s.req.deadline_ms)
    tracer = tenant.obs.tracer
    if tracer.enabled and tracer.period:
        # head-based sampling: the keep/drop decision is made before any
        # span exists; unsampled requests then pay `s.trace is None` checks
        # and nothing else.  The tracer's countdown is decremented inline
        # (batch-at-once) because this sits on the warm-hit p50 path: the
        # common not-due case is one integer subtract + compare, and only
        # when a sample is due does the batch take the slow path below.
        c = tracer.countdown = tracer.countdown - len(states)
        if c <= 0:
            # one sample per period boundary crossed, taken from the front
            # of the batch (deterministic pacing, Tracer.start_trace
            # semantics); the countdown carries the remainder forward
            period = tracer.period
            due = min(len(states), (-c) // period + 1)
            tracer.countdown = c + due * period
            for s in states[:due]:
                s.trace = tracer.make_trace()
                s.trace_wall0 = time.time()
                s.trace_t0 = time.perf_counter()
    tenant.stats.bump(requests=len(states), batches=1)
    try:
        for name, stage in (("canonicalize", _stage_canonicalize),
                            ("validate", _stage_validate),
                            ("gate", _stage_gate),
                            ("lookup", _stage_lookup),
                            ("execute", _stage_plan_and_execute),
                            ("store", _stage_store)):
            try:
                stage(tenant, states)
            except Exception as e:  # noqa: BLE001 — containment boundary
                # a stage-level crash must not escape as a raw exception:
                # every still-pending request resolves to a typed error, and
                # the finally below wakes any followers this batch leads
                for s in states:
                    if s.pending:
                        _fail_state(tenant, s, name, "internal",
                                    f"{type(e).__name__}: {e}")
                break
    finally:
        # never strand a follower: if this batch dies mid-pipeline, every
        # flight it leads is failed so waiters wake up and fall back to
        # executing themselves
        fail = getattr(tenant.cache, "fail_flight", None)
        if fail is not None:
            for s in states:
                if s.flight is not None and s.flight_leader and not s.flight.done:
                    fail(s.flight,
                         RuntimeError("pipeline aborted before flight completion"))
    return [_finalize(tenant, s) for s in states]


# ------------------------------------------------------------ failure paths


def _peek_stale(tenant: "Tenant", sig: Optional[Signature]):
    """Best-effort fetch of a TTL-expired cached table for degraded serving.
    Returns None when the cache keeps no stale copy (or cannot peek) — the
    degraded path must itself never raise."""
    if sig is None:
        return None
    peek = getattr(tenant.cache, "peek_stale", None)
    if peek is None:
        return None
    try:
        return peek(sig)
    except Exception:  # noqa: BLE001 — last-resort path, swallow and miss
        return None


def _conclude_failure(tenant: "Tenant", s: RequestState, stage: str,
                      kind: str, message: str, *, retries: int = 0,
                      breaker: Optional[str] = None,
                      shed: bool = False) -> None:
    """Resolve a failed request to a structured outcome: a ``degraded``
    result serving a TTL-expired cached answer (explicitly tagged, never
    silent) when the policy allows and a stale copy exists, else a typed
    ``error`` result.  Either way the caller gets a ``QueryResult`` carrying
    a :class:`FailureInfo` — raw exceptions stop here."""
    info = FailureInfo(stage=stage, kind=kind, message=message,
                       retries=retries, breaker=breaker)
    s.store = False
    s.error = info
    extra = {"shed": 1} if shed else {}
    pol = tenant.resilience.policy
    if pol.enabled and pol.serve_stale and not s.req.refresh:
        stale = _peek_stale(tenant, s.sig)
        if stale is not None:
            info.degraded = True
            s.status = "degraded"
            s.table = stale
            s.provenance.append("degraded:stale")
            s.provenance.append(f"failure:{info.brief()}")
            tenant.stats.bump(degraded=1, **extra)
            return
    s.status = "error"
    s.table = None
    s.provenance.append(f"failure:{info.brief()}")
    tenant.stats.bump(failures=1, **extra)


# the stage-crash containment boundary uses the same conclusion logic
_fail_state = _conclude_failure


# ------------------------------------------------------------- canonicalize


def _stage_canonicalize(tenant: "Tenant", states: list[RequestState]) -> None:
    nl_states = [s for s in states if s.origin == "nl"]
    _canonicalize_nl(tenant, nl_states)
    for s in states:
        if s.origin == "nl":
            continue
        t0 = time.perf_counter()
        try:
            if s.origin == "sql":
                s.sig = tenant.sql_canon.canonicalize(s.req.sql, scope=s.req.scope)
            elif s.origin == "metric":
                if tenant.metrics is None:
                    raise CanonicalizationError("no metric layer configured")
                s.sig = tenant.metrics.expand(
                    s.req.metric_id, levels=s.req.levels, filters=s.req.filters,
                    time_window=s.req.time_window, order_by=s.req.order_by,
                    limit=s.req.limit, scope=s.req.scope)
            else:  # pre-built signature
                s.sig = s.req.signature
                if s.req.scope is not None:
                    s.sig = s.sig.replace(scope=s.req.scope)
        except (UnsupportedQuery, SQLSyntaxError, CanonicalizationError, KeyError) as e:
            s.add_ms("canonicalize", (time.perf_counter() - t0) * 1e3)
            # raw-SQL bypasses still run on the backend; metric/signature
            # failures have nothing safe to execute
            s.bypass(str(e), "raw" if s.origin == "sql" else None)
            continue
        s.add_ms("canonicalize", (time.perf_counter() - t0) * 1e3)
        s.provenance.append(f"canonicalize:{s.origin}")


def _canonicalize_nl(tenant: "Tenant", states: list[RequestState]) -> None:
    if not states:
        return
    if tenant.nl is None:
        for s in states:
            s.add_ms("canonicalize", 0.0)
            s.bypass("no NL canonicalizer configured")
        return
    pol = tenant.resilience.policy
    breaker = tenant.resilience.canonicalizer
    # shed requests whose deadline already expired before spending model time
    live: list[RequestState] = []
    for s in states:
        if pol.enabled and s.deadline is not None and s.deadline.expired:
            _conclude_failure(tenant, s, "canonicalize", "deadline",
                              "deadline expired before canonicalization",
                              shed=True)
        else:
            live.append(s)
    # group by the `now` anchor so each group can share one batched model call
    groups: dict[Optional[str], list[RequestState]] = {}
    for s in live:
        groups.setdefault(s.req.now.isoformat() if s.req.now else None, []).append(s)
    batch_fn = getattr(tenant.nl, "canonicalize_batch", None)
    for group in groups.values():
        now = group[0].req.now
        if pol.enabled and not breaker.allow():
            for s in group:
                s.provenance.append("breaker:open")
                _conclude_failure(tenant, s, "canonicalize", "breaker_open",
                                  "canonicalizer circuit breaker open",
                                  breaker="open")
            continue
        t0 = time.perf_counter()
        try:
            # chaos: a hung/timed-out LLM call surfaces here, before any
            # per-request result exists
            faults.fire("canonicalize.timeout")
            if batch_fn is not None and len(group) > 1:
                results = batch_fn([s.req.nl for s in group], now)
                tag = "canonicalize:nl_batched"
            else:
                results = [tenant.nl.canonicalize(s.req.nl, now) for s in group]
                tag = "canonicalize:nl"
        except Exception as e:  # noqa: BLE001 — containment boundary
            ms = (time.perf_counter() - t0) * 1e3 / len(group)
            if pol.enabled:
                breaker.record_failure()
            for s in group:
                s.add_ms("canonicalize", ms)
                _conclude_failure(
                    tenant, s, "canonicalize", classify(e),
                    f"{type(e).__name__}: {e}",
                    breaker=breaker.state if pol.enabled else None)
            continue
        if pol.enabled:
            breaker.record_success()
        ms = (time.perf_counter() - t0) * 1e3 / len(group)
        for s, res in zip(group, results):
            # chaos: corrupt the model's *output* — garbage JSON loses the
            # signature (bypass, never a wrong cache key); lowconf drops the
            # confidence under the acceptance threshold (gated to bypass)
            if faults.should_fire("canonicalize.garbage"):
                res = dataclasses.replace(
                    res, signature=None, confidence=0.0,
                    error="injected fault: canonicalizer returned garbage")
            elif faults.should_fire("canonicalize.lowconf"):
                res = dataclasses.replace(res, confidence=0.01)
            s.add_ms("canonicalize", ms)
            s.nl_res = res
            s.confidence = res.confidence
            sig = res.signature
            if sig is not None and s.req.scope is not None:
                sig = sig.replace(scope=s.req.scope)
            if sig is None:
                tenant.stats.bump(nl_gated=1)
                s.bypass(res.error or "canonicalization failed")
                continue
            s.sig = sig
            s.provenance.append(tag)


# ----------------------------------------------------------------- validate


def _stage_validate(tenant: "Tenant", states: list[RequestState]) -> None:
    for s in states:
        if not s.pending:
            continue
        t0 = time.perf_counter()
        v = tenant.validator.validate(s.sig)
        s.add_ms("validate", (time.perf_counter() - t0) * 1e3)
        if v:
            s.provenance.append("validate:ok")
            continue
        reason = "; ".join(v.reasons)
        if s.origin == "nl":
            tenant.stats.bump(nl_gated=1)
            s.bypass(reason)  # invalid NL signature: nothing safe to execute
        else:
            # raw SQL still runs on the backend; metric/signature requests
            # have no raw form, so an invalid signature executes nothing
            s.bypass(reason, "raw" if s.origin == "sql" else None)


# --------------------------------------------------------------- NL gating


def _stage_gate(tenant: "Tenant", states: list[RequestState]) -> None:
    for s in states:
        if not s.pending:
            continue
        if s.origin == "nl":
            t0 = time.perf_counter()
            gate = gate_nl(tenant.policy, s.req.nl, s.nl_res, s.req.now)
            s.add_ms("gate", (time.perf_counter() - t0) * 1e3)
            if not gate:
                tenant.stats.bump(nl_gated=1)
                # the signature is schema-valid: the bypass still executes it,
                # it just never touches the cache (§3.5)
                s.bypass("; ".join(gate.reasons), "sig")
                continue
            s.provenance.append("gate:ok")
            s.store = not tenant.policy.sql_seeded_only
        if s.req.read_only:
            s.store = False


# ------------------------------------------------------------------- lookup


def _stage_lookup(tenant: "Tenant", states: list[RequestState]) -> None:
    todo = []
    for s in states:
        if not s.pending:
            continue
        if s.req.refresh:
            # zero-duration timing so the stage still shows up in stage
            # histograms and gets its finalize-time span (the provenance
            # token proves the request passed through lookup)
            s.add_ms("lookup", 0.0)
            s.provenance.append("lookup:skipped_refresh")
            continue
        todo.append(s)
    if not todo:
        return
    batch_fn = getattr(tenant.cache, "lookup_or_flight_batch", None)
    if batch_fn is not None:
        # cluster cache: scatter-gather over shards (one lock acquisition per
        # touched shard) with atomic single-flight registration for misses
        t0 = time.perf_counter()
        triples = batch_fn([
            (s.sig, "nl" if s.origin == "nl" else "sql") for s in todo])
        ms = (time.perf_counter() - t0) * 1e3 / len(todo)
        for s, (lr, flight, leader) in zip(todo, triples):
            s.add_ms("lookup", ms)
            _apply_lookup(tenant, s, lr)
            if s.pending:
                s.flight, s.flight_leader = flight, leader
                if leader and flight is not None and s.trace is not None:
                    # publish the sampled leader's trace context on the
                    # flight so followers (this batch or other threads) can
                    # link their adoption back to the leader's trace; the
                    # flight event publication orders the read
                    flight.obs_ctx = s.trace.ctx()
        return
    for s in todo:
        t0 = time.perf_counter()
        lr: LookupResult = tenant.cache.lookup(
            s.sig, request_origin="nl" if s.origin == "nl" else "sql")
        s.add_ms("lookup", (time.perf_counter() - t0) * 1e3)
        _apply_lookup(tenant, s, lr)


def _apply_lookup(tenant: "Tenant", s: RequestState, lr: LookupResult) -> None:
    if lr.status != "miss" and s.origin == "nl" \
            and tenant.policy.verify_time_window and lr.source_key is not None:
        src = tenant.cache.entry(lr.source_key)
        if src is not None and not verify_hit_time_window(s.sig, src.signature):
            # fail safe: treat as miss (no flight was registered for the
            # original hit, so this executes directly in the plan stage)
            lr = LookupResult("miss", None)
    s.provenance.append(f"lookup:{lr.status}")
    if getattr(lr, "tier", None) == "cold":
        # served by a cold-tier promotion: same table, different tier
        s.provenance.append("tier:cold")
    if lr.status != "miss":
        s.status = lr.status
        s.table = lr.table
        s.source_origin = lr.source_origin
        s.source_snapshot = lr.source_snapshot
        if lr.source_snapshot is not None:
            # audit trail: which data snapshot the served table reflects
            s.provenance.append(f"snapshot:{lr.source_snapshot}")


# ---------------------------------------------------- miss planner + execute


def _stage_plan_and_execute(tenant: "Tenant", states: list[RequestState]) -> None:
    """Group the batch's cache misses, dedup identical in-flight signatures,
    and execute the unique ones through ``execute_batch`` shared scans
    (falling back to serial ``execute`` for singleton groups or plain
    backends).  With a sharded cluster cache, miss leaders are partitioned by
    shard and the per-shard groups execute *concurrently*; misses whose
    signature is already in flight on another thread become followers and
    wait for that flight instead of executing.  Bypass executions stay
    per-request — they are out-of-scope by definition and carry no shareable
    signature."""
    followers: list[RequestState] = []
    misses: dict[str, list[RequestState]] = {}
    for s in states:
        if not s.pending:
            continue
        if s.flight is not None and not s.flight_leader:
            followers.append(s)
            s.provenance.append("plan:coalesced")
            continue
        t0 = time.perf_counter()
        # sig.key() is interned: the lookup stage already computed it, so
        # this (and the store stage's re-read) is a dict probe, not a
        # second SHA-256 — the one-hash-per-request invariant is
        # regression-tested via signature.key_hash_computations()
        misses.setdefault(s.sig.key(), []).append(s)
        s.add_ms("plan", (time.perf_counter() - t0) * 1e3)

    leaders = [group[0] for group in misses.values()]
    for group in misses.values():
        if len(group) > 1:
            tenant.stats.bump(deduped_misses=len(group) - 1)
            for s in group[1:]:
                s.deduped = True
                s.provenance.append("plan:deduped")

    # shard-partitioned execution only pays when several shard groups can
    # actually overlap; otherwise (one group, concurrency disabled, plain
    # cache) the single cross-family execute_batch keeps the fused shared
    # scan — one fact-table pass for the whole batch.  A partition-parallel
    # backend (OlapExecutor(partitions=N)) already saturates the device with
    # its own partition pool: splitting leaders across a second shard pool
    # would nest thread pools and break the scan plane's cross-signature
    # scan sharing, so those backends take the single execute_batch
    shard_groups: Optional[list[list[RequestState]]] = None
    shard_of = getattr(tenant.cache, "shard_index", None)
    if len(leaders) > 1 and shard_of is not None \
            and getattr(tenant.cache, "concurrent_misses", False) \
            and hasattr(tenant.backend, "execute_batch") \
            and getattr(tenant.backend, "partitions", 1) == 1:
        by_shard: dict[int, list[RequestState]] = {}
        for s in leaders:
            by_shard.setdefault(shard_of(s.sig), []).append(s)
        if len(by_shard) > 1:
            shard_groups = list(by_shard.values())
    if shard_groups is not None:
        _execute_shard_groups(tenant, shard_groups)
    elif len(leaders) > 1 and hasattr(tenant.backend, "execute_batch"):
        _execute_group_guarded(tenant, leaders)
    else:
        for s in leaders:
            _execute_group_guarded(tenant, [s])
    for group in misses.values():
        lead = group[0]
        if lead.status is None:
            lead.status = "miss"
        for s in group[1:]:
            # dedup followers adopt the leader's outcome wholesale — status,
            # table, and failure record alike (a failed leader must not leave
            # followers pending, and a degraded leader's stale table stays
            # tagged on every requester it serves)
            s.status = lead.status
            s.table = lead.table
            s.batched = lead.batched
            if lead.error is not None:
                s.error = dataclasses.replace(lead.error)
                s.store = False
                s.provenance.append(f"failure:{lead.error.brief()}")
                if lead.status == "degraded":
                    s.provenance.append("degraded:stale")
                    tenant.stats.bump(degraded=1)
                else:
                    tenant.stats.bump(failures=1)

    # resolve this batch's flights so followers (here and on other threads)
    # unblock; then serve our own followers.  Scanned over all states, not
    # just group heads — a flight-owning state can sit at group[1:] when a
    # flightless request with the same key (refresh, NL verify fail-safe)
    # preceded it in the batch, and its flight must still complete.  The
    # leader *stores before the flight deregisters*: once the flight is
    # popped, a concurrent miss on this key starts a fresh computation unless
    # the entry is already resident — and a later stage raising (a bypass
    # execution, say) must not lose the only copy of a result followers
    # adopted with store=False
    complete = getattr(tenant.cache, "complete_flight", None)
    fail = getattr(tenant.cache, "fail_flight", None)
    if complete is not None:
        for s in states:
            if s.flight is not None and s.flight_leader and not s.flight.done:
                if s.status == "miss" and s.table is not None:
                    if s.store:
                        _store_state(tenant, s)
                    complete(s.flight, s.table)
                elif fail is not None:
                    # a failed or degraded leader must not publish its result:
                    # followers adopting a stale table through the flight
                    # would serve it *untagged*.  Fail the flight so waiters
                    # fall back to executing (and tagging) for themselves
                    fail(s.flight, RuntimeError(
                        s.error.brief() if s.error is not None
                        else f"leader resolved {s.status or 'unresolved'}"))
    for s in followers:
        _resolve_follower(tenant, s)

    # bypass executions (raw SQL or a validated-but-gated NL signature); no
    # retries or breaker here — bypasses are out-of-scope by definition —
    # but failures still resolve to structured errors, not raw exceptions
    for s in states:
        if s.status != "bypass" or s.bypass_exec is None:
            continue
        t0 = time.perf_counter()
        try:
            with tenant.gate.read:
                if s.bypass_exec == "raw":
                    s.table = tenant.backend.execute_raw(s.req.sql)
                else:
                    s.table = tenant.backend.execute(s.sig)
        except Exception as e:  # noqa: BLE001 — containment boundary
            s.add_ms("execute", (time.perf_counter() - t0) * 1e3)
            s.status = "error"
            s.table = None
            s.error = FailureInfo(stage="execute", kind=classify(e),
                                  message=f"{type(e).__name__}: {e}")
            s.provenance.append(f"failure:{s.error.brief()}")
            tenant.stats.bump(failures=1)
            continue
        s.add_ms("execute", (time.perf_counter() - t0) * 1e3)
        tenant.stats.bump(backend_executions=1)
        s.provenance.append(f"execute:bypass_{s.bypass_exec}")


def _execute_leader_group(tenant: "Tenant", group: list[RequestState]) -> None:
    """Execute one group of miss leaders: a shared ``execute_batch`` scan
    when the group carries several intents, a single ``execute`` otherwise.
    Counter bumps stay with the callers (concurrent callers must not bump
    from pool threads mid-flight)."""
    partitioned = getattr(tenant.backend, "partitions", 1) > 1
    if len(group) > 1:
        t0 = time.perf_counter()
        with tenant.gate.read:
            tables = tenant.backend.execute_batch([s.sig for s in group])
        batch_ms = (time.perf_counter() - t0) * 1e3
        for s, table in zip(group, tables):
            s.table = table
            s.batched = True
            # the scan is shared: each request is attributed the full batch
            # wall time under 'execute' (not a per-request cost)
            s.add_ms("execute", batch_ms)
            s.provenance.append("execute:batched")
            if partitioned:
                s.provenance.append("execute:partitioned")
    else:
        s = group[0]
        t0 = time.perf_counter()
        with tenant.gate.read:
            s.table = tenant.backend.execute(s.sig)
        s.add_ms("execute", (time.perf_counter() - t0) * 1e3)
        s.provenance.append("execute:single")
        if partitioned:
            s.provenance.append("execute:partitioned")


def _execute_group_guarded(tenant: "Tenant",
                           group: list[RequestState]) -> bool:
    """Run one miss-leader group through the backend behind the full guard
    stack: deadline shed, breaker admission, bounded retry with deterministic
    backoff, and per-leader isolation when a shared batch fails.  Requests
    that cannot be served resolve to degraded/error via
    :func:`_conclude_failure`; returns True when every leader got a table.
    Thread-safe (shard groups call this from pool threads): all counter
    bumps go through the lock-guarded ``TenantStats.bump``."""
    pol = tenant.resilience.policy
    breaker = tenant.resilience.backend
    if pol.enabled:
        live = []
        for s in group:
            if s.deadline is not None and s.deadline.expired:
                # shed: don't spend backend time on an already-dead request
                _conclude_failure(tenant, s, "execute", "deadline",
                                  "deadline expired before execution",
                                  shed=True)
            else:
                live.append(s)
        group = live
        if not group:
            return False
        if not breaker.allow():
            for s in group:
                s.provenance.append("breaker:open")
                _conclude_failure(tenant, s, "execute", "breaker_open",
                                  "backend circuit breaker open",
                                  breaker="open")
            return False
    attempts = max(pol.execute_attempts, 1) if pol.enabled else 1
    salt = group[0].sig.key() if group[0].sig is not None else ""
    delays = backoff_delays(attempts, pol.retry_base_s, pol.retry_max_s, salt)
    err: Optional[BaseException] = None
    retries_used = 0
    # live span on the first sampled leader's trace: it publishes itself as
    # this thread's current context, so the scan plane's partition spans and
    # any write-behind spill hang under it; attrs are finalized before exit
    trace = next((s.trace for s in group if s.trace is not None), None)
    eattrs: dict = {"leaders": len(group)}
    with span_ctx(trace, "execute.backend",
                  parent_id=trace.root_id if trace is not None else None,
                  attrs=eattrs):
        for attempt in range(attempts):
            try:
                lat = faults.latency_s("backend.latency")
                if lat:
                    time.sleep(lat)  # injected latency spike, not a failure
                faults.fire("backend.error")
                _execute_leader_group(tenant, group)
                err = None
                break
            except Exception as e:  # noqa: BLE001 — containment boundary
                err = e
                if attempt + 1 < attempts:
                    retries_used += 1
                    tenant.stats.bump(retries=1)
                    time.sleep(delays[attempt])
        eattrs["retries"] = retries_used
        eattrs["ok"] = err is None
        if err is not None:
            eattrs["error"] = f"{type(err).__name__}: {err}"
    if err is None and any(s.flight_leader for s in group) \
            and faults.should_fire("flight.leader_death"):
        # chaos: the single-flight leader dies *after* computing its result
        # but *before* publishing it.  Deliberately not retryable — the
        # point of this fault is that followers coalesced onto the flight
        # must survive via the self-execute fallback, not that the leader
        # quietly recovers.  The backend call itself succeeded, so the
        # breaker is not charged.
        for s in group:
            s.table = None
            s.batched = False
            _conclude_failure(tenant, s, "execute", "fault",
                              "injected fault: flight.leader_death")
        return False
    if err is None:
        if pol.enabled:
            breaker.record_success()
        tenant.stats.bump(backend_executions=len(group))
        if len(group) > 1:
            tenant.stats.bump(batched_misses=len(group))
        if retries_used:
            for s in group:
                s.provenance.append(f"retry:{retries_used}")
        return True
    if pol.enabled:
        breaker.record_failure()
    if len(group) > 1:
        # a shared batch scan may have died on one poisoned signature:
        # isolate and re-run each leader alone so one bad intent cannot
        # take down its co-batched innocents
        ok = True
        for s in group:
            s.provenance.append("execute:isolated_retry")
            ok = _execute_group_guarded(tenant, [s]) and ok
        return ok
    _conclude_failure(tenant, group[0], "execute", classify(err),
                      f"{type(err).__name__}: {err}", retries=retries_used,
                      breaker=breaker.state if pol.enabled else None)
    return False


def _execute_shard_groups(tenant: "Tenant",
                          groups: list[list[RequestState]]) -> None:
    """Execute per-shard miss groups concurrently (the caller guarantees >= 2
    groups and an opted-in cluster).  Safe because the OlapExecutor's plan
    memos are idempotent, its counters are lock-guarded, and its kernels
    release the GIL during numpy/JAX work, so shard groups overlap.  Each
    group fails *independently*: one shard's backend error resolves only
    that group's requests, never its co-batched neighbours."""
    with ThreadPoolExecutor(max_workers=len(groups),
                            thread_name_prefix="shard-miss") as pool:
        futures = [pool.submit(_execute_group_guarded, tenant, g)
                   for g in groups]
        for f, g in zip(futures, groups):
            try:
                f.result()
            except Exception as e:  # noqa: BLE001 — belt and braces: the
                # guarded runner contains failures itself; if it somehow
                # raises, fail only this group's still-pending requests
                for s in g:
                    if s.pending:
                        _conclude_failure(tenant, s, "execute", "internal",
                                          f"{type(e).__name__}: {e}")


def _resolve_follower(tenant: "Tenant", s: RequestState) -> None:
    """Wait for the flight owning this signature; on success adopt its table,
    on leader failure/timeout execute directly (through the same guard
    stack) — coalescing is opportunistic, never load-bearing."""
    timeout = getattr(tenant.cache, "flight_timeout", 30.0)
    t0 = time.perf_counter()
    ok = s.flight.wait(timeout)
    s.add_ms("plan", (time.perf_counter() - t0) * 1e3)
    s.deduped = True
    lctx = getattr(s.flight, "obs_ctx", None)
    if ok and lctx is not None:
        # adoption link, both directions: the follower's plan span names
        # the leader's trace/span, and (if sampled) the leader's trace gets
        # a link span naming the follower's trace
        ltrace, lspan = lctx
        attrs = s.stage_attrs.setdefault("plan", {})
        attrs["adopted_from_trace"] = ltrace.trace_id
        attrs["adopted_from_span"] = lspan
        ltrace.record("flight.adopt", parent_id=lspan, attrs={
            "follower_trace": None if s.trace is None else s.trace.trace_id,
            "key": s.flight.key})
    if ok and s.flight.ok and s.flight.table is not None:
        s.status = "miss"
        s.table = s.flight.table
        # the leader's store is authoritative; a second identical put would
        # only inflate store counters
        s.store = False
        tenant.stats.bump(coalesced_misses=1)
        return
    s.provenance.append("execute:flight_fallback")
    _execute_group_guarded(tenant, [s])
    if s.status is None:
        s.status = "miss"


# -------------------------------------------------------------------- store


def _store_state(tenant: "Tenant", s: RequestState) -> None:
    t0 = time.perf_counter()
    try:
        # adopt the request's root span as the thread context for the put:
        # a write-behind spill enqueued inside lands its worker-side span
        # under this trace (adopt(None) is a no-op shell)
        with adopt(None if s.trace is None else s.trace.ctx()):
            tenant.cache.put(s.sig, s.table,
                             origin="nl" if s.origin == "nl" else "sql",
                             snapshot_id=tenant.snapshot_id,
                             # recompute-cost estimate for the cost-benefit
                             # eviction policy: what this entry's miss
                             # actually paid to execute
                             cost_ms=s.timings.get("execute", 0.0))
    except Exception:  # noqa: BLE001 — a failed store must not fail the
        # request: the table is already in hand, the cache just stays cold
        s.add_ms("store", (time.perf_counter() - t0) * 1e3)
        s.provenance.append("store:error")
        tenant.stats.bump(store_errors=1)
        return
    s.add_ms("store", (time.perf_counter() - t0) * 1e3)
    s.stored = True
    tenant.stats.bump(stores=1)
    s.provenance.append("store")


def _stage_store(tenant: "Tenant", states: list[RequestState]) -> None:
    # keys flight leaders already put at completion time count as stored:
    # one put per key per batch
    stored: set[str] = {s.sig.key() for s in states if s.stored}
    for s in states:
        if s.status != "miss" or not s.store or s.table is None or s.stored:
            continue
        key = s.sig.key()
        if key in stored:
            continue
        stored.add(key)
        _store_state(tenant, s)


# ----------------------------------------------------------------- finalize


def _emit_trace(s: RequestState) -> None:
    """Emit the sampled request's spans: one per pipeline stage it passed
    through, plus the root.  Stage spans come from the union of recorded
    ``timings`` and provenance-derived stage names (a failed execute that
    never recorded a timing still proves its passage via provenance, and
    the error's own stage is always covered), so trace completeness holds
    by construction — including under injected chaos."""
    tr = s.trace
    by_stage: dict[str, list[str]] = {}
    events: list[str] = []
    for tok in s.provenance:
        head = tok.split(":", 1)[0]
        if head in STAGES:
            by_stage.setdefault(head, []).append(tok)
        else:
            events.append(tok)  # resilience/audit tokens: retry, breaker,
            # degraded, failure, snapshot, tier, bypass
    stages = set(s.timings) | set(by_stage)
    if s.error is not None and s.error.stage in STAGES:
        stages.add(s.error.stage)
    # stages are laid out sequentially from the request's start: per-stage
    # starts were never recorded (tracing adds no clock reads to stages),
    # durations are the pipeline's own perf_counter timings
    cursor = s.trace_wall0
    for stage in STAGES:
        if stage not in stages:
            continue
        dur = s.timings.get(stage, 0.0)
        attrs: dict = {}
        if stage in by_stage:
            attrs["outcomes"] = by_stage[stage]
        extra = s.stage_attrs.get(stage)
        if extra:
            attrs.update(extra)
        if s.error is not None and s.error.stage == stage:
            attrs["failure_kind"] = s.error.kind
            attrs["failure_message"] = s.error.message
            attrs["degraded"] = s.error.degraded
            if s.error.retries:
                attrs["retries"] = s.error.retries
            if s.error.breaker is not None:
                attrs["breaker"] = s.error.breaker
        if stage == "execute":
            for tok in events:
                if tok.startswith("retry:"):
                    attrs.setdefault("retries", int(tok.split(":", 1)[1]))
        tr.record(stage, parent_id=tr.root_id, start_s=cursor, dur_ms=dur,
                  attrs=attrs)
        cursor += dur / 1e3
    root_attrs: dict = {
        "status": s.status or "bypass",
        "origin": s.origin,
        "tenant": s.req.tenant,
        "batched": s.batched,
        "deduped": s.deduped,
    }
    if s.sig is not None:
        root_attrs["key"] = s.sig.key()
    if events:
        root_attrs["events"] = events
    tr.record("request", span_id=tr.root_id, start_s=s.trace_wall0,
              dur_ms=(time.perf_counter() - s.trace_t0) * 1e3,
              attrs=root_attrs)


def _finalize(tenant: "Tenant", s: RequestState) -> QueryResult:
    if s.status == "bypass":
        tenant.stats.bump(bypasses=1)
    tenant.stats.record_stage_timings(s.timings)
    if s.trace is not None:
        _emit_trace(s)
    return QueryResult(
        status=s.status or "bypass",
        table=s.table,
        signature=s.sig if s.sig is not None else (
            s.nl_res.signature if s.nl_res is not None else None),
        origin=s.origin,
        tenant=s.req.tenant,
        bypass_reason=s.bypass_reason,
        confidence=s.confidence,
        source_origin=s.source_origin,
        source_snapshot=s.source_snapshot,
        provenance=tuple(s.provenance),
        timings_ms=dict(s.timings),
        batched=s.batched,
        deduped=s.deduped,
        error=s.error,
        trace_id=None if s.trace is None else s.trace.trace_id,
        span_id=None if s.trace is None else s.trace.root_id,
    )
