"""Typed request/response envelopes for the batch-first cache service.

The service API replaces the one-schema, one-query-at-a-time middleware
surface with a unified :class:`QueryRequest` (exactly one of ``sql`` | ``nl``
| ``metric_id`` | pre-built ``signature``, plus tenant/scope and consistency
options) and a structured :class:`QueryResult` carrying the served table, the
resolved signature, the provenance chain of pipeline stages the request
passed through, and per-stage timings.  Every request — single or batched,
live or cache-warming — flows through the same staged pipeline
(pipeline.py), so the envelopes below are the *only* request surface.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import threading
from typing import Any, Optional, Protocol, Sequence

from ..analysis.sanitizer import make_lock, note_acquire, note_release
from ..obs.metrics import LogHistogram
from ..core.middleware import Backend
from ..core.signature import Filter, OrderKey, Signature, TimeWindow
from ..core.table import ResultTable
from ..resilience.errors import FailureInfo

DEFAULT_TENANT = "default"


class BatchBackend(Backend, Protocol):
    """A backend that can additionally execute a group of signatures as one
    shared scan (``OlapExecutor.execute_batch``).  The miss planner routes
    multi-miss batches through this entry point when present.  The optional
    ``partition=(start_row, end_row)`` bounds the scan to that fact row
    range — ``advance_snapshot(delta=...)`` relies on it for the incremental
    delta scan, so wrappers delegating to an ``OlapExecutor`` must pass it
    through."""

    def execute_batch(
        self, sigs: Sequence[Signature],
        partition: Optional[tuple[int, int]] = None,
    ) -> list[ResultTable]: ...


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """One unit of work for :meth:`CacheService.submit_batch`.

    Exactly one of ``sql`` / ``nl`` / ``metric_id`` / ``signature`` must be
    set.  ``tenant`` selects the registered tenant (schema + backend + cache
    + policy); ``scope`` further partitions the key space *within* a tenant
    (strict isolation: scoped signatures hash to disjoint keys).  ``now``
    anchors relative-time NL phrases.  ``levels``/``filters``/``time_window``
    /``order_by``/``limit`` parameterize governed ``metric_id`` requests.

    Consistency options: ``read_only`` serves from cache or executes but
    never stores (probe semantics); ``refresh`` skips the cache read and
    re-executes, re-storing the fresh result (forced freshness).

    ``deadline_ms`` is a per-request wall-clock budget: stages check it
    before starting expensive work (the canonicalizer call, a backend
    execute) and shed the request — serving a stale cached answer with
    ``degraded:stale`` provenance when one exists, a typed ``deadline``
    error otherwise — instead of burning backend time on a request whose
    caller has already given up.
    """

    sql: Optional[str] = None
    nl: Optional[str] = None
    metric_id: Optional[str] = None
    signature: Optional[Signature] = None
    tenant: str = DEFAULT_TENANT
    scope: Optional[str] = None
    now: Optional[_dt.date] = None
    # governed metric_id expansion arguments
    levels: tuple[str, ...] = ()
    filters: tuple[Filter, ...] = ()
    time_window: Optional[TimeWindow] = None
    order_by: tuple[OrderKey, ...] = ()
    limit: Optional[int] = None
    # consistency options
    read_only: bool = False
    refresh: bool = False
    # per-request deadline budget (wall-clock milliseconds), None = unbounded
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        forms = [f for f, v in (("sql", self.sql), ("nl", self.nl),
                                ("metric_id", self.metric_id),
                                ("signature", self.signature))
                 if v is not None]
        if len(forms) != 1:
            raise ValueError(
                "QueryRequest needs exactly one of sql | nl | metric_id | "
                f"signature, got {forms or 'none'}")

    @property
    def kind(self) -> str:
        if self.sql is not None:
            return "sql"
        if self.nl is not None:
            return "nl"
        if self.metric_id is not None:
            return "metric"
        return "signature"


@dataclasses.dataclass
class QueryResult:
    """Structured response for one :class:`QueryRequest`.

    ``status`` matches the middleware vocabulary ('hit_exact' | 'hit_rollup'
    | 'hit_filterdown' | 'hit_compose' | 'miss' | 'bypass'), extended by the
    resilience plane with 'degraded' (a dependency failed but a stale cached
    answer was served, explicitly tagged ``degraded:stale`` in provenance)
    and 'error' (a dependency failed and nothing was safe to serve — a typed
    :class:`FailureInfo` in ``error``, never a raw exception).  ``provenance``
    is the ordered chain of pipeline-stage outcomes the request passed
    through (e.g. ``('canonicalize:sql', 'validate:ok', 'lookup:miss',
    'execute:batched', 'store')``); ``timings_ms`` holds per-stage wall time.
    ``batched`` marks misses served by a shared ``execute_batch`` scan;
    ``deduped`` marks requests whose identical in-flight signature was
    executed once for several requesters.
    """

    status: str
    table: Optional[ResultTable]
    signature: Optional[Signature]
    origin: str  # 'sql' | 'nl' | 'metric' | 'signature'
    tenant: str = DEFAULT_TENANT
    bypass_reason: Optional[str] = None
    confidence: Optional[float] = None
    source_origin: Optional[str] = None  # origin of the serving cache entry
    source_snapshot: Optional[str] = None  # data snapshot the served table reflects
    provenance: tuple[str, ...] = ()
    timings_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    batched: bool = False
    deduped: bool = False
    # typed failure record for 'degraded'/'error' (and contained store
    # failures on otherwise-successful requests)
    error: Optional[FailureInfo] = None
    # observability: set when the request was head-sampled — the id of its
    # trace and of the request's root span in it
    trace_id: Optional[str] = None
    span_id: Optional[str] = None

    @property
    def hit(self) -> bool:
        return self.status.startswith("hit")

    @property
    def ok(self) -> bool:
        """Success-or-explicitly-degraded: the availability predicate the
        chaos bench measures.  Only 'error' results are not ok."""
        return self.status != "error"

    def to_dict(self, include_table: bool = False) -> dict[str, Any]:
        d: dict[str, Any] = {
            "status": self.status,
            "tenant": self.tenant,
            "origin": self.origin,
            "signature": None if self.signature is None else self.signature.to_json(),
            "provenance": list(self.provenance),
            "timings_ms": dict(self.timings_ms),
            "batched": self.batched,
            "deduped": self.deduped,
        }
        if self.bypass_reason is not None:
            d["bypass_reason"] = self.bypass_reason
        if self.confidence is not None:
            d["confidence"] = self.confidence
        if self.source_origin is not None:
            d["source_origin"] = self.source_origin
        if self.source_snapshot is not None:
            d["source_snapshot"] = self.source_snapshot
        if self.error is not None:
            d["error"] = self.error.to_dict()
        if self.trace_id is not None:
            d["trace_id"] = self.trace_id
            d["span_id"] = self.span_id
        if include_table and self.table is not None:
            d["table"] = {n: self.table.columns[n].tolist() for n in self.table.names}
        return d


@dataclasses.dataclass
class RefreshReport:
    """Outcome of :meth:`CacheService.advance_snapshot`.

    ``refreshed`` entries were brought current by merging a delta-partition
    aggregate into their cached table (cost proportional to the delta);
    ``recomputed`` entries were non-composable and re-executed over the full
    table; ``dropped`` entries were invalidated without replacement;
    ``unaffected`` closed-window entries stayed untouched.
    ``delta_rows_scanned`` counts fact rows read by the partition-bounded
    delta scan alone; ``recompute_rows_scanned`` counts the full-table rows
    the non-composable fallbacks read (kept separate so the delta metric
    stays proportional to the delta).
    """

    tenant: str
    snapshot_id: str
    appended_rows: int = 0
    refreshed: int = 0
    recomputed: int = 0
    dropped: int = 0
    unaffected: int = 0
    updated_start: Optional[str] = None
    updated_end: Optional[str] = None
    delta_rows_scanned: int = 0
    recompute_rows_scanned: int = 0

    @property
    def affected(self) -> int:
        return self.refreshed + self.recomputed + self.dropped

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["affected"] = self.affected
        return d


class ReadWriteGate:
    """Many concurrent readers or one exclusive writer.

    Request threads hold the *read* side around backend executions; dataset-
    mutating lifecycle operations (``advance_snapshot(delta=...)`` appends
    rows and resyncs executor caches) hold the *write* side — a scan can
    never observe half-appended columns or a plan-memo flush mid-execution.
    Writer-preference: an arriving writer blocks new readers, so steady
    traffic cannot starve a refresh."""

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0  # guarded-by: self._cond
        self._writer = False  # guarded-by: self._cond
        self._writers_waiting = 0  # guarded-by: self._cond
        # sanitizer pseudo-lock tokens: the gate is held *across* its body
        # (unlike _cond, which is released while waiting), so the held span
        # is reported manually per side; read tokens are per-thread
        self._san_read = threading.local()
        self._san_write = None  # guarded-by: external[only the single gate-holding writer touches it]

    def acquire_read(self) -> None:
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1
        self._san_read.token = note_acquire("ReadWriteGate.read", shared=True)

    def release_read(self) -> None:
        note_release(getattr(self._san_read, "token", None))
        self._san_read.token = None
        with self._cond:
            self._readers -= 1
            if not self._readers:
                self._cond.notify_all()

    def acquire_write(self) -> None:
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
            finally:
                self._writers_waiting -= 1
            self._writer = True
        self._san_write = note_acquire("ReadWriteGate.write")

    def release_write(self) -> None:
        note_release(self._san_write)
        self._san_write = None
        with self._cond:
            self._writer = False
            self._cond.notify_all()

    class _Side:
        __slots__ = ("_acquire", "_release")

        def __init__(self, acquire, release):
            self._acquire, self._release = acquire, release

        def __enter__(self):
            self._acquire()

        def __exit__(self, *exc):
            self._release()
            return False

    @property
    def read(self) -> "ReadWriteGate._Side":
        return self._Side(self.acquire_read, self.release_read)

    @property
    def write(self) -> "ReadWriteGate._Side":
        return self._Side(self.acquire_write, self.release_write)


@dataclasses.dataclass
class TenantStats:
    """Per-tenant service counters (cache-level counters live in
    ``SemanticCache.stats``).  A superset of the legacy ``MiddlewareStats``
    fields so middleware shims can expose it unchanged.

    ``stage_timings`` holds one log-bucketed :class:`LogHistogram` per
    pipeline stage (constant memory, never forgets old samples — it replaced
    the bounded sample deques) so ``stage_percentiles`` can report front-end
    p50/p95, and ``CacheService.metrics()`` can export the full distribution.

    Thread safety: the service runs request batches on concurrent caller
    threads (the sharded-cluster regime), so counters are bumped through
    :meth:`bump` and the latency reservoirs are guarded by an internal lock —
    plain field *reads* stay lock-free (single int loads are atomic under the
    GIL; momentarily torn cross-field views are acceptable for stats)."""

    requests: int = 0  # guarded-by: self._lock
    batches: int = 0  # guarded-by: self._lock
    bypasses: int = 0  # guarded-by: self._lock
    nl_gated: int = 0  # guarded-by: self._lock
    backend_executions: int = 0  # guarded-by: self._lock
    # misses served through a shared execute_batch scan
    batched_misses: int = 0  # guarded-by: self._lock
    # in-batch duplicates coalesced onto one execution
    deduped_misses: int = 0  # guarded-by: self._lock
    # cross-thread misses served by another's flight
    coalesced_misses: int = 0  # guarded-by: self._lock
    stores: int = 0  # guarded-by: self._lock
    # resilience counters: retry attempts spent on failing executes, requests
    # served degraded (stale-but-tagged), requests shed on deadline, requests
    # that ended in a typed error, and contained cache-store failures
    retries: int = 0  # guarded-by: self._lock
    degraded: int = 0  # guarded-by: self._lock
    shed: int = 0  # guarded-by: self._lock
    failures: int = 0  # guarded-by: self._lock
    store_errors: int = 0  # guarded-by: self._lock
    stage_timings: dict = dataclasses.field(  # guarded-by: self._lock
        default_factory=dict, repr=False, compare=False)
    _lock: threading.Lock = dataclasses.field(
        default_factory=lambda: make_lock("TenantStats._lock"),
        init=False, repr=False, compare=False)

    def bump(self, **deltas: int) -> None:
        """Atomically add to one or more counter fields.  ``x += n`` on a
        shared dataclass field is a read-modify-write race under threads;
        every pipeline/service increment goes through here instead."""
        with self._lock:
            for name, delta in deltas.items():
                setattr(self, name, getattr(self, name) + delta)

    def record_stage_timings(self, timings_ms: dict[str, float]) -> None:
        with self._lock:
            for stage, ms in timings_ms.items():
                h = self.stage_timings.get(stage)
                if h is None:
                    h = self.stage_timings[stage] = LogHistogram()
                h.observe(ms)

    def stage_percentiles(self) -> dict[str, dict[str, float]]:
        """p50/p95 per pipeline stage, from the stage histograms.  Quantiles
        use the proper zero-indexed rank ``q * (n - 1)`` (the old sorted-
        window ``int(len * 0.95)`` index overshot on small sample counts)."""
        out: dict[str, dict[str, float]] = {}
        for stage, h in self.stage_histograms().items():
            if not h.count:
                continue
            out[stage] = {
                "p50_ms": h.quantile(0.5),
                "p95_ms": h.quantile(0.95),
                "n": h.count,
            }
        return out

    def stage_histograms(self) -> dict[str, LogHistogram]:
        """Consistent snapshots of the per-stage histograms — the metrics
        registry adopts these wholesale at exposition time."""
        with self._lock:
            return {stage: h.snapshot()
                    for stage, h in self.stage_timings.items()}

    def to_dict(self) -> dict:
        # field loop instead of dataclasses.asdict: the raw sample windows
        # and the lock are implementation details (and deques are not JSON);
        # asdict would deep-copy thousands of retained samples just to drop
        # them
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
             if f.name not in ("stage_timings", "_lock")}
        d["stages_ms"] = self.stage_percentiles()
        return d
