"""Service layer: the batch-first, multi-tenant request surface over the
semantic cache — unified QueryRequest/QueryResult envelopes, a staged
request pipeline with per-stage observability, and a miss planner that
routes batched cache misses through the fused shared-scan backend."""

from .api import (Backend, BatchBackend, QueryRequest, QueryResult,
                  RefreshReport, TenantStats, DEFAULT_TENANT)
from .pipeline import STAGES, run_pipeline
from .service import CacheService, Tenant

__all__ = [
    "Backend", "BatchBackend", "CacheService", "DEFAULT_TENANT",
    "QueryRequest", "QueryResult", "RefreshReport", "STAGES", "Tenant",
    "TenantStats", "run_pipeline",
]
