"""Runtime lock-order sanitizer (opt-in via ``REPRO_SANITIZE=1``).

The static lock-order pass (``lockorder.py``) proves acyclicity of the
acquisition graph it can *see*; this module checks the orders that actually
happen.  Production modules create their locks through :func:`make_lock`,
which normally returns a plain ``threading.Lock``/``RLock``.  With
``REPRO_SANITIZE=1`` in the environment it returns a :class:`SanitizedLock`
that, on every acquisition, records the edge (held lock class -> acquiring
lock class) into a global observed-order digraph and raises
:class:`LockOrderViolation` the moment an acquisition would close a cycle —
i.e. the moment two threads have demonstrated opposite acquisition orders,
which is a latent deadlock even if this particular run never interleaved
into one.

Granularity is the **lock class** (the ``order_class`` string passed to
``make_lock``, e.g. ``"CacheShard.lock"``), not the instance: a deadlock
between two shard locks is an ordering bug of the class, and per-instance
graphs would miss the A-instance-1 -> B vs B -> A-instance-2 interleaving.
Two escapes keep that sound in practice:

* re-entrant acquisition of the *same instance* (RLock semantics) never
  records an edge;
* classes registered via :func:`allow_same_class_order` may nest instances
  of themselves (the cluster rebalance acquires every shard lock, in shard
  order, while holding the topology lock).

``note_blocking(what)`` is the held-lock-across-blocking-call check:
instrumented blocking points (``Flight.wait``, the tenant read/write gate
acquisitions) call it, and it raises if the calling thread still holds any
sanitized lock — waiting on another thread's progress while holding a lock
that thread may need is the other classic deadlock shape.

Violations both raise in the offending thread *and* are recorded in a
global list (``violations()``), because test harnesses often swallow worker
thread exceptions.  All sanitizer state is process-global and reset via
:func:`reset` (tests).  This module must stay import-light: production hot
paths import it unconditionally.
"""
from __future__ import annotations

import os
import threading
from typing import Optional, Union

__all__ = [
    "LockOrderViolation", "SanitizedLock", "make_lock", "sanitize_enabled",
    "note_blocking", "note_acquire", "note_release", "violations", "reset",
    "allow_same_class_order", "observed_edges",
]

ENV_FLAG = "REPRO_SANITIZE"


def sanitize_enabled() -> bool:
    return os.environ.get(ENV_FLAG, "") not in ("", "0", "false", "no")


class LockOrderViolation(AssertionError):
    """A demonstrated lock-order cycle or a blocking call under a held lock."""


class _State:
    """Process-global sanitizer state.  Its own plain lock is deliberately
    *not* sanitized (it is a leaf acquired for bookkeeping only)."""

    def __init__(self):
        self.lock = threading.Lock()
        # observed order digraph over lock classes: class -> set of classes
        # acquired while it was held, plus the first witness per edge
        self.edges: dict[str, set[str]] = {}
        self.witness: dict[tuple[str, str], str] = {}
        self.allowed_self: set[str] = set()
        self.violations: list[str] = []
        self.tls = threading.local()

    def held_stack(self) -> list:
        st = getattr(self.tls, "stack", None)
        if st is None:
            st = self.tls.stack = []
        return st


_STATE = _State()


def reset() -> None:
    """Forget all observed edges, violations, and self-order allowances
    (held stacks are thread-local and drain naturally)."""
    with _STATE.lock:
        _STATE.edges.clear()
        _STATE.witness.clear()
        _STATE.violations.clear()
        _STATE.allowed_self.clear()


def allow_same_class_order(order_class: str) -> None:
    """Permit nesting several *instances* of one lock class (the caller
    vouches for a deterministic instance order, e.g. shard-index order)."""
    with _STATE.lock:
        _STATE.allowed_self.add(order_class)


def violations() -> list[str]:
    with _STATE.lock:
        return list(_STATE.violations)


def observed_edges() -> dict[str, set[str]]:
    with _STATE.lock:
        return {k: set(v) for k, v in _STATE.edges.items()}


def _record(msg: str) -> None:
    with _STATE.lock:
        _STATE.violations.append(msg)


def _reaches(src: str, dst: str) -> Optional[list[str]]:
    """DFS: path src -> dst in the observed digraph (caller holds state
    lock).  Returns the class path or None."""
    stack = [(src, [src])]
    seen = set()
    while stack:
        node, path = stack.pop()
        if node == dst:
            return path
        if node in seen:
            continue
        seen.add(node)
        for nxt in _STATE.edges.get(node, ()):
            stack.append((nxt, path + [nxt]))
    return None


def _on_acquired(lock: "SanitizedLock") -> None:
    """Called after the underlying lock is held: record edges from every
    currently-held lock class and check for order cycles."""
    stack = _STATE.held_stack()
    for held in stack:
        a, b = held.order_class, lock.order_class
        if a == b:
            if held is lock:
                continue  # re-entrant same-instance: RLock semantics
            with _STATE.lock:
                allowed = a in _STATE.allowed_self
            if not allowed:
                msg = (f"lock-order: nested acquisition of two {a!r} "
                       f"instances (not registered as self-ordered)")
                _record(msg)
                raise LockOrderViolation(msg)
            continue
        msg = None
        with _STATE.lock:
            if b in _STATE.edges.get(a, ()):
                continue  # edge already known consistent
            back = _reaches(b, a)
            if back is not None:
                first = _STATE.witness.get((back[0], back[1]), "?")
                msg = (f"lock-order cycle: acquiring {b!r} while holding "
                       f"{a!r}, but the opposite order "
                       f"{' -> '.join(back)} was observed (first witness: "
                       f"{first})")
                _STATE.violations.append(msg)
            else:
                _STATE.edges.setdefault(a, set()).add(b)
                _STATE.witness[(a, b)] = _thread_site()
        if msg is not None:
            raise LockOrderViolation(msg)
    stack.append(lock)


def _thread_site() -> str:
    return f"thread={threading.current_thread().name}"


def _on_released(lock: "SanitizedLock") -> None:
    stack = _STATE.held_stack()
    # remove the most recent entry for this instance (release order may not
    # be perfectly LIFO across instances)
    for i in range(len(stack) - 1, -1, -1):
        if stack[i] is lock:
            del stack[i]
            return


class SanitizedLock:
    """Drop-in for ``threading.Lock``/``RLock`` that reports acquisition
    edges to the global order graph.  Re-entrancy is backed by a real RLock;
    non-reentrant use sites simply never re-enter."""

    __slots__ = ("order_class", "_inner", "_depth_tls")

    def __init__(self, order_class: str, reentrant: bool = True):
        self.order_class = order_class
        self._inner = threading.RLock() if reentrant else threading.Lock()
        self._depth_tls = threading.local()

    def _depth(self) -> int:
        return getattr(self._depth_tls, "d", 0)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        if self._depth() == 0 and blocking and timeout == -1:
            # a contended blocking acquire is itself a wait-for edge; the
            # edge recording below covers it (cycle == potential deadlock)
            pass
        got = self._inner.acquire(blocking, timeout)
        if got:
            if self._depth() == 0:
                try:
                    _on_acquired(self)
                except BaseException:
                    self._inner.release()
                    raise
            self._depth_tls.d = self._depth() + 1
        return got

    def release(self) -> None:
        d = self._depth()
        self._inner.release()
        self._depth_tls.d = d - 1
        if d == 1:
            _on_released(self)

    def __enter__(self) -> "SanitizedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> bool:
        self.release()
        return False

    def locked(self) -> bool:  # Lock-protocol compatibility
        return self._depth() > 0


LockLike = Union["SanitizedLock", "threading.Lock", "threading.RLock"]


def make_lock(order_class: str, *, reentrant: bool = False) -> LockLike:
    """The production lock factory.  Plain ``threading`` primitive normally;
    a :class:`SanitizedLock` of the given order class under
    ``REPRO_SANITIZE=1``.  ``order_class`` is the class-qualified attribute
    name (``"CacheShard.lock"``) — the same identifier the static lock-order
    pass uses, so static edges and runtime edges line up."""
    if sanitize_enabled():
        return SanitizedLock(order_class, reentrant=reentrant)
    return threading.RLock() if reentrant else threading.Lock()


# --------------------------------------------------------- manual bookkeeping
class _Pseudo:
    """A pseudo-lock entry for constructs that are not mutexes but impose
    ordering (the tenant read/write gate): note_acquire/note_release push and
    pop it on the held stack so edges through it are observed.  ``shared``
    marks read-side acquisitions: many holders at once, so holding one across
    a blocking wait cannot starve the thread being waited on."""

    __slots__ = ("order_class", "shared")

    def __init__(self, order_class: str, shared: bool = False):
        self.order_class = order_class
        self.shared = shared


def note_acquire(order_class: str, *, shared: bool = False) -> Optional[_Pseudo]:
    """Record a non-mutex acquisition (returns a token for note_release).
    No-op (None) when sanitizing is off."""
    if not sanitize_enabled():
        return None
    token = _Pseudo(order_class, shared=shared)
    _on_acquired(token)  # type: ignore[arg-type]
    return token


def note_release(token: Optional[_Pseudo]) -> None:
    if token is not None:
        _on_released(token)  # type: ignore[arg-type]


def note_blocking(what: str) -> None:
    """Assert the calling thread holds no sanitized lock while entering a
    blocking wait on another thread's progress.  No-op when sanitizing is
    off."""
    if not sanitize_enabled():
        return
    stack = [l for l in _STATE.held_stack()
             if not getattr(l, "shared", False)]
    if stack:
        held = [l.order_class for l in stack]
        msg = (f"blocking call {what!r} while holding sanitized lock(s) "
               f"{held}: the thread being waited on may need them")
        _record(msg)
        raise LockOrderViolation(msg)
