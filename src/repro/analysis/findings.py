"""Findings model + JSON report + baseline handling for the static passes.

A finding is keyed for baseline purposes by ``(rule, file, identifier)`` —
*not* by line number, so unrelated edits above an accepted finding don't
churn the baseline.  ``identifier`` is a stable name: the guarded attribute
(``CacheShard._inflight``), the lock-order cycle (``A -> B -> A``), or the
frozen field (``Signature._family_hash``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional

RULES = (
    "guarded-by",              # write to a guarded attr without its lock
    "unannotated-shared-write",  # lock-owning class writes an undeclared attr
    "lock-order",              # static acquisition-order cycle
    "immutability",            # mutation of an interned / frozen value type
)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    file: str          # repo-relative path
    line: int
    identifier: str    # stable name for baseline matching
    message: str

    def key(self) -> tuple:
        return (self.rule, self.file, self.identifier)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"


def load_baseline(path: str) -> set:
    """Baseline file: ``{"findings": [{rule, file, identifier}, ...]}``."""
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return set()
    return {(f["rule"], f["file"], f["identifier"])
            for f in data.get("findings", ())}


def split_baseline(findings: Iterable[Finding],
                   baseline: set) -> tuple[list, list]:
    """Partition into (new, baselined)."""
    new, old = [], []
    for f in findings:
        (old if f.key() in baseline else new).append(f)
    return new, old


def write_report(path: str, *, paths: list, findings: list,
                 new: list, baselined: list,
                 waived: Optional[list] = None) -> dict:
    report = {
        "tool": "repro.analysis",
        "paths": list(paths),
        "counts": {
            "total": len(findings),
            "new": len(new),
            "baselined": len(baselined),
            "waived": len(waived or ()),
        },
        "findings": [f.to_dict() for f in findings],
        "new": [f.to_dict() for f in new],
        "waived": [f.to_dict() for f in (waived or ())],
    }
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return report
