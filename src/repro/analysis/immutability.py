"""Interning-immutability pass.

Interned value types (the frozen ``@dataclass(frozen=True)`` classes in
``core/signature.py``) are shared across threads precisely *because* they
are immutable: the cache keys, the single-compute-per-request hash
invariant, and the family index all assume a ``Signature`` never changes
after construction.  ``object.__setattr__`` pierces the frozen guard, so
this pass polices it:

* inside the defining module, ``object.__setattr__(self, ...)`` from the
  class's own methods is construction/interning and allowed;
* outside, only the blessed ``INTERNING_SITES`` registry entries (e.g.
  the cluster writing ``Signature._family_hash`` once under its topology
  lock) are allowed — anything else is a finding;
* a plain attribute assignment to a receiver inferred frozen is also
  flagged (it would raise ``FrozenInstanceError`` at runtime; the lint
  catches it before a test has to).

Additionally, ``FROZEN_OWNERS`` declares owner-only mutable fields of
shared record types: ``CacheEntry.signature`` / ``lru_stamp`` /
``store_stamp`` are written only by ``core/cache.py`` (under the shard
lock); a write from any other module is a finding even though the class
itself is not frozen.
"""
from __future__ import annotations

import ast
from typing import Optional

from . import annotations as A
from .findings import Finding
from .lockcheck import _Scope, _expr_calls, _own_exprs


def _frozen_classes(index: A.ProjectIndex) -> dict:
    return {name: ci for name, ci in index.classes.items() if ci.frozen}


def _interning_allowed(rel: str, cls: str, field: str) -> bool:
    for (suffix, c, f) in A.INTERNING_SITES:
        if rel.endswith(suffix) and c == cls and f == field:
            return True
    return False


def _walk_functions(module: A.ModuleInfo):
    for cinfo in module.classes.values():
        for func in cinfo.methods.values():
            yield cinfo, func
    for func in module.functions.values():
        yield None, func


def _iter_stmts(fn: ast.AST):
    for node in ast.walk(fn):
        if isinstance(node, ast.stmt):
            yield node


def run(index: A.ProjectIndex) -> tuple:
    """Returns (findings, waived)."""
    frozen = _frozen_classes(index)
    out: list = []
    waived_out: list = []

    def emit(module: A.ModuleInfo, site: ast.AST, identifier: str,
             message: str) -> None:
        f = Finding(rule="immutability", file=module.rel, line=site.lineno,
                    identifier=identifier, message=message)
        (waived_out if A.waived(module, site, "immutability")
         else out).append(f)

    for module in index.modules:
        own_frozen = {name for name in module.classes if name in frozen}
        for cinfo, func in _walk_functions(module):
            scope = _Scope(index, cinfo, func.node)
            for stmt in _iter_stmts(func.node):
                # --- object.__setattr__ escapes
                for call in _expr_calls(_own_exprs(stmt)):
                    fname = A.normalize(call.func) or ""
                    if fname != "object.__setattr__" or len(call.args) < 2:
                        continue
                    recv = call.args[0]
                    field = (call.args[1].value
                             if isinstance(call.args[1], ast.Constant) and
                             isinstance(call.args[1].value, str) else "?")
                    classes = scope.receiver_classes(recv)
                    froz = sorted(c for c in classes if c in frozen)
                    if not froz:
                        continue
                    for cls in froz:
                        if cls in own_frozen and cinfo is not None and \
                                A.normalize(recv) == "self":
                            continue  # construction/interning in-class
                        if _interning_allowed(module.rel, cls, field):
                            continue
                        emit(module, call, f"{cls}.{field}",
                             f"{func.qualname} pierces frozen {cls} via "
                             f"object.__setattr__ on field {field!r} "
                             f"(not a registered interning site)")
                # --- plain assignment to a frozen receiver / owned field
                targets = []
                if isinstance(stmt, ast.Assign):
                    targets = stmt.targets
                elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                    targets = [stmt.target]
                for tgt in targets:
                    if not isinstance(tgt, ast.Attribute):
                        continue
                    classes = scope.receiver_classes(tgt.value)
                    for cls in sorted(classes):
                        if cls in frozen:
                            if cinfo is not None and cls == cinfo.name and \
                                    A.normalize(tgt.value) == "self":
                                continue
                            emit(module, tgt, f"{cls}.{tgt.attr}",
                                 f"{func.qualname} assigns "
                                 f"{cls}.{tgt.attr}: {cls} is frozen "
                                 f"(would raise FrozenInstanceError)")
                        owned = A.FROZEN_OWNERS.get(cls)
                        if owned and tgt.attr in owned["fields"] and \
                                not module.rel.endswith(owned["owner"]):
                            emit(module, tgt, f"{cls}.{tgt.attr}",
                                 f"{func.qualname} writes owner-only field "
                                 f"{cls}.{tgt.attr} outside "
                                 f"{owned['owner']}")
    return out, waived_out
