"""``python -m repro.analysis`` — run the static concurrency passes.

Usage::

    python -m repro.analysis [paths...] [--strict] [--json REPORT]
                             [--baseline FILE | --no-baseline]
                             [--print-lock-graph]

Default paths are the concurrency-bearing packages
(``src/repro/{cluster,service,olap,core}``).  Findings matching the
checked-in baseline (``src/repro/analysis/baseline.json``, keyed by
``(rule, file, identifier)`` — line-number independent) are reported but
do not fail the run; ``--strict`` exits non-zero on any *new* finding.
The JSON report (default ``ANALYSIS_report.json``) always carries the
full finding set plus the waived list, so CI artifacts show everything.
"""
from __future__ import annotations

import argparse
import os
import sys

from . import annotations as A
from . import immutability, lockcheck, lockorder
from .findings import load_baseline, split_baseline, write_report

DEFAULT_PACKAGES = ("cluster", "service", "olap", "core", "storage",
                    "resilience", "obs")


def _repo_root() -> str:
    # src/repro/analysis/cli.py -> repo root is four levels up
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _default_paths(root: str) -> list:
    base = os.path.join(root, "src", "repro")
    return [os.path.join(base, pkg) for pkg in DEFAULT_PACKAGES
            if os.path.isdir(os.path.join(base, pkg))]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="concurrency invariant analysis: guarded-by lint, "
                    "lock-order graph, interning-immutability")
    parser.add_argument("paths", nargs="*", help="files or directories "
                        "(default: src/repro/{%s})" % ",".join(
                            DEFAULT_PACKAGES))
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on findings not in the baseline")
    parser.add_argument("--json", default="ANALYSIS_report.json",
                        metavar="FILE", help="JSON report path "
                        "(default: %(default)s; '-' disables)")
    parser.add_argument("--baseline", default=None, metavar="FILE",
                        help="baseline file (default: the checked-in one)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="treat every finding as new")
    parser.add_argument("--print-lock-graph", action="store_true",
                        help="dump the extracted acquisition digraph")
    args = parser.parse_args(argv)

    root = _repo_root()
    paths = [os.path.abspath(p) for p in args.paths] or _default_paths(root)
    index = A.build_index(paths, root)

    lc_findings, lc_waived = lockcheck.run(index)
    lo_findings, lo_waived, edges = lockorder.run(index)
    im_findings, im_waived = immutability.run(index)
    findings = sorted(lc_findings + lo_findings + im_findings,
                      key=lambda f: (f.file, f.line, f.rule, f.identifier))
    waived = sorted(lc_waived + lo_waived + im_waived,
                    key=lambda f: (f.file, f.line, f.rule, f.identifier))

    if args.no_baseline:
        baseline = set()
    else:
        bl_path = args.baseline or os.path.join(
            os.path.dirname(os.path.abspath(__file__)), "baseline.json")
        baseline = load_baseline(bl_path)
    new, baselined = split_baseline(findings, baseline)

    if args.print_lock_graph:
        print("lock-order acquisition digraph "
              f"({len(edges)} edge{'s' * (len(edges) != 1)}):")
        for (a, b), witness in sorted(edges.items()):
            print(f"  {a} -> {b}    [{witness}]")
        print()

    n_files = len(index.modules)
    n_guarded = sum(len(c.guarded) for m in index.modules
                    for c in m.classes.values())
    n_locks = sum(len(c.locks) for m in index.modules
                  for c in m.classes.values())
    print(f"repro.analysis: {n_files} files, {n_guarded} guarded attrs, "
          f"{n_locks} locks, {len(edges)} order edges")

    for f in new:
        print(f"NEW  {f.render()}")
    for f in baselined:
        print(f"BASE {f.render()}")
    for f in waived:
        print(f"WAIV {f.render()}")

    if args.json != "-":
        write_report(args.json, paths=[os.path.relpath(p, root)
                                       for p in paths],
                     findings=findings, new=new, baselined=baselined,
                     waived=waived)
        print(f"report: {args.json}")

    if new:
        print(f"{len(new)} new finding{'s' * (len(new) != 1)}"
              f"{' (strict: failing)' if args.strict else ''}")
        return 1 if args.strict else 0
    print("clean: no findings beyond baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
