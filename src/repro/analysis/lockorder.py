"""Static lock-order pass: extract the acquisition digraph, reject cycles.

Granularity is the **lock class** — the ``make_lock`` order-class string
(``"CacheShard.lock"``) — matching the runtime sanitizer, so static edges
and observed edges line up.  Lock expressions are resolved through the
project index: ``with self._lock:`` looks up the enclosing class's lock
attributes; ``with fl.shard.lock:`` walks receiver types (parameter
annotations, constructor locals, registry TYPE_HINTS); ``with
t.gate.write:`` maps the tenant gate's ``read``/``write`` context managers
to their pseudo-lock classes.

Nesting is collected flow-sensitively inside each function, then
propagated across calls by a fixpoint over per-function summaries (the set
of lock classes a function may transitively acquire): a call made while
holding ``A`` contributes edges ``A -> x`` for every ``x`` in the callee's
summary.  This is conservative — summaries ignore *which instance* — so:

* a held and re-acquired lock with the *same normalized expression* is
  same-instance reentrance and records no edge;
* self-edges on classes in ``SELF_ORDER_OK`` (deterministic instance
  order, mirrored by ``sanitizer.allow_same_class_order``) are skipped;
* a documented false positive is suppressed with ``# analysis:
  allow[lock-order]`` on the call/with line — it still shows up in the
  JSON report as waived.

Nested ``def``s (thread bodies, closures) contribute their own edges with
an empty entry held-set but are excluded from the enclosing function's
summary: the enclosing call site does not acquire their locks on the
caller's thread.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from . import annotations as A
from .findings import Finding
from .lockcheck import _Scope, _expr_calls, _own_exprs


@dataclasses.dataclass
class _Event:
    kind: str            # "acquire" | "call"
    held: tuple          # ((order_class, expr), ...) at the event
    classes: set         # acquire: lock classes; call: unused
    callees: tuple       # call: resolved function keys
    site: str            # "file:line"
    line: int
    waived: bool
    expr: Optional[str] = None   # acquire: normalized lock expression


@dataclasses.dataclass
class _Fn:
    key: tuple
    info: A.FuncInfo
    module: A.ModuleInfo
    scope: _Scope
    events: list = dataclasses.field(default_factory=list)
    direct: set = dataclasses.field(default_factory=set)
    callees: set = dataclasses.field(default_factory=set)
    summarized: bool = True   # nested defs excluded from caller summaries


def _resolve_lock(index: A.ProjectIndex, scope: _Scope,
                  expr: ast.AST) -> set:
    """Lock classes a dotted expression denotes (usually one)."""
    if not isinstance(expr, ast.Attribute):
        return set()
    leaf = expr.attr
    out = set()
    for cls_name in scope.receiver_classes(expr.value):
        ci = index.lookup(cls_name)
        if ci is not None and leaf in ci.locks:
            out.add(ci.locks[leaf])
        if cls_name == "ReadWriteGate" and leaf in A.GATE_PSEUDO_LOCKS:
            out.add(A.GATE_PSEUDO_LOCKS[leaf])
    return out


def _resolve_callees(index: A.ProjectIndex, module: A.ModuleInfo,
                     scope: _Scope, call: ast.Call) -> tuple:
    fn = call.func
    keys = []
    if isinstance(fn, ast.Attribute):
        for cls_name in scope.receiver_classes(fn.value):
            ci = index.lookup(cls_name)
            if ci is not None and fn.attr in ci.methods:
                keys.append((cls_name, fn.attr))
    elif isinstance(fn, ast.Name):
        if fn.id in module.functions:
            keys.append((module.rel, fn.id))
        else:
            ci = index.lookup(fn.id)
            if ci is not None and "__init__" in ci.methods:
                keys.append((fn.id, "__init__"))
    return tuple(keys)


def _collect(index: A.ProjectIndex, module: A.ModuleInfo, fn: _Fn,
             nested_out: list) -> None:
    info = fn.info

    def site(node: ast.AST) -> str:
        return f"{module.rel}:{node.lineno}"

    def is_waived(node: ast.AST) -> bool:
        return A.waived(module, node, "lock-order")

    def record_calls(stmt: ast.AST, held: list) -> None:
        for call in _expr_calls(_own_exprs(stmt)):
            callees = _resolve_callees(index, module, fn.scope, call)
            if callees:
                fn.callees.update(callees)
                fn.events.append(_Event(
                    kind="call", held=tuple(held), classes=set(),
                    callees=callees, site=site(call), line=call.lineno,
                    waived=is_waived(stmt)))

    def walk(stmts: list, held: list) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                nested_out.append((stmt, fn))
                continue
            record_calls(stmt, held)
            if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call) \
                    and isinstance(stmt.value.func, ast.Attribute):
                recv = stmt.value.func.value
                if stmt.value.func.attr == "acquire":
                    classes = _resolve_lock(index, fn.scope, recv)
                    expr = A.normalize(recv)
                    if classes:
                        fn.direct.update(classes)
                        fn.events.append(_Event(
                            kind="acquire", held=tuple(held), classes=classes,
                            callees=(), site=site(stmt), line=stmt.lineno,
                            waived=is_waived(stmt), expr=expr))
                        for oc in classes:
                            held.append((oc, expr))
                elif stmt.value.func.attr == "release":
                    expr = A.normalize(recv)
                    for i in range(len(held) - 1, -1, -1):
                        if held[i][1] == expr:
                            del held[i]
                            break
            if isinstance(stmt, (ast.With, ast.AsyncWith)):
                inner = list(held)
                for item in stmt.items:
                    classes = _resolve_lock(index, fn.scope,
                                            item.context_expr)
                    expr = A.normalize(item.context_expr)
                    if classes:
                        fn.direct.update(classes)
                        fn.events.append(_Event(
                            kind="acquire", held=tuple(inner),
                            classes=classes, callees=(), site=site(stmt),
                            line=stmt.lineno, waived=is_waived(stmt),
                            expr=expr))
                        for oc in classes:
                            inner.append((oc, expr))
                walk(stmt.body, inner)
                continue
            for attr_name in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr_name, None)
                if sub:
                    walk(sub, held)
            for handler in getattr(stmt, "handlers", ()) or ():
                walk(handler.body, held)

    held0: list = []
    for req in sorted(info.requires):
        try:
            expr = ast.parse(req, mode="eval").body
        except SyntaxError:
            continue
        for oc in _resolve_lock(index, fn.scope, expr):
            held0.append((oc, req))
    walk(info.node.body, held0)


def _build_functions(index: A.ProjectIndex) -> dict:
    funcs: dict = {}
    nested: list = []
    for module in index.modules:
        for cinfo in module.classes.values():
            for name, info in cinfo.methods.items():
                fn = _Fn(key=(cinfo.name, name), info=info, module=module,
                         scope=_Scope(index, cinfo, info.node))
                funcs[fn.key] = fn
        for name, info in module.functions.items():
            fn = _Fn(key=(module.rel, name), info=info, module=module,
                     scope=_Scope(index, None, info.node))
            funcs[fn.key] = fn
    for fn in list(funcs.values()):
        _collect(index, fn.module, fn, nested)
    # nested defs: own events, excluded from caller summaries
    while nested:
        node, parent = nested.pop()
        info = A.FuncInfo(
            qualname=f"{parent.info.qualname}.<{node.name}>", node=node,
            cls=parent.info.cls, requires=set(), file=parent.info.file)
        fn = _Fn(key=(parent.key[0], info.qualname), info=info,
                 module=parent.module,
                 scope=_Scope(index, index.lookup(parent.key[0])
                              if isinstance(parent.key[0], str) else None,
                              node),
                 summarized=False)
        if fn.key not in funcs:
            funcs[fn.key] = fn
            _collect(index, fn.module, fn, nested)
    return funcs


def _summaries(funcs: dict) -> dict:
    summary = {k: set(fn.direct) for k, fn in funcs.items()}
    changed = True
    while changed:
        changed = False
        for key, fn in funcs.items():
            acc = summary[key]
            before = len(acc)
            for callee in fn.callees:
                sub = funcs.get(callee)
                if sub is not None and sub.summarized:
                    acc |= summary[callee]
            if len(acc) != before:
                changed = True
    return summary


def _edges(funcs: dict, summary: dict) -> tuple:
    """Returns (edges {(A, B): witness}, waived_events [Finding])."""
    edges: dict = {}
    waived_events: list = []

    def add(a: str, b: str, witness: str, ev: _Event, held_expr: str,
            acq_expr: Optional[str]) -> None:
        if a == b:
            if acq_expr is not None and acq_expr == held_expr:
                return      # same normalized expr: same-instance reentrance
            if a in A.SELF_ORDER_OK:
                return
        if ev.waived:
            waived_events.append(Finding(
                rule="lock-order", file=witness.rsplit(":", 1)[0],
                line=ev.line, identifier=f"edge:{a} -> {b}",
                message=f"edge {a} -> {b} suppressed by waiver at {witness}"))
            return
        edges.setdefault((a, b), witness)

    for key, fn in funcs.items():
        for ev in fn.events:
            if ev.kind == "acquire":
                for b in ev.classes:
                    for a, held_expr in ev.held:
                        add(a, b, ev.site, ev, held_expr, ev.expr)
            else:
                if not ev.held:
                    continue
                for callee in ev.callees:
                    for b in summary.get(callee, ()):
                        for a, held_expr in ev.held:
                            add(a, b, f"{ev.site} (via {callee[0]}."
                                      f"{callee[1]})", ev, held_expr, None)
    return edges, waived_events


def _cycles(edges: dict) -> list:
    """Self-loops plus strongly-connected components of size > 1."""
    graph: dict = {}
    for (a, b) in edges:
        graph.setdefault(a, set()).add(b)
        graph.setdefault(b, set())
    out = []
    for a in sorted(graph):
        if a in graph[a]:
            out.append([a])
    # Tarjan SCC, iterative
    index_counter = [0]
    stack: list = []
    lowlink: dict = {}
    num: dict = {}
    on_stack: set = set()
    sccs: list = []

    def strongconnect(v: str) -> None:
        work = [(v, iter(sorted(graph[v])))]
        num[v] = lowlink[v] = index_counter[0]
        index_counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in num:
                    num[w] = lowlink[w] = index_counter[0]
                    index_counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(graph[w]))))
                    advanced = True
                    break
                elif w in on_stack:
                    lowlink[node] = min(lowlink[node], num[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                lowlink[parent] = min(lowlink[parent], lowlink[node])
            if lowlink[node] == num[node]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == node:
                        break
                if len(scc) > 1:
                    sccs.append(sorted(scc))

    for v in sorted(graph):
        if v not in num:
            strongconnect(v)
    out.extend(sccs)
    return out


def run(index: A.ProjectIndex) -> tuple:
    """Returns (findings, waived, edges) where edges maps (A, B) -> witness."""
    funcs = _build_functions(index)
    summary = _summaries(funcs)
    edges, waived_events = _edges(funcs, summary)
    findings = []
    for cyc in _cycles(edges):
        members = set(cyc)
        involved = {pair: w for pair, w in sorted(edges.items())
                    if pair[0] in members and pair[1] in members}
        witnesses = "; ".join(f"{a} -> {b} at {w}"
                              for (a, b), w in list(involved.items())[:6])
        ident = " -> ".join(cyc + [cyc[0]])
        findings.append(Finding(
            rule="lock-order", file=sorted(
                w.rsplit(":", 1)[0].split(" ")[0]
                for w in involved.values())[0] if involved else "?",
            line=0, identifier=f"cycle:{ident}",
            message=f"lock-order cycle {ident} ({witnesses})"))
    return findings, waived_events, edges
