"""Concurrency invariant analysis plane.

Two halves share one set of conventions:

* **Static passes** (`python -m repro.analysis`) — an ``ast``-walk suite
  that proves the ``# guarded-by:`` lock discipline, builds the lock-order
  acquisition digraph and rejects cycles, and flags mutation of interned /
  frozen value types outside construction.  See :mod:`repro.analysis.cli`.
* **Runtime sanitizer** (:mod:`repro.analysis.sanitizer`) — opt-in via
  ``REPRO_SANITIZE=1``; wraps the production locks to record per-thread
  acquisition stacks and assert the observed lock order stays acyclic.

This ``__init__`` re-exports only the sanitizer surface: production modules
import :func:`make_lock` unconditionally on their hot construction paths, so
the heavy static passes must never be pulled in transitively.
"""
from .sanitizer import (  # noqa: F401
    LockOrderViolation,
    SanitizedLock,
    allow_same_class_order,
    make_lock,
    note_acquire,
    note_blocking,
    note_release,
    observed_edges,
    reset,
    sanitize_enabled,
    violations,
)

__all__ = [
    "LockOrderViolation",
    "SanitizedLock",
    "allow_same_class_order",
    "make_lock",
    "note_acquire",
    "note_blocking",
    "note_release",
    "observed_edges",
    "reset",
    "sanitize_enabled",
    "violations",
]
