"""Annotation conventions, analysis registry, and the project index.

The passes read three comment conventions out of the source text (the
``ast`` module drops comments, so declarations are matched back to their
statement's source span):

``# guarded-by: self._lock``
    Trailing an attribute declaration (``self.x = ...`` in ``__init__`` /
    ``__post_init__``, or a dataclass field).  Every write to the attribute
    outside construction must happen while the named lock expression is
    held (a dominating ``with`` or a paired ``acquire()``), where the
    guard is spelled relative to the *owning instance* — a write through
    another receiver ``r`` requires ``r.<guard suffix>`` to be held.

``# guarded-by: external[why]``
    The attribute is mutable and shared but synchronized by a mechanism
    the pass cannot see (single-writer protocols, rebalance holding every
    shard lock).  Declares the invariant without a provable lock.

``# requires-lock: self.shard.lock``
    Trailing a ``def`` header (any of its physical lines): the method's
    contract is caller-holds-lock; the pass seeds the held set with it.

``# analysis: allow[rule] reason``
    Line-level waiver: findings of ``rule`` whose statement span covers
    this line are suppressed (they still appear in the JSON report under
    ``waived``).  Used for documented false positives only.

The REGISTRY section collects the facts that have no natural source line:
externally-synchronized whole classes, benign idempotent races, receiver
type hints, interning sites, and owner-only record fields.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Optional

# --------------------------------------------------------------- REGISTRY

#: Classes whose entire mutable state is externally synchronized; writes to
#: their attributes are never findings.  Keyed by class name, value is why.
EXTERNAL_CLASSES = {
    "SemanticCache": "owned by exactly one CacheShard; every entry/stats "
                     "mutation runs inside CacheShard.lock (_shard_op)",
    "CacheStats": "owned by a SemanticCache (same shard lock) or by "
                  "CacheCluster._retired_stats under _topology_lock",
    "ColdTier": "owned by exactly one TieredStore; every call runs under "
                "TieredStore._lock (write_payload targets unique tmp names)",
    "DurableManifest": "owned by exactly one ColdTier; serialized by the "
                       "owning TieredStore._lock",
}

#: (class, attr) pairs that are deliberate benign races: idempotent memos
#: where a lost race recomputes the same value.  Exempt from both
#: guarded-by and unannotated-shared-write.
BENIGN_RACES = {
    ("OlapExecutor", "_exact_cols"):
        "idempotent dtype-widening memo; racing writers store equal lists",
    ("OlapExecutor", "_nan_cols"):
        "idempotent NaN-column memo; racing writers store equal sets",
    ("OlapExecutor", "_devices"):
        "idempotent device-list memo; racing writers store equal tuples",
}

#: Receiver-name -> class-name hints for sites with no annotation to read.
TYPE_HINTS = {
    "shard": "CacheShard",
    "sh": "CacheShard",
    "tenant": "Tenant",
    "t": "Tenant",
    "sub": "OlapExecutor",
    "entry": "CacheEntry",
    "gate": "ReadWriteGate",
    "flight": "Flight",
    "fl": "Flight",
    "cluster": "CacheCluster",
    "store": "TieredStore",
}

#: ReadWriteGate attributes that act as ordering pseudo-locks (held across
#: the gated body; the gate's internal Condition is not).
GATE_PSEUDO_LOCKS = {"write": "ReadWriteGate.write", "read": "ReadWriteGate.read"}

#: Lock classes that may nest instances of themselves in a deterministic
#: instance order (mirrors sanitizer.allow_same_class_order call sites).
SELF_ORDER_OK = {"CacheShard.lock"}

#: (file suffix, frozen class, field) triples allowed to object.__setattr__
#: outside the class's defining module: blessed interning sites.
INTERNING_SITES = {
    ("cluster/cluster.py", "Signature", "_family_hash"),
    # level-lattice memo attached to the frozen schema: an idempotent,
    # schema-pure cache (racing attachers lose at most one warm memo dict)
    ("core/derivations.py", "StarSchema", "_lattice_memo"),
}

#: Owner-only mutable fields of otherwise-shared records: writes allowed
#: only inside the owning module (path suffix).
FROZEN_OWNERS = {
    "CacheEntry": {
        "fields": {"signature", "lru_stamp", "store_stamp"},
        "owner": "core/cache.py",
    },
}

# ------------------------------------------------------------- annotations

_GUARD_RE = re.compile(r"#\s*guarded-by:\s*(.+?)\s*$")
_EXTERNAL_RE = re.compile(r"^external\[(.*)\]$")
_REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*(.+?)\s*$")
_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\[([\w-]+)\]\s*(.*)$")


@dataclasses.dataclass
class GuardedAttr:
    cls: str
    attr: str
    guard: Optional[str]       # normalized expr ("self._lock"); None if external
    external: Optional[str]    # external[...] description
    file: str
    line: int


@dataclasses.dataclass
class FuncInfo:
    qualname: str              # "CacheShard._shard_op" or module func name
    node: ast.AST              # FunctionDef / AsyncFunctionDef
    cls: Optional[str]
    requires: set
    file: str


@dataclasses.dataclass
class ClassInfo:
    name: str
    file: str
    line: int
    guarded: dict = dataclasses.field(default_factory=dict)   # attr -> GuardedAttr
    locks: dict = dataclasses.field(default_factory=dict)     # attr -> order class
    attr_types: dict = dataclasses.field(default_factory=dict)  # attr -> set[class]
    methods: dict = dataclasses.field(default_factory=dict)   # name -> FuncInfo
    frozen: bool = False
    fields: set = dataclasses.field(default_factory=set)      # dataclass fields

    @property
    def owns_lock(self) -> bool:
        return bool(self.locks)


@dataclasses.dataclass
class ModuleInfo:
    path: str                  # absolute
    rel: str                   # repo-relative, forward slashes
    tree: ast.Module
    lines: list
    classes: dict = dataclasses.field(default_factory=dict)
    functions: dict = dataclasses.field(default_factory=dict)  # module-level
    waivers: dict = dataclasses.field(default_factory=dict)    # line -> set[rule]


@dataclasses.dataclass
class ProjectIndex:
    modules: list
    classes: dict              # class name -> ClassInfo (first definition wins)

    def lookup(self, name: str) -> Optional[ClassInfo]:
        return self.classes.get(name)


# ----------------------------------------------------------- expr helpers

def normalize(expr: ast.AST) -> Optional[str]:
    """Dotted-name form of a Name/Attribute chain, else None."""
    parts = []
    node = expr
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def annotation_classes(node: Optional[ast.AST]) -> set:
    """Class names referenced by a type annotation: handles Name,
    string annotations, Optional[...]/list[...] subscripts, and PEP 604
    unions ("SemanticCache | CacheCluster")."""
    out = set()
    if node is None:
        return out
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return out
    if isinstance(node, ast.Name):
        out.add(node.id)
    elif isinstance(node, ast.Attribute):
        out.add(node.attr)
    elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        out |= annotation_classes(node.left)
        out |= annotation_classes(node.right)
    elif isinstance(node, ast.Subscript):
        base = node.value
        basename = base.id if isinstance(base, ast.Name) else (
            base.attr if isinstance(base, ast.Attribute) else "")
        if basename in ("Optional", "Union"):
            sl = node.slice
            if isinstance(sl, ast.Tuple):
                for elt in sl.elts:
                    out |= annotation_classes(elt)
            else:
                out |= annotation_classes(sl)
    return out - {"None", "str", "int", "float", "bool", "dict", "list",
                  "set", "tuple", "bytes", "object", "Any"}


def span_lines(lines: list, node: ast.AST) -> list:
    """(lineno, text) pairs for the physical lines a node spans."""
    lo = getattr(node, "lineno", None)
    hi = getattr(node, "end_lineno", lo)
    if lo is None:
        return []
    return [(i, lines[i - 1]) for i in range(lo, min(hi, len(lines)) + 1)]


def _comment_match(lines: list, node: ast.AST, regex: re.Pattern):
    for _, text in span_lines(lines, node):
        m = regex.search(text)
        if m:
            return m
    return None


def _header_lines(lines: list, fn: ast.AST) -> list:
    """Physical lines of a def header (def line through the line before the
    first body statement)."""
    lo = fn.lineno
    hi = fn.body[0].lineno - 1 if fn.body else fn.lineno
    deco_hi = max((getattr(d, "end_lineno", lo) for d in fn.decorator_list),
                  default=lo - 1)
    lo = max(lo, deco_hi + 1) if fn.decorator_list else lo
    return [(i, lines[i - 1]) for i in range(lo, min(hi, len(lines)) + 1)]


def _is_make_lock(call: ast.AST) -> Optional[str]:
    if not isinstance(call, ast.Call):
        return None
    fname = normalize(call.func) or ""
    if fname.split(".")[-1] == "make_lock" and call.args and \
            isinstance(call.args[0], ast.Constant) and \
            isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


def _is_threading_lock(call: ast.AST) -> bool:
    if not isinstance(call, ast.Call):
        return False
    fname = normalize(call.func) or ""
    return fname.split(".")[-1] in ("Lock", "RLock", "Condition")


def waived(module: ModuleInfo, node: ast.AST, rule: str) -> bool:
    for lineno, _ in span_lines(module.lines, node):
        if rule in module.waivers.get(lineno, ()):
            return True
    return False


# ------------------------------------------------------------- the parser

_CTORS = ("__init__", "__post_init__")


def _parse_class(module: ModuleInfo, cdef: ast.ClassDef) -> ClassInfo:
    info = ClassInfo(name=cdef.name, file=module.rel, line=cdef.lineno)
    for deco in cdef.decorator_list:
        call = deco if isinstance(deco, ast.Call) else None
        fname = normalize(call.func if call else deco) or ""
        if fname.split(".")[-1] == "dataclass":
            if call:
                for kw in call.keywords:
                    if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is True:
                        info.frozen = True

    def note_decl(attr: str, stmt: ast.AST) -> None:
        m = _comment_match(module.lines, stmt, _GUARD_RE)
        if not m:
            return
        raw = m.group(1)
        ext = _EXTERNAL_RE.match(raw)
        info.guarded[attr] = GuardedAttr(
            cls=cdef.name, attr=attr,
            guard=None if ext else raw,
            external=ext.group(1) if ext else None,
            file=module.rel, line=stmt.lineno)

    # class-level dataclass fields
    for stmt in cdef.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
            attr = stmt.target.id
            info.fields.add(attr)
            info.attr_types[attr] = annotation_classes(stmt.annotation)
            note_decl(attr, stmt)
            # dataclass field lock: default_factory=lambda: make_lock("...")
            if isinstance(stmt.value, ast.Call):
                for kw in stmt.value.keywords:
                    if kw.arg == "default_factory" and \
                            isinstance(kw.value, ast.Lambda):
                        oc = _is_make_lock(kw.value.body)
                        if oc:
                            info.locks[attr] = oc
                        elif _is_threading_lock(kw.value.body):
                            info.locks[attr] = f"{cdef.name}.{attr}"

    # methods
    for stmt in cdef.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            requires = set()
            for _, text in _header_lines(module.lines, stmt):
                m = _REQUIRES_RE.search(text)
                if m:
                    requires.add(m.group(1))
            info.methods[stmt.name] = FuncInfo(
                qualname=f"{cdef.name}.{stmt.name}", node=stmt,
                cls=cdef.name, requires=requires, file=module.rel)

    # __init__ / __post_init__: self-attr declarations, locks, attr types
    for ctor_name in _CTORS:
        ctor = info.methods.get(ctor_name)
        if ctor is None:
            continue
        params = {}
        for arg in list(ctor.node.args.args) + list(ctor.node.args.kwonlyargs):
            params[arg.arg] = annotation_classes(arg.annotation)
        for stmt in ast.walk(ctor.node):
            targets = []
            value = None
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign):
                targets, value = [stmt.target], stmt.value
            for tgt in targets:
                if not (isinstance(tgt, ast.Attribute) and
                        isinstance(tgt.value, ast.Name) and
                        tgt.value.id == "self"):
                    continue
                attr = tgt.attr
                note_decl(attr, stmt)
                oc = _is_make_lock(value)
                if oc:
                    info.locks[attr] = oc
                elif _is_threading_lock(value):
                    info.locks[attr] = f"{cdef.name}.{attr}"
                if isinstance(stmt, ast.AnnAssign):
                    info.attr_types.setdefault(attr, set()).update(
                        annotation_classes(stmt.annotation))
                if isinstance(value, ast.Call):
                    fname = normalize(value.func) or ""
                    cls_name = fname.split(".")[-1]
                    if cls_name and cls_name[0].isupper():
                        info.attr_types.setdefault(attr, set()).add(cls_name)
                elif isinstance(value, ast.Name) and value.id in params:
                    info.attr_types.setdefault(attr, set()).update(
                        params[value.id])
    return info


def parse_module(path: str, repo_root: str) -> ModuleInfo:
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
    module = ModuleInfo(path=path, rel=rel, tree=ast.parse(src, filename=path),
                        lines=src.splitlines())
    for lineno, text in enumerate(module.lines, start=1):
        m = _ALLOW_RE.search(text)
        if m:
            module.waivers.setdefault(lineno, set()).add(m.group(1))
    for stmt in module.tree.body:
        if isinstance(stmt, ast.ClassDef):
            module.classes[stmt.name] = _parse_class(module, stmt)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            requires = set()
            for _, text in _header_lines(module.lines, stmt):
                m = _REQUIRES_RE.search(text)
                if m:
                    requires.add(m.group(1))
            module.functions[stmt.name] = FuncInfo(
                qualname=stmt.name, node=stmt, cls=None,
                requires=requires, file=module.rel)
    return module


def build_index(paths: list, repo_root: str) -> ProjectIndex:
    files = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    files.append(os.path.join(dirpath, fn))
    modules = [parse_module(f, repo_root) for f in sorted(set(files))]
    classes: dict = {}
    for mod in modules:
        for name, cinfo in mod.classes.items():
            classes.setdefault(name, cinfo)
    return ProjectIndex(modules=modules, classes=classes)
