"""Lock-discipline pass: prove every write to a ``# guarded-by:`` attribute
is dominated by a ``with`` (or paired ``acquire()``) on the declared lock.

Flow handling, deliberately pragmatic for a lint:

* ``with <dotted-expr>:`` adds the normalized expression to the held set
  for its body (any dotted context manager counts — guards are matched
  textually, so ``with self._cond:`` proves ``guarded-by: self._cond`` and
  ``with t.gate.write:`` proves ``guarded-by: self.gate.write`` on a
  ``t``-typed receiver).
* ``X.acquire()`` / ``X.release()`` statements toggle the held set for the
  remainder of the enclosing block (covers the try/finally multi-lock
  pattern in ``CacheCluster.set_shards``).
* ``# requires-lock:`` on the def header seeds the held set
  (caller-holds-lock contract; call sites are checked by the lock-order
  pass's graph, runtime truth by the sanitizer).
* Nested ``def``s are analyzed with an *empty* held set: a closure may run
  on another thread after the enclosing scope released everything.

Writes are attribute assigns (plain, augmented, annotated), ``del``,
subscript stores through an attribute, known mutator-method calls
(``append``/``update``/``move_to_end``/...), and ``setattr(obj, ...)``
(treated as writing every guarded attribute of the receiver's class).
``__init__`` / ``__post_init__`` are construction and exempt.

Cross-receiver writes (``flight.table = ...``) are checked when the
receiver's class can be inferred (parameter annotations, constructor-call
locals, registry TYPE_HINTS): the guard is re-rooted from ``self`` onto the
receiver expression.

A second rule, ``unannotated-shared-write``, is how the pass *surfaces*
undeclared shared state: in a class that owns a lock (``make_lock`` /
``threading.Lock`` attribute), any non-constructor write to an attribute
with no ``guarded-by`` declaration is a finding — the author must either
annotate the guard, declare ``external[...]``, or register a benign race.
"""
from __future__ import annotations

import ast
from typing import Optional

from . import annotations as A
from .findings import Finding

MUTATORS = {
    "add", "append", "appendleft", "clear", "discard", "extend", "insert",
    "move_to_end", "pop", "popitem", "popleft", "remove", "reverse",
    "setdefault", "sort", "update", "__setitem__",
}

_CTORS = ("__init__", "__post_init__")


class _Scope:
    """Per-function receiver-type context."""

    def __init__(self, index: A.ProjectIndex, cinfo: Optional[A.ClassInfo],
                 fn: ast.AST):
        self.index = index
        self.cinfo = cinfo
        self.params: dict = {}
        for arg in list(fn.args.args) + list(fn.args.kwonlyargs):
            self.params[arg.arg] = A.annotation_classes(arg.annotation)
        self.locals: dict = {}
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                    isinstance(stmt.targets[0], ast.Name) and \
                    isinstance(stmt.value, ast.Call):
                name = stmt.targets[0].id
                self.locals.setdefault(name, set()).update(
                    self._call_result_classes(stmt.value))

    def _call_result_classes(self, call: ast.Call) -> set:
        fname = A.normalize(call.func) or ""
        leaf = fname.split(".")[-1]
        if leaf and leaf[0].isupper() and self.index.lookup(leaf):
            return {leaf}
        # self.method(...) with a return annotation
        if fname.startswith("self.") and self.cinfo is not None:
            m = self.cinfo.methods.get(leaf)
            if m is not None:
                return A.annotation_classes(m.node.returns)
        return set()

    def receiver_classes(self, node: ast.AST) -> set:
        if isinstance(node, ast.Name):
            if node.id == "self":
                return {self.cinfo.name} if self.cinfo else set()
            out = set()
            out |= self.params.get(node.id, set())
            out |= self.locals.get(node.id, set())
            hint = A.TYPE_HINTS.get(node.id)
            if hint:
                out.add(hint)
            return out
        if isinstance(node, ast.Attribute):
            bases = self.receiver_classes(node.value)
            out = set()
            for b in bases:
                ci = self.index.lookup(b)
                if ci is not None:
                    out |= ci.attr_types.get(node.attr, set())
            return out
        if isinstance(node, ast.Call):
            return self._call_result_classes(node)
        return set()


def _target_writes(tgt: ast.AST):
    """Yield (receiver_node, attr, site) pairs for an assignment target."""
    if isinstance(tgt, ast.Attribute):
        yield tgt.value, tgt.attr, tgt
    elif isinstance(tgt, ast.Subscript):
        if isinstance(tgt.value, ast.Attribute):
            yield tgt.value.value, tgt.value.attr, tgt
    elif isinstance(tgt, (ast.Tuple, ast.List)):
        for elt in tgt.elts:
            yield from _target_writes(elt)
    elif isinstance(tgt, ast.Starred):
        yield from _target_writes(tgt.value)


def _own_exprs(stmt: ast.AST) -> list:
    """Expression nodes belonging to the statement itself — never the
    bodies of compound statements (those are walked with their own held
    sets)."""
    if isinstance(stmt, ast.Assign):
        return list(stmt.targets) + [stmt.value]
    if isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        return [stmt.target] + ([stmt.value] if stmt.value else [])
    if isinstance(stmt, ast.Delete):
        return list(stmt.targets)
    if isinstance(stmt, (ast.Expr, ast.Return)):
        return [stmt.value] if stmt.value is not None else []
    if isinstance(stmt, ast.Raise):
        return [e for e in (stmt.exc, stmt.cause) if e is not None]
    if isinstance(stmt, ast.Assert):
        return [e for e in (stmt.test, stmt.msg) if e is not None]
    if isinstance(stmt, (ast.If, ast.While)):
        return [stmt.test]
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [stmt.iter]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [item.context_expr for item in stmt.items]
    return []


def _expr_calls(exprs: list):
    """Call nodes in expression trees, pruning nested function bodies."""
    stack = list(exprs)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))


def _stmt_writes(stmt: ast.AST):
    """All attribute writes a single statement performs directly: assign
    targets, plus mutator-method calls and setattr in its own expressions."""
    if isinstance(stmt, ast.Assign):
        for tgt in stmt.targets:
            yield from _target_writes(tgt)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        yield from _target_writes(stmt.target)
    elif isinstance(stmt, ast.Delete):
        for tgt in stmt.targets:
            yield from _target_writes(tgt)
    for node in _expr_calls(_own_exprs(stmt)):
        if isinstance(node.func, ast.Attribute) and node.func.attr in MUTATORS:
            inner = node.func.value
            if isinstance(inner, ast.Attribute):
                yield inner.value, inner.attr, node
        elif isinstance(node.func, ast.Name) and node.func.id == "setattr" \
                and len(node.args) >= 2:
            yield node.args[0], "*", node


def _reroot(guard: str, receiver: str) -> str:
    """Re-root a guard declared against ``self`` onto a write-site
    receiver expression ("self.shard.lock" + "fl" -> "fl.shard.lock")."""
    if guard == "self":
        return receiver
    if guard.startswith("self."):
        return receiver + guard[len("self"):]
    return guard


def _check_write(module: A.ModuleInfo, scope: _Scope, func: A.FuncInfo,
                 held: set, recv: ast.AST, attr: str, site: ast.AST,
                 out: list, waived_out: list) -> None:
    recv_expr = A.normalize(recv)
    classes = scope.receiver_classes(recv)
    is_self = recv_expr == "self"
    for cls_name in sorted(classes):
        if cls_name in A.EXTERNAL_CLASSES:
            continue
        cinfo = scope.index.lookup(cls_name)
        if cinfo is None:
            continue
        attrs = [attr] if attr != "*" else sorted(cinfo.guarded)
        for a in attrs:
            if (cls_name, a) in A.BENIGN_RACES:
                continue
            g = cinfo.guarded.get(a)
            if g is not None:
                if g.external is not None:
                    continue
                needed = g.guard if is_self else _reroot(g.guard, recv_expr or "")
                if needed in held or g.guard in func.requires:
                    continue
                f = Finding(
                    rule="guarded-by", file=module.rel, line=site.lineno,
                    identifier=f"{cls_name}.{a}",
                    message=(f"{func.qualname} writes {cls_name}.{a} "
                             f"without holding {needed!r} "
                             f"(declared at {g.file}:{g.line}); "
                             f"held={sorted(held) or '[]'}"))
                (waived_out if A.waived(module, site, "guarded-by")
                 else out).append(f)
            elif is_self and cinfo.owns_lock and a not in cinfo.locks \
                    and func.qualname.split(".")[-1] not in _CTORS:
                f = Finding(
                    rule="unannotated-shared-write", file=module.rel,
                    line=site.lineno, identifier=f"{cls_name}.{a}",
                    message=(f"{func.qualname} writes {cls_name}.{a}, but "
                             f"the lock-owning class declares no "
                             f"'# guarded-by:' for it (annotate the guard, "
                             f"'external[...]', or register a benign race)"))
                (waived_out if A.waived(module, site,
                                        "unannotated-shared-write")
                 else out).append(f)


def _walk(module: A.ModuleInfo, scope: _Scope, func: A.FuncInfo,
          stmts: list, held: set, out: list, waived_out: list,
          nested: list) -> None:
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            nested.append(stmt)
            continue
        # acquire()/release() toggles for the remainder of this block
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call) \
                and isinstance(stmt.value.func, ast.Attribute):
            target = A.normalize(stmt.value.func.value)
            if target is not None:
                if stmt.value.func.attr == "acquire":
                    held.add(target)
                elif stmt.value.func.attr == "release":
                    held.discard(target)
        for recv, attr, site in _stmt_writes(stmt):
            _check_write(module, scope, func, held, recv, attr, site,
                         out, waived_out)
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            inner = set(held)
            for item in stmt.items:
                expr = A.normalize(item.context_expr)
                if expr is not None:
                    inner.add(expr)
            _walk(module, scope, func, stmt.body, inner, out, waived_out,
                  nested)
            continue
        for attr_name in ("body", "orelse", "finalbody"):
            sub = getattr(stmt, attr_name, None)
            if sub:
                _walk(module, scope, func, sub, held, out, waived_out, nested)
        for handler in getattr(stmt, "handlers", ()) or ():
            _walk(module, scope, func, handler.body, held, out, waived_out,
                  nested)


def _check_function(module: A.ModuleInfo, index: A.ProjectIndex,
                    cinfo: Optional[A.ClassInfo], func: A.FuncInfo,
                    out: list, waived_out: list) -> None:
    leaf = func.qualname.split(".")[-1]
    if cinfo is not None and leaf in _CTORS:
        return
    scope = _Scope(index, cinfo, func.node)
    nested: list = []
    # in-loop acquire() (e.g. "for sh in shards: sh.lock.acquire()") leaks
    # the held expr into the remainder of the block via a pre-scan
    _walk(module, scope, func, func.node.body, set(func.requires),
          out, waived_out, nested)
    for nfn in nested:
        sub = A.FuncInfo(qualname=f"{func.qualname}.<{nfn.name}>",
                         node=nfn, cls=func.cls, requires=set(),
                         file=func.file)
        _check_function(module, index, cinfo, sub, out, waived_out)


def run(index: A.ProjectIndex) -> tuple:
    """Returns (findings, waived)."""
    out: list = []
    waived_out: list = []
    for module in index.modules:
        for cinfo in module.classes.values():
            for func in cinfo.methods.values():
                _check_function(module, index, cinfo, func, out, waived_out)
        for func in module.functions.values():
            _check_function(module, index, None, func, out, waived_out)
    return out, waived_out
