"""Layered safety policy for NL-driven reuse (§3.7, §6.1).

NL canonicalization can be schema-valid yet semantically incorrect.  Reuse is
controlled by layered policies that prefer misses over false hits:

1. schema validation (always on; see validator.py),
2. confidence-gated reuse,
3. heuristic ambiguity checks (deployment-specific templates):
   unresolved relative time, underspecified spatial terms, and
   aggregation-word mismatches,
4. optional lightweight verification of NL-originated hits (time windows),
5. SQL-seeded-reuse mode: NL gets read-only cache access (no stores).
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import re
from typing import Optional

from .nl_canon import AGG_WORDS, RELATIVE_TIME_RE, NLResult
from .signature import Signature


@dataclasses.dataclass(frozen=True)
class SafetyPolicy:
    confidence_threshold: Optional[float] = 0.5
    heuristic_time: bool = True
    heuristic_spatial: bool = True
    heuristic_aggword: bool = True
    verify_time_window: bool = False  # optional lightweight hit verification
    sql_seeded_only: bool = False  # NL may read the cache but never populate it
    # deployment-specific: spatial terms that are underspecified for this
    # schema, e.g. {'area': ('zones.zone', 'zones.borough')}
    spatial_ambiguous_terms: tuple[tuple[str, tuple[str, ...]], ...] = ()
    # longer phrases that *specify* an otherwise-ambiguous term ('customer
    # region' specifies 'region'); stripped before the spatial check
    spatial_qualified_phrases: tuple[str, ...] = ()

    @staticmethod
    def conservative(spatial=(), qualified=()) -> "SafetyPolicy":
        return SafetyPolicy(0.7, True, True, True, True, False,
                            tuple(spatial), tuple(qualified))

    @staticmethod
    def balanced(spatial=(), qualified=()) -> "SafetyPolicy":
        return SafetyPolicy(0.5, True, True, False, False, False,
                            tuple(spatial), tuple(qualified))

    @staticmethod
    def aggressive() -> "SafetyPolicy":
        return SafetyPolicy(None, False, False, False, False, False, (), ())


@dataclasses.dataclass
class SafetyDecision:
    allow: bool
    reasons: tuple[str, ...] = ()

    def __bool__(self) -> bool:
        return self.allow


def gate_nl(
    policy: SafetyPolicy,
    text: str,
    result: NLResult,
    now: Optional[_dt.date] = None,
) -> SafetyDecision:
    """Decide whether an NL-derived signature may interact with the cache."""
    reasons: list[str] = []
    if result.signature is None:
        return SafetyDecision(False, (result.error or "no signature",))
    if policy.confidence_threshold is not None and result.confidence < policy.confidence_threshold:
        reasons.append(
            f"confidence {result.confidence:.2f} below threshold {policy.confidence_threshold}"
        )
    t = " " + re.sub(r"\s+", " ", text.lower()) + " "
    if policy.heuristic_time:
        reasons.extend(_check_time(t, result.signature, now))
    if policy.heuristic_spatial:
        reasons.extend(_check_spatial(t, result.signature, policy))
    if policy.heuristic_aggword:
        reasons.extend(_check_aggword(t, result.signature))
    return SafetyDecision(not reasons, tuple(reasons))


def _check_time(t: str, sig: Signature, now: Optional[_dt.date]) -> list[str]:
    """Reject unresolved relative time: a relative phrase with no date context
    cannot be anchored, and an open-ended window without context is a guess."""
    if RELATIVE_TIME_RE.search(t) and now is None:
        return ["unresolved relative time reference without current-date context"]
    if sig.time_window is not None and sig.time_window.open_ended and now is None:
        return ["open-ended time window without current-date context"]
    return []


def _check_spatial(t: str, sig: Signature, policy: SafetyPolicy) -> list[str]:
    """Reject underspecified spatial terms ('area' -> zone vs borough) when
    the signature actually uses one of the candidate columns.  Occurrences
    inside a qualifying phrase ('customer region') are specified, not
    ambiguous, and are stripped first."""
    out = []
    for phrase in sorted(policy.spatial_qualified_phrases, key=len, reverse=True):
        t = t.replace(" " + phrase + " ", " ").replace(" " + phrase + "s ", " ")
    used = set(sig.levels) | {f.col for f in sig.filters}
    for term, candidates in policy.spatial_ambiguous_terms:
        if (" " + term + " ") in t or (" " + term + "s ") in t:
            if used & set(candidates):
                out.append(f"underspecified spatial term {term!r}")
    return out


def _check_aggword(t: str, sig: Signature) -> list[str]:
    """Reject aggregation-word mismatches: the NL names an aggregation that
    the signature does not contain at all."""
    sig_aggs = {m.agg for m in sig.measures}
    matched: list[str] = []
    consumed = t
    for phrase, agg in AGG_WORDS:  # longest-phrase-first order in AGG_WORDS
        if phrase in consumed:
            matched.append(agg)
            consumed = consumed.replace(phrase, " ")
    for agg in matched:
        if agg not in sig_aggs:
            return [f"aggregation word implies {agg} but signature has {sorted(sig_aggs)}"]
    return []


def verify_hit_time_window(sig: Signature, cached_sig: Signature) -> bool:
    """Optional lightweight verification on NL-originated hits (§3.7): the
    served entry's window must equal the request's window.  Exact-intent
    matching already guarantees this; the check catches derivation bugs and
    future fuzzy-matching modes.  Returns True when safe."""
    return sig.time_window == cached_sig.time_window
