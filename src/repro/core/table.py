"""Columnar result tables (numpy-backed).

Cached OLAP results are small aggregates (§2); we hold them as named numpy
columns.  Derivations (roll-up / filter-down) operate directly on these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np


@dataclasses.dataclass
class ResultTable:
    columns: dict[str, np.ndarray]  # insertion order == presentation order

    def __post_init__(self):
        n = {len(v) for v in self.columns.values()}
        if len(n) > 1:
            raise ValueError(f"ragged result table: lengths {n}")

    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))

    @property
    def names(self) -> list[str]:
        return list(self.columns.keys())

    def nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.columns.values()))

    def project(self, names: Sequence[str]) -> "ResultTable":
        return ResultTable({n: self.columns[n] for n in names})

    def mask(self, m: np.ndarray) -> "ResultTable":
        return ResultTable({n: v[m] for n, v in self.columns.items()})

    def sort(self, keys: Sequence[tuple[str, bool]]) -> "ResultTable":
        """Stable sort by (name, desc) keys, last key least significant."""
        if self.num_rows == 0 or not keys:
            return self
        order = np.arange(self.num_rows)
        for name, desc in reversed(list(keys)):
            col = self.columns[name][order]
            idx = np.argsort(col, kind="stable")
            if desc:
                idx = idx[::-1]
                # keep stability under descending: argsort of negated rank
                col_sorted = col[idx]
                # re-stabilize equal runs (argsort reversed breaks stability)
                idx = idx[np.argsort(_rank_equal_runs(col_sorted), kind="stable")]
            order = order[idx]
        return ResultTable({n: v[order] for n, v in self.columns.items()})

    def head(self, k: int) -> "ResultTable":
        return ResultTable({n: v[:k] for n, v in self.columns.items()})

    def to_rows(self) -> list[tuple]:
        cols = list(self.columns.values())
        return [tuple(c[i] for c in cols) for i in range(self.num_rows)]

    def row_set(self, sig_digits: int = 5) -> frozenset:
        """Order-insensitive content fingerprint.  Floats are rounded to
        ``sig_digits`` significant digits: cached results may have been
        accumulated in f32 (seg_agg) or f64 (numpy oracle)."""
        return frozenset(tuple(_norm(x, sig_digits) for x in row) for row in self.to_rows())

    def equals(self, other: "ResultTable", ordered: bool = False,
               rtol: float = 1e-4) -> bool:
        """Content equality with float tolerance.  Unordered comparison aligns
        rows by the non-float (grouping key) columns — group-by results have
        unique key combinations per row — then compares float measures with
        ``allclose`` (results may be f32- or f64-accumulated)."""
        if self.num_rows != other.num_rows or len(self.columns) != len(other.columns):
            return False
        if self.num_rows == 0:
            return True
        a, b = self, other
        if not ordered:
            keys = [n for n, v in self.columns.items() if v.dtype.kind not in "fc"]
            order_keys = [(k, False) for k in keys] or [(self.names[0], False)]
            a = self.sort(order_keys)
            b = other.sort(order_keys)
        for (na, ca), (nb, cb) in zip(a.columns.items(), b.columns.items()):
            if na != nb:
                return False
            if ca.dtype.kind in "fc" or cb.dtype.kind in "fc":
                af = np.asarray(ca, np.float64)
                bf = np.asarray(cb, np.float64)
                both_nan = np.isnan(af) & np.isnan(bf)
                close = np.isclose(af, bf, rtol=rtol, atol=1e-8)
                if not np.all(close | both_nan):
                    return False
            elif not np.array_equal(np.asarray(ca, str) if ca.dtype.kind in "UO" else ca,
                                    np.asarray(cb, str) if cb.dtype.kind in "UO" else cb):
                return False
        return True

    def to_rows_normalized(self, sig_digits: int = 5) -> list[tuple]:
        return [tuple(_norm(x, sig_digits) for x in row) for row in self.to_rows()]


def _norm(x: Any, sig_digits: int = 5):
    if isinstance(x, (np.floating, float)):
        f = float(x)
        if f == 0 or not np.isfinite(f):
            return 0.0 if f == 0 else f
        return float(f"{f:.{sig_digits}g}")
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, (np.str_, str)):
        return str(x)
    return x


def _rank_equal_runs(sorted_col: np.ndarray) -> np.ndarray:
    """Helper for stable descending sort: ranks equal runs by position."""
    n = len(sorted_col)
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    change = np.ones(n, dtype=bool)
    change[1:] = sorted_col[1:] != sorted_col[:-1]
    return np.cumsum(change)


def eval_predicate(col: np.ndarray, op: str, val: Any) -> np.ndarray:
    """Vectorized predicate evaluation used by filter-down and executors."""
    if op == "in":
        vals = list(val) if isinstance(val, (list, tuple, frozenset, set)) else [val]
        return np.isin(col, np.asarray(vals, dtype=col.dtype))
    v = np.asarray(val, dtype=col.dtype)
    if op == "=":
        return col == v
    if op == "!=":
        return col != v
    if op == "<":
        return col < v
    if op == "<=":
        return col <= v
    if op == ">":
        return col > v
    if op == ">=":
        return col >= v
    raise ValueError(f"unknown op {op!r}")
