"""Core: the paper's contribution — OLAP Intent Signatures, canonicalization,
validation, the semantic cache with correctness-preserving derivations, and
the layered NL safety policy."""

from .cache import CacheStats, LookupResult, SemanticCache
from .middleware import Backend, Response, SemanticCacheMiddleware
from .nl_canon import MemoizedNL, NLResult, NLVocab, MeasureSense, SimulatedLLM
from .refresh import merge_tables, refreshable
from .safety import SafetyPolicy, gate_nl
from .schema import Column, Dimension, FactTable, Hierarchy, StarSchema
from .signature import (
    Filter,
    HavingClause,
    Measure,
    OrderKey,
    Signature,
    TimeWindow,
    signature_from_json,
)
from .sql_canon import CanonicalizationError, SQLCanonicalizer
from .sqlparse import SQLSyntaxError, UnsupportedQuery
from .table import ResultTable
from .validator import SignatureValidator

__all__ = [
    "Backend", "CacheStats", "CanonicalizationError", "Column", "Dimension",
    "FactTable", "Filter", "HavingClause", "Hierarchy", "LookupResult",
    "MeasureSense", "Measure", "MemoizedNL", "NLResult", "NLVocab", "OrderKey",
    "Response", "ResultTable", "SQLCanonicalizer", "SQLSyntaxError",
    "SafetyPolicy", "SemanticCache", "SemanticCacheMiddleware", "Signature",
    "SignatureValidator", "SimulatedLLM", "StarSchema", "TimeWindow",
    "UnsupportedQuery", "gate_nl", "merge_tables", "refreshable",
    "signature_from_json",
]
