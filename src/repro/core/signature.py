"""OLAP Intent Signature (§3.3) — the unified cache key for SQL and NL.

A signature captures *all* semantics that can affect the numerical output:
measures, grouping levels, filters, time window, post-aggregation operators,
and (optionally) a governed metric identity and tenant scope.  It serializes
to canonical JSON (sorted keys, normalized lists) and hashes with SHA-256 to a
fixed-length cache key, so different surface forms map to the same key.

Signatures are frozen after construction, so every derived form — the
canonical JSON, the SHA-256 key, the measure multiset, the filter set — is
*interned* on the instance the first time it is asked for and reused from
then on.  A request that flows one Signature object through lookup, miss
dedup, store, and spill therefore hashes exactly once; template-cache and
NL-memo hits that return a previously-interned instance hash zero times.
``key_hash_computations()`` exposes a counting hook so tests can assert the
one-hash-per-request invariant.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import hashlib
import json
from typing import Any, Optional

COMPOSABLE_AGGS = ("SUM", "COUNT", "MIN", "MAX")  # roll-up-safe (§3.6)
ALL_AGGS = COMPOSABLE_AGGS + ("AVG", "COUNT_DISTINCT")

_OPS = ("=", "!=", "<", "<=", ">", ">=", "in")

# Counting hook for the interning invariant: incremented only when a key is
# actually SHA-256'd (memoized re-reads are free).  Tests reset it around a
# request and assert at most one computation.
_KEY_COMPUTES = 0


def key_hash_computations() -> int:
    return _KEY_COMPUTES


def reset_key_hash_computations() -> None:
    global _KEY_COMPUTES
    _KEY_COMPUTES = 0


def _canon_value(v: Any) -> Any:
    """Canonical literal format: ints stay ints, floats normalized, strings
    stripped; dates as ISO 'YYYY-MM-DD' strings."""
    if isinstance(v, bool):
        return int(v)
    if isinstance(v, float):
        if v == int(v) and abs(v) < 1e15:
            return int(v)
        return float(v)
    if isinstance(v, _dt.date):
        return v.isoformat()
    if isinstance(v, str):
        return v.strip()
    if isinstance(v, (list, tuple)):
        return tuple(sorted((_canon_value(x) for x in v), key=lambda x: (str(type(x)), str(x))))
    return v


@dataclasses.dataclass(frozen=True)
class Measure:
    """Aggregation function + canonical base expression, e.g. SUM(sales.amount).

    ``expr`` is the canonical expression string produced by the canonicalizer
    (fully-qualified lowercase identifiers, commutative operands sorted).
    """

    agg: str
    expr: str
    distinct: bool = False

    def __post_init__(self):
        agg = self.agg.upper()
        object.__setattr__(self, "agg", "COUNT_DISTINCT" if (agg == "COUNT" and self.distinct) else agg)
        if self.agg not in ALL_AGGS:
            raise ValueError(f"unsupported aggregation {self.agg!r}")

    def composable(self) -> bool:
        return self.agg in COMPOSABLE_AGGS and not self.distinct

    def to_json(self) -> dict:
        d = {"agg": self.agg, "expr": self.expr}
        if self.distinct:
            d["distinct"] = True
        return d


@dataclasses.dataclass(frozen=True)
class Filter:
    """A normalized predicate over a non-temporal dimension/fact column."""

    col: str  # fully-qualified 'table.column'
    op: str
    val: Any

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unsupported filter op {self.op!r}")
        object.__setattr__(self, "val", _canon_value(self.val))
        # the JSON serialization of the (canonical) value is fixed at
        # construction — sort_key used to re-dump it on every comparison
        object.__setattr__(
            self, "_sort_key",
            (self.col, self.op, json.dumps(self.val, default=str, sort_keys=True)),
        )

    def sort_key(self) -> tuple:
        return self._sort_key

    def to_json(self) -> dict:
        v = self.val
        if isinstance(v, tuple):
            v = list(v)
        return {"col": self.col, "op": self.op, "val": v}


@dataclasses.dataclass(frozen=True)
class TimeWindow:
    """Explicit [start, end) boundaries on the time dimension (§3.3).

    ``open_ended`` marks windows derived from relative phrases ("last 30
    days"): they resolve to concrete boundaries at canonicalization time but
    must be refreshed on data arrival (§6.2), unlike closed windows.
    """

    start: str  # ISO date, inclusive
    end: str  # ISO date, exclusive
    open_ended: bool = False

    def __post_init__(self):
        s = _dt.date.fromisoformat(self.start)
        e = _dt.date.fromisoformat(self.end)
        if e < s:
            raise ValueError(f"time window end {self.end} before start {self.start}")

    def to_json(self) -> dict:
        d = {"start": self.start, "end": self.end}
        if self.open_ended:
            d["open_ended"] = True
        return d

    def contains(self, other: "TimeWindow") -> bool:
        return self.start <= other.start and other.end <= self.end

    def intersects(self, start: str, end: str) -> bool:
        return self.start < end and start < self.end


@dataclasses.dataclass(frozen=True)
class OrderKey:
    key: str  # level name or 'measure:<index>'
    desc: bool = False

    def to_json(self) -> dict:
        return {"key": self.key, "desc": self.desc}


@dataclasses.dataclass(frozen=True)
class HavingClause:
    """Post-aggregation predicate over a measure, by measure index."""

    measure: int
    op: str
    val: Any

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unsupported having op {self.op!r}")
        object.__setattr__(self, "val", _canon_value(self.val))

    def to_json(self) -> dict:
        v = self.val
        if isinstance(v, tuple):
            v = list(v)
        return {"measure": self.measure, "op": self.op, "val": v}


@dataclasses.dataclass(frozen=True)
class Signature:
    """The OLAP Intent Signature — canonical cache key (§3.3)."""

    schema: str  # schema name the signature is resolved against
    measures: tuple[Measure, ...]
    levels: tuple[str, ...] = ()  # 'dim.level' names, canonically sorted
    filters: tuple[Filter, ...] = ()
    time_window: Optional[TimeWindow] = None
    having: tuple[HavingClause, ...] = ()
    order_by: tuple[OrderKey, ...] = ()
    limit: Optional[int] = None
    metric_id: Optional[str] = None  # governed-layer identity (optional)
    scope: Optional[str] = None  # tenant/user isolation (optional)

    def __post_init__(self):
        if not self.measures:
            raise ValueError("signature requires at least one measure")
        object.__setattr__(self, "levels", tuple(sorted(self.levels)))
        object.__setattr__(
            self, "filters", tuple(sorted(self.filters, key=Filter.sort_key))
        )
        object.__setattr__(
            self, "having", tuple(sorted(self.having, key=lambda h: (h.measure, h.op, str(h.val))))
        )
        # set-semantics view of the filters, used by the derivation planners'
        # subset checks (Filter is frozen/hashable, so no JSON round trip)
        object.__setattr__(self, "_filters_frozen", frozenset(self.filters))

    def _interned(self, slot: str, compute):
        cached = self.__dict__.get(slot)
        if cached is None:
            cached = compute()
            object.__setattr__(self, slot, cached)
        return cached

    # ------------------------------------------------------------- canonical
    def to_json(self) -> dict:
        d: dict[str, Any] = {
            "schema": self.schema,
            "measures": [m.to_json() for m in self.measures],
            "levels": list(self.levels),
            "filters": [f.to_json() for f in self.filters],
        }
        if self.time_window is not None:
            d["time_window"] = self.time_window.to_json()
        if self.having:
            d["having"] = [h.to_json() for h in self.having]
        if self.order_by:
            d["order_by"] = [o.to_json() for o in self.order_by]
        if self.limit is not None:
            d["limit"] = self.limit
        if self.metric_id is not None:
            d["metric_id"] = self.metric_id
        if self.scope is not None:
            d["scope"] = self.scope
        return d

    def canonical_json(self) -> str:
        return self._interned("_canonical_json", lambda: json.dumps(
            self.to_json(), sort_keys=True, separators=(",", ":"), default=str))

    def key(self) -> str:
        """SHA-256 over the canonical JSON — the fixed-length cache key.
        Interned: computed once per instance (see ``key_hash_computations``)."""
        k = self.__dict__.get("_key")
        if k is None:
            global _KEY_COMPUTES
            _KEY_COMPUTES += 1
            k = hashlib.sha256(self.canonical_json().encode()).hexdigest()
            object.__setattr__(self, "_key", k)
        return k

    # --------------------------------------------------------------- helpers
    def has_order_or_limit(self) -> bool:
        return bool(self.order_by) or self.limit is not None

    def all_composable(self) -> bool:
        return all(m.composable() for m in self.measures)

    def measure_key(self) -> tuple:
        """Identity of the measure set (used by the derivation index)."""
        return self._interned("_measure_key", lambda: tuple(
            sorted((m.agg, m.expr, m.distinct) for m in self.measures)))

    def filters_frozen(self) -> frozenset:
        """The filters as a frozenset of :class:`Filter` (precomputed at
        construction) — the derivation planners' subset-check currency."""
        return self._filters_frozen

    def filter_set(self) -> frozenset:
        return self._interned("_filter_set", lambda: frozenset(
            (f.col, f.op, json.dumps(f.val, default=str)) for f in self.filters))

    def replace(self, **kw) -> "Signature":
        return dataclasses.replace(self, **kw)


def signature_from_json(obj: dict) -> Signature:
    """Parse a signature from (LLM-emitted) JSON.  Raises on malformed input —
    the safety layer treats parse failures as bypass."""
    measures = tuple(
        Measure(m["agg"], m["expr"], bool(m.get("distinct", False)))
        for m in obj["measures"]
    )
    filters = tuple(
        Filter(f["col"], f["op"], f["val"]) for f in obj.get("filters", ())
    )
    tw = None
    if obj.get("time_window"):
        t = obj["time_window"]
        tw = TimeWindow(t["start"], t["end"], bool(t.get("open_ended", False)))
    having = tuple(
        HavingClause(h["measure"], h["op"], h["val"]) for h in obj.get("having", ())
    )
    order = tuple(
        OrderKey(o["key"], bool(o.get("desc", False))) for o in obj.get("order_by", ())
    )
    return Signature(
        schema=obj["schema"],
        measures=measures,
        levels=tuple(obj.get("levels", ())),
        filters=filters,
        time_window=tw,
        having=having,
        order_by=order,
        limit=obj.get("limit"),
        metric_id=obj.get("metric_id"),
        scope=obj.get("scope"),
    )
