"""Incremental maintenance of cached aggregates (§6.2 refresh as a merge).

The composable-aggregate algebra that powers roll-up (derivations.py) also
makes cached results mergeable across *disjoint row partitions*: for
SUM/COUNT the partition aggregates add, for MIN/MAX they combine, and the
group-by key space of the union is the union of the partitions' key spaces.
So when a delta partition arrives, an affected cached entry can be brought
current by

    refresh(entry) = merge(cached table, aggregate of the delta rows)

costing one scan of the delta instead of a drop-and-recompute over the full
fact table.  The merge is exact — bit-for-bit the same selection results for
MIN/MAX, float-tolerance-identical sums — because grouped aggregation over a
disjoint row union decomposes per group.

Not everything is mergeable.  ``refreshable`` gates the algebra to

* composable measures only (SUM / COUNT / MIN / MAX, no DISTINCT): AVG and
  COUNT DISTINCT lose the information needed to merge (the cached table has
  no separate sum/count, and distinct sets don't add);
* no post-aggregation: HAVING changes group survival and ORDER BY / LIMIT
  change membership, so the cached rows are not the full group space.

Callers fall back to drop-and-recompute for the rest.  NaN semantics follow
the executors: a NaN that reached a cached or delta group value keeps
poisoning that group through the merge, exactly as a full rescan would.

Numpy-only on purpose: cached tables are small aggregates (§2), and the
merge must work on the oracle path without importing JAX.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .derivations import _group_inverse, _reaggregate
from .signature import Signature
from .table import ResultTable


def refreshable(sig: Signature) -> bool:
    """True when ``merge_tables`` is exact for this signature: composable
    measures only and no HAVING / ORDER BY / LIMIT."""
    return (sig.all_composable() and not sig.having and not sig.order_by
            and sig.limit is None)


def merge_tables(sig: Signature, base: ResultTable, delta: ResultTable) -> ResultTable:
    """Merge a cached aggregate with the same signature's aggregate over a
    disjoint delta partition.

    Both tables must be in the executor's canonical layout: one column per
    grouping level (decoded values), measures as ``m0..mK`` in signature
    order.  Group keys are unioned via the roll-up machinery
    (``_group_inverse``); appended rows can only add groups, never empty
    existing ones, so the union is the full recompute's group space.
    """
    return merge_partials(sig, (base, delta))


def merge_partials(sig: Signature, tables: Sequence[ResultTable]) -> ResultTable:
    """K-way generalization of :func:`merge_tables`: merge the signature's
    aggregates over any number of disjoint row partitions in one pass.

    This is the partition-parallel scan plane's combiner: each table is the
    fused scan of one fact partition (or streaming chunk), and one composite
    factorization over the concatenated key columns unions the group spaces.
    Because ``_group_inverse`` canonicalizes groups by *sorted value order* —
    independent of which partition contributed them or in what order the
    partials arrive — the merged table is invariant under permutation of
    ``tables``, and its row order matches the unpartitioned fused scan (whose
    dense group ids are also sorted-unique order).
    """
    if not refreshable(sig):
        raise ValueError(
            f"signature is not mergeable (non-composable measures or "
            f"post-aggregation): {sig.canonical_json()}")
    if not tables:
        raise ValueError("merge_partials requires at least one partial table")
    if len(tables) == 1:
        return tables[0]
    if not sig.levels:
        # global aggregate: one row per partial, combine directly
        cols = {}
        for i, m in enumerate(sig.measures):
            acc = np.asarray(tables[0].columns[f"m{i}"], np.float64)
            for t in tables[1:]:
                acc = _combine(m.agg, acc,
                               np.asarray(t.columns[f"m{i}"], np.float64))
            cols[f"m{i}"] = acc
        return ResultTable(cols)
    # partitions that matched no rows contribute no groups
    live = [t for t in tables if t.num_rows > 0]
    if not live:
        return tables[0]
    if len(live) == 1:
        return live[0]
    key_cols = [
        np.concatenate([np.asarray(t.columns[lv]) for t in live])
        for lv in sig.levels
    ]
    n = sum(t.num_rows for t in live)
    inverse, uniques = _group_inverse(key_cols, n)
    n_groups = len(uniques[0])
    out: dict[str, np.ndarray] = {lv: u for lv, u in zip(sig.levels, uniques)}
    for i, m in enumerate(sig.measures):
        vals = np.concatenate([
            np.asarray(t.columns[f"m{i}"], np.float64) for t in live])
        # partition values re-aggregate exactly like roll-up child groups:
        # SUM/COUNT add, MIN/MAX combine NaN-aware
        out[f"m{i}"] = _reaggregate(m.agg, vals, inverse, n_groups)
    return ResultTable(out)


def _combine(agg: str, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if agg in ("SUM", "COUNT"):
        return a + b
    red = np.minimum if agg == "MIN" else np.maximum
    with np.errstate(invalid="ignore"):  # NaN operands must poison, silently
        out = red(a, b)
    return np.where(np.isnan(a) | np.isnan(b), np.nan, out)
