"""Back-compat middleware shims over the batch-first service (§3.2).

The end-to-end request path — canonicalize, validate, NL-gate, cache lookup
(exact, then roll-up / filter-down derivations), miss execution, store —
lives in the staged pipeline of :mod:`repro.service`.  This module keeps the
original one-schema, one-query surface (``query_sql`` / ``query_nl`` and the
:class:`Response` envelope) as thin shims that submit one-element batches to
a single-tenant :class:`CacheService`, so existing call sites keep working
unchanged while new code talks to the service directly.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
from typing import TYPE_CHECKING, Optional, Protocol

from .cache import SemanticCache
from .nl_canon import NLCanonicalizer
from .safety import SafetyPolicy
from .schema import StarSchema
from .signature import Signature
from .table import ResultTable

if TYPE_CHECKING:  # pragma: no cover
    from ..service.api import QueryResult


def __getattr__(name: str):
    # Back-compat alias: the service-level per-tenant stats carry the
    # original MiddlewareStats fields (bypasses, nl_gated,
    # backend_executions) and more.  Resolved lazily — the service package
    # imports core submodules, so a module-level import here would be
    # circular when repro.service loads first.
    if name == "MiddlewareStats":
        from ..service.api import TenantStats

        return TenantStats
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class Backend(Protocol):
    """Any engine that can execute an intent signature (paper: DuckDB; here:
    the JAX columnar executor, or raw SQL for out-of-scope bypasses)."""

    def execute(self, sig: Signature) -> ResultTable: ...

    def execute_raw(self, sql: str) -> Optional[ResultTable]: ...


@dataclasses.dataclass
class Response:
    status: str  # 'hit_exact' | 'hit_rollup' | 'hit_filterdown' | 'miss' | 'bypass'
    table: Optional[ResultTable]
    signature: Optional[Signature]
    origin: str  # 'sql' | 'nl'
    bypass_reason: Optional[str] = None
    confidence: Optional[float] = None
    lookup_ms: float = 0.0
    backend_ms: float = 0.0
    canon_ms: float = 0.0
    source_origin: Optional[str] = None  # origin of the serving cache entry

    @property
    def hit(self) -> bool:
        return self.status.startswith("hit")


def _to_response(qr: "QueryResult") -> Response:
    t = qr.timings_ms
    return Response(
        status=qr.status, table=qr.table, signature=qr.signature,
        origin=qr.origin, bypass_reason=qr.bypass_reason,
        confidence=qr.confidence,
        lookup_ms=t.get("lookup", 0.0),
        backend_ms=t.get("execute", 0.0),
        canon_ms=t.get("canonicalize", 0.0) + t.get("validate", 0.0),
        source_origin=qr.source_origin,
    )


class SemanticCacheMiddleware:
    """One-tenant facade over :class:`repro.service.CacheService`."""

    def __init__(
        self,
        schema: StarSchema,
        backend: Backend,
        cache: SemanticCache,
        nl: Optional[NLCanonicalizer] = None,
        policy: SafetyPolicy = SafetyPolicy(),
        snapshot_id: str = "snap0",
    ):
        from ..service.service import CacheService

        self.schema = schema
        self.service = CacheService()
        self._tenant = self.service.register_tenant(
            schema=schema, backend=backend, cache=cache, nl=nl,
            policy=policy, snapshot_id=snapshot_id)
        self.sql_canon = self._tenant.sql_canon
        self.validator = self._tenant.validator
        self.stats = self._tenant.stats

    # The pre-service middleware read these per request, so reassigning
    # them (mw.policy = ..., tests swapping backends) must keep taking
    # effect: forward everything to the live tenant record.
    def _tenant_attr(name: str):  # noqa: N805 — descriptor factory
        def get(self):
            return getattr(self._tenant, name)

        def set_(self, value):
            setattr(self._tenant, name, value)

        return property(get, set_)

    backend = _tenant_attr("backend")
    cache = _tenant_attr("cache")
    nl = _tenant_attr("nl")
    policy = _tenant_attr("policy")
    snapshot_id = _tenant_attr("snapshot_id")
    del _tenant_attr

    def service_stats(self) -> dict:
        """Structured front-end observability for this tenant: per-stage
        p50/p95, template-cache and NL-memo counters, derivation-probe
        counters (see :meth:`repro.service.CacheService.stats`)."""
        return self.service.stats(self._tenant.name)

    # ------------------------------------------------------------------ SQL
    def query_sql(self, sql: str, scope: Optional[str] = None) -> Response:
        from ..service.api import QueryRequest

        return _to_response(self.service.submit(QueryRequest(sql=sql, scope=scope)))

    # ------------------------------------------------------------------- NL
    def query_nl(self, text: str, now: Optional[_dt.date] = None,
                 scope: Optional[str] = None) -> Response:
        from ..service.api import QueryRequest

        return _to_response(
            self.service.submit(QueryRequest(nl=text, now=now, scope=scope)))
