"""The semantic caching middleware — end-to-end request path (§3.2).

For each request: (1) canonicalize into an intent signature, (2) validate
against schema and safety rules, (3) look up the signature hash in the cache
(exact, then roll-up / filter-down derivations), (4) on a miss execute on the
backend and store the result under the signature.  Validation failures bypass
the cache and execute directly — the system never returns incorrect results
for unsupported patterns.  Every decision is auditable via the returned
:class:`Response`.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import time
from typing import Optional, Protocol

from .cache import LookupResult, SemanticCache
from .nl_canon import NLCanonicalizer, NLResult
from .safety import SafetyPolicy, gate_nl, verify_hit_time_window
from .schema import StarSchema
from .signature import Signature
from .sql_canon import CanonicalizationError, SQLCanonicalizer
from .sqlparse import SQLSyntaxError, UnsupportedQuery
from .table import ResultTable
from .validator import SignatureValidator


class Backend(Protocol):
    """Any engine that can execute an intent signature (paper: DuckDB; here:
    the JAX columnar executor, or raw SQL for out-of-scope bypasses)."""

    def execute(self, sig: Signature) -> ResultTable: ...

    def execute_raw(self, sql: str) -> Optional[ResultTable]: ...


@dataclasses.dataclass
class Response:
    status: str  # 'hit_exact' | 'hit_rollup' | 'hit_filterdown' | 'miss' | 'bypass'
    table: Optional[ResultTable]
    signature: Optional[Signature]
    origin: str  # 'sql' | 'nl'
    bypass_reason: Optional[str] = None
    confidence: Optional[float] = None
    lookup_ms: float = 0.0
    backend_ms: float = 0.0
    canon_ms: float = 0.0
    source_origin: Optional[str] = None  # origin of the serving cache entry

    @property
    def hit(self) -> bool:
        return self.status.startswith("hit")


@dataclasses.dataclass
class MiddlewareStats:
    bypasses: int = 0
    nl_gated: int = 0
    backend_executions: int = 0


class SemanticCacheMiddleware:
    def __init__(
        self,
        schema: StarSchema,
        backend: Backend,
        cache: SemanticCache,
        nl: Optional[NLCanonicalizer] = None,
        policy: SafetyPolicy = SafetyPolicy(),
        snapshot_id: str = "snap0",
    ):
        self.schema = schema
        self.backend = backend
        self.cache = cache
        self.nl = nl
        self.policy = policy
        self.snapshot_id = snapshot_id
        self.sql_canon = SQLCanonicalizer(schema)
        self.validator = SignatureValidator(schema)
        self.stats = MiddlewareStats()

    # ------------------------------------------------------------------ SQL
    def query_sql(self, sql: str, scope: Optional[str] = None) -> Response:
        t0 = time.perf_counter()
        try:
            sig = self.sql_canon.canonicalize(sql, scope=scope)
        except (UnsupportedQuery, SQLSyntaxError, CanonicalizationError) as e:
            return self._bypass(sql, "sql", str(e), t0)
        canon_ms = (time.perf_counter() - t0) * 1e3
        v = self.validator.validate(sig)
        if not v:
            return self._bypass(sql, "sql", "; ".join(v.reasons), t0, sig)
        return self._serve(sig, "sql", canon_ms, store=True)

    # ------------------------------------------------------------------- NL
    def query_nl(self, text: str, now: Optional[_dt.date] = None,
                 scope: Optional[str] = None) -> Response:
        if self.nl is None:
            return Response("bypass", None, None, "nl", "no NL canonicalizer configured")
        t0 = time.perf_counter()
        res: NLResult = self.nl.canonicalize(text, now)
        canon_ms = (time.perf_counter() - t0) * 1e3
        sig = res.signature
        if sig is not None and scope is not None:
            sig = sig.replace(scope=scope)
        if sig is None:
            self.stats.nl_gated += 1
            return self._nl_bypass(text, res, res.error or "canonicalization failed", canon_ms)
        v = self.validator.validate(sig)
        if not v:
            self.stats.nl_gated += 1
            return self._nl_bypass(text, res, "; ".join(v.reasons), canon_ms)
        gate = gate_nl(self.policy, text, res, now)
        if not gate:
            self.stats.nl_gated += 1
            return self._nl_bypass(text, res, "; ".join(gate.reasons), canon_ms)
        store = not self.policy.sql_seeded_only
        return self._serve(sig, "nl", canon_ms, store=store, confidence=res.confidence)

    # -------------------------------------------------------------- serving
    def _serve(self, sig: Signature, origin: str, canon_ms: float,
               store: bool, confidence: Optional[float] = None) -> Response:
        t0 = time.perf_counter()
        lr: LookupResult = self.cache.lookup(sig, request_origin=origin)
        lookup_ms = (time.perf_counter() - t0) * 1e3
        if lr.status != "miss":
            if (
                origin == "nl"
                and self.policy.verify_time_window
                and lr.source_key is not None
            ):
                src = self.cache.entry(lr.source_key)
                if src is not None and not verify_hit_time_window(sig, src.signature):
                    lr = LookupResult("miss", None)  # fail safe: treat as miss
            if lr.status != "miss":
                return Response(lr.status, lr.table, sig, origin,
                                confidence=confidence, lookup_ms=lookup_ms,
                                canon_ms=canon_ms, source_origin=lr.source_origin)
        t1 = time.perf_counter()
        table = self.backend.execute(sig)
        backend_ms = (time.perf_counter() - t1) * 1e3
        self.stats.backend_executions += 1
        if store:
            self.cache.put(sig, table, origin=origin, snapshot_id=self.snapshot_id)
        return Response("miss", table, sig, origin, confidence=confidence,
                        lookup_ms=lookup_ms, backend_ms=backend_ms, canon_ms=canon_ms)

    # -------------------------------------------------------------- bypass
    def _bypass(self, sql: str, origin: str, reason: str, t0: float,
                sig: Optional[Signature] = None) -> Response:
        self.stats.bypasses += 1
        t1 = time.perf_counter()
        table = self.backend.execute_raw(sql)
        backend_ms = (time.perf_counter() - t1) * 1e3
        self.stats.backend_executions += 1
        return Response("bypass", table, sig, origin, bypass_reason=reason,
                        backend_ms=backend_ms,
                        canon_ms=(t1 - t0) * 1e3)

    def _nl_bypass(self, text: str, res: NLResult, reason: str, canon_ms: float) -> Response:
        """NL requests that fail validation/safety run on the backend *only*
        when a well-formed signature exists; they are never stored unless the
        executed signature is well-formed and the policy allows it (§3.5)."""
        self.stats.bypasses += 1
        sig = res.signature
        table = None
        backend_ms = 0.0
        if sig is not None and self.validator.validate(sig):
            t1 = time.perf_counter()
            table = self.backend.execute(sig)
            backend_ms = (time.perf_counter() - t1) * 1e3
            self.stats.backend_executions += 1
        return Response("bypass", table, sig, "nl", bypass_reason=reason,
                        confidence=res.confidence, backend_ms=backend_ms,
                        canon_ms=canon_ms)
