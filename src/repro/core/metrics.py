"""Governed metric layer (§3.3 'Metric identity', §6.1).

In governed deployments (dbt Metrics, Cube) a metric identifier pins the
exact measure expressions and base filters, eliminating NL metric-name
ambiguity at the source: 'revenue' is whatever the governance layer says it
is, and the signature carries the metric_id so governed and ad-hoc requests
occupy disjoint key spaces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .signature import Filter, Measure, OrderKey, Signature, TimeWindow


@dataclasses.dataclass(frozen=True)
class GovernedMetric:
    metric_id: str  # e.g. 'finance.net_revenue'
    schema: str
    measures: tuple[Measure, ...]
    base_filters: tuple[Filter, ...] = ()  # governance-mandated slice
    description: str = ""
    # NL aliases that resolve to this metric with certainty
    aliases: tuple[str, ...] = ()


class MetricLayer:
    def __init__(self, metrics: tuple[GovernedMetric, ...] = ()):
        self._by_id: dict[str, GovernedMetric] = {}
        self._by_alias: dict[tuple[str, str], GovernedMetric] = {}
        for m in metrics:
            self.register(m)

    def register(self, m: GovernedMetric) -> None:
        if m.metric_id in self._by_id:
            raise ValueError(f"duplicate metric id {m.metric_id!r}")
        self._by_id[m.metric_id] = m
        for a in m.aliases:
            key = (m.schema, a.lower())
            if key in self._by_alias:
                raise ValueError(f"alias {a!r} already bound in schema {m.schema!r}")
            self._by_alias[key] = m

    def get(self, metric_id: str) -> Optional[GovernedMetric]:
        return self._by_id.get(metric_id)

    def resolve_alias(self, schema: str, text_term: str) -> Optional[GovernedMetric]:
        return self._by_alias.get((schema, text_term.lower()))

    def expand(
        self,
        metric_id: str,
        levels: tuple[str, ...] = (),
        filters: tuple[Filter, ...] = (),
        time_window: Optional[TimeWindow] = None,
        order_by: tuple[OrderKey, ...] = (),
        limit: Optional[int] = None,
        scope: Optional[str] = None,
    ) -> Signature:
        """Build the full intent signature for a governed request.  The
        metric's base filters merge with the request's; the metric_id is
        carried in the signature so governed keys never collide with ad-hoc
        ones even when expressions coincide."""
        m = self._by_id.get(metric_id)
        if m is None:
            raise KeyError(f"unknown governed metric {metric_id!r}")
        merged = tuple(sorted(set(m.base_filters) | set(filters),
                              key=Filter.sort_key))
        return Signature(
            schema=m.schema, measures=m.measures, levels=levels,
            filters=merged, time_window=time_window, order_by=order_by,
            limit=limit, metric_id=metric_id, scope=scope,
        )
