"""NL -> OLAP Intent Signature canonicalization (§3.4, NL path).

The paper maps NL to signatures with an LLM constrained to schema-valid JSON
plus an uncalibrated confidence score.  GPT-4o-mini is unavailable offline, so
this module provides:

* :class:`SimulatedLLM` — a vocabulary-grounded semantic parser that consumes
  the *text* (never the gold intent).  Genuine ambiguity in the text (a noun
  matching several columns, relative time without a date context, a missing
  aggregation word) surfaces as an explicit ambiguity event; resolution is a
  seeded stochastic choice whose per-ambiguity-type error rates are calibrated
  to the paper's Table 2 measurements (profiles for GPT-4o-mini and
  Claude-3.5-haiku).  Errors are therefore *schema-valid but semantically
  wrong* signatures — exactly the paper's failure mode.
* :class:`MemoizedNL` — the paper's NL-string -> signature memo (repeat NL
  requests skip the LLM; Table 4a "Repeat (memo) < 0.01 ms").

A real-model path exists too: ``repro.serving.engine.CanonicalizerService``
drives any of the ten assigned architectures with grammar-constrained JSON
decoding and plugs in behind the same :class:`NLCanonicalizer` protocol.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
import hashlib
import json
import re
from typing import Optional, Protocol

from ..analysis.sanitizer import make_lock

from .signature import Filter, Measure, Signature, TimeWindow

# ---------------------------------------------------------------- vocabulary


@dataclasses.dataclass(frozen=True)
class MeasureSense:
    """One meaning of a measure noun: e.g. 'revenue' -> SUM(sales.net_amount)."""

    expr: str
    default_agg: str = "SUM"


@dataclasses.dataclass
class NLVocab:
    """Schema-specific controlled vocabulary (the paper ships it in the LLM
    prompt; we ship it to the parser).  Ambiguity is explicit: a noun mapping
    to multiple senses / a term mapping to multiple levels."""

    schema: str
    # measure noun -> candidate senses (len>1 == metric-name ambiguity)
    measures: dict[str, tuple[MeasureSense, ...]]
    # grouping noun -> candidate levels 'dim.col' (len>1 == dimension ambiguity)
    levels: dict[str, tuple[str, ...]]
    # literal value -> candidate (column, value) pairs
    values: dict[str, tuple[tuple[str, str], ...]]
    # numeric filter phrases: noun -> fact column
    numeric_cols: dict[str, str] = dataclasses.field(default_factory=dict)
    # nouns whose *absence of an aggregation word* is ambiguous
    # (e.g. 'trips' could be COUNT or AVG per group)
    agg_ambiguous_nouns: tuple[str, ...] = ()


AGG_WORDS = [
    ("count of distinct", "COUNT_DISTINCT"),
    ("distinct count", "COUNT_DISTINCT"),
    ("number of distinct", "COUNT_DISTINCT"),
    ("average", "AVG"),
    ("mean", "AVG"),
    ("total", "SUM"),
    ("sum of", "SUM"),
    ("overall", "SUM"),
    ("count", "COUNT"),
    ("number of", "COUNT"),
    ("how many", "COUNT"),
    ("minimum", "MIN"),
    ("lowest", "MIN"),
    ("smallest", "MIN"),
    ("maximum", "MAX"),
    ("highest", "MAX"),
    ("largest", "MAX"),
]

RELATIVE_TIME_RE = re.compile(
    r"\b(last|past|previous|this|recent)\s+(month|quarter|year|week|\d+\s+days?)\b|\byesterday\b|\brecently\b"
)

_MONTHS = {
    m: i + 1
    for i, m in enumerate(
        ["january", "february", "march", "april", "may", "june", "july",
         "august", "september", "october", "november", "december"]
    )
}
for _m, _i in list(_MONTHS.items()):
    _MONTHS[_m[:3]] = _i


@dataclasses.dataclass
class NLResult:
    signature: Optional[Signature]
    confidence: float
    raw_json: str
    error: Optional[str] = None
    ambiguities: tuple[str, ...] = ()  # ambiguity types encountered


class NLCanonicalizer(Protocol):
    def canonicalize(self, text: str, now: Optional[_dt.date] = None) -> NLResult: ...

    # Canonicalizers may additionally expose
    #   canonicalize_batch(texts, now) -> list[NLResult]
    # to resolve a whole batch of NL requests in one model call; the service
    # pipeline uses it when present (duck-typed, optional).


# ------------------------------------------------------------ error profiles

# P(resolving a detected ambiguity *incorrectly*), per ambiguity type.
# Calibrated to Table 2 (GPT-4o-mini: 28/63 correct) and Table 5b
# (Claude-3.5-haiku: 38/63).  'compositional_invalid' is the probability a
# multi-measure request yields malformed JSON (5/15 for 4o-mini, 0 for haiku).
MODEL_PROFILES: dict[str, dict[str, float]] = {
    "gpt-4o-mini": {
        "metric": 0.45,
        "time": 0.95,
        "dimension": 0.96,
        "aggregation": 0.65,
        "compositional": 0.18,
        "compositional_invalid": 0.50,
    },
    "claude-3.5-haiku": {
        "metric": 0.20,
        "time": 0.60,
        "dimension": 0.52,
        "aggregation": 0.60,
        "compositional": 0.37,
        "compositional_invalid": 0.0,
    },
    "oracle": {  # for controlled main-workload runs: resolves nothing wrongly
        "metric": 0.0, "time": 0.0, "dimension": 0.0,
        "aggregation": 0.0, "compositional": 0.0, "compositional_invalid": 0.0,
    },
}


def _hash01(text: str, salt: str) -> float:
    h = hashlib.sha256((salt + "|" + text).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


# --------------------------------------------------------------- the parser


class SimulatedLLM:
    """Vocabulary-grounded NL parser with calibrated ambiguity resolution."""

    def __init__(self, vocab: NLVocab, model: str = "gpt-4o-mini"):
        self.vocab = vocab
        self.profile = MODEL_PROFILES[model]
        self.model = model

    # -- confidence bookkeeping: starts high, decays per ambiguity/guess
    def canonicalize(self, text: str, now: Optional[_dt.date] = None) -> NLResult:
        t = text.lower()
        t = re.sub(r"[?!,;:]", " ", t)
        t = re.sub(r"\.(?!\d)", " ", t)  # keep decimal points, drop periods
        t = " " + re.sub(r"\s+", " ", t.strip()) + " "
        conf = 0.92 + 0.08 * _hash01(text, "jitter")
        ambiguities: list[str] = []

        try:
            measures, conf = self._parse_measures(t, text, conf, ambiguities)
            if measures is None:  # malformed-output simulation
                return NLResult(None, 0.0, "", "malformed JSON from model",
                                tuple(ambiguities))
            levels, conf = self._parse_levels(t, text, conf, ambiguities)
            filters, conf = self._parse_filters(t, conf)
            tw, conf = self._parse_time(t, text, now, conf, ambiguities)
            limit, order, conf = self._parse_topk(t, levels, conf)
            having, conf = self._parse_having(t, conf)
            if not measures:
                return NLResult(None, 0.2 * conf, "", "no measure recognized",
                                tuple(ambiguities))
            sig = Signature(
                schema=self.vocab.schema,
                measures=tuple(measures),
                levels=tuple(levels),
                filters=tuple(filters),
                time_window=tw,
                having=tuple(having),
                order_by=tuple(order),
                limit=limit,
            )
        except Exception as e:  # any construction failure = invalid output
            return NLResult(None, 0.0, "", f"invalid signature: {e}", tuple(ambiguities))
        raw = json.dumps({**sig.to_json(), "confidence": round(conf, 3)}, sort_keys=True)
        return NLResult(sig, round(conf, 3), raw, None, tuple(ambiguities))

    # ------------------------------------------------------------- measures
    def _resolve(self, text: str, options, amb_type: str, ambiguities: list[str]):
        """Pick among ambiguous options with the calibrated error rate: index 0
        is the conventional/correct reading; a 'wrong' draw takes another."""
        if len(options) == 1:
            return options[0], 1.0
        ambiguities.append(amb_type)
        p_wrong = self.profile.get(amb_type, 0.5)
        r = _hash01(text, amb_type)
        # confidence correlates (noisily) with difficulty: the draws that
        # resolve wrongly skew lower — miscalibrated but informative, which is
        # what makes threshold gating useful at all (Table 3a)
        if r < p_wrong:
            alt = 1 + int(_hash01(text, amb_type + "#alt") * (len(options) - 1))
            return options[min(alt, len(options) - 1)], 0.30 + 0.26 * _hash01(text, amb_type + "#c")
        return options[0], 0.48 + 0.26 * _hash01(text, amb_type + "#c2")

    _FILTER_USE_RE = re.compile(
        r"^\s*(?:under|below|over|above|between|less than|more than|at least|at most)\s+\d"
    )

    def _parse_measures(self, t: str, raw_text: str, conf: float, ambiguities: list[str]):
        found: list[tuple[int, str, tuple[MeasureSense, ...]]] = []
        for noun, senses in self.vocab.measures.items():
            pos = t.find(" " + noun + " ")
            if pos < 0:
                pos = t.find(" " + noun + "s ")
            if pos >= 0:
                # a noun immediately followed by a comparator is a filter
                # usage ('quantity under 25'), not a requested measure
                after = t[pos + len(noun) + 2:]
                if noun in self.vocab.numeric_cols and self._FILTER_USE_RE.match(after):
                    continue
                found.append((pos, noun, senses))
        found.sort()
        # drop nouns contained in longer matched nouns at same position
        kept = []
        for pos, noun, senses in found:
            if any(noun != n2 and noun in n2 and abs(pos - p2) <= len(n2) for p2, n2, _ in found):
                continue
            kept.append((pos, noun, senses))
        if len(kept) > 1:  # compositional request (multiple measures)
            # only *ambiguous* compositions trigger the calibrated error model:
            # a measure without an explicit aggregation word, or 3+ measures.
            # 'total sales and total profit' is a clean controlled rewrite.
            explicit = [
                any(p in t[max(0, pos - 28): pos + len(noun) + 2] for p, _ in AGG_WORDS)
                for pos, noun, _ in kept
            ]
            if not all(explicit) or len(kept) > 2:
                ambiguities.append("compositional")
                if _hash01(raw_text, "compositional_invalid") < self.profile["compositional_invalid"]:
                    return None, conf
                p_wrong = self.profile["compositional"]
                if _hash01(raw_text, "compositional") < p_wrong:
                    kept = kept[:1]  # wrong: drops all but one measure
                conf *= 0.7
            else:
                conf *= 0.93
        measures: list[Measure] = []
        for pos, noun, senses in kept:
            sense, c = self._resolve(raw_text, list(senses), "metric", ambiguities)
            conf *= c
            agg, c2 = self._agg_for(t, pos, noun, sense, raw_text, ambiguities)
            conf *= c2
            if agg == "COUNT_DISTINCT":
                measures.append(Measure("COUNT", sense.expr, distinct=True))
            else:
                measures.append(Measure(agg, sense.expr))
        return measures, conf

    def _agg_for(self, t: str, pos: int, noun: str, sense: MeasureSense,
                 raw_text: str, ambiguities: list[str]) -> tuple[str, float]:
        window = t[max(0, pos - 28): pos + len(noun) + 2]
        for phrase, agg in AGG_WORDS:
            if phrase in window:
                return agg, 1.0
        # no aggregation word: ambiguous for flagged nouns ('average trips'
        # vs 'trip count'), default otherwise
        if noun in self.vocab.agg_ambiguous_nouns:
            options = [sense.default_agg, "AVG" if sense.default_agg != "AVG" else "COUNT"]
            agg, c = self._resolve(raw_text, options, "aggregation", ambiguities)
            return agg, c
        return sense.default_agg, 0.97

    # --------------------------------------------------------------- levels
    def _parse_levels(self, t: str, raw_text: str, conf: float, ambiguities: list[str]):
        levels: list[str] = []
        m = re.search(r" (?:by|per|for each|broken down by|grouped by) ", t)
        if not m:
            return levels, conf
        tail = t[m.end() - 1:]
        # strip relative-time phrases — 'last month' / 'this year' must not
        # contribute month/year grouping levels
        tail = RELATIVE_TIME_RE.sub(" ", tail)
        # strip filter value phrases — 'for category mfgr#12' must not
        # contribute a 'category' grouping level
        for val in sorted(self.vocab.values, key=len, reverse=True):
            tail = tail.replace(" " + val.lower() + " ", " ")
        # longest-noun-first matching over the grouping vocabulary
        for noun in sorted(self.vocab.levels, key=len, reverse=True):
            pat = " " + noun + " "
            if pat in tail or (" " + noun + "s ") in tail:
                options = list(self.vocab.levels[noun])
                lv, c = self._resolve(raw_text, options, "dimension", ambiguities)
                conf *= c
                if lv not in levels:
                    levels.append(lv)
                tail = tail.replace(pat, " ")
        return levels, conf

    # -------------------------------------------------------------- filters
    def _parse_filters(self, t: str, conf: float):
        filters: list[Filter] = []
        for val in sorted(self.vocab.values, key=len, reverse=True):
            if (" " + val.lower() + " ") in t:
                options = self.vocab.values[val]
                col, v = options[0]
                if len(options) > 1:
                    conf *= 0.8
                filters.append(Filter(col, "=", v))
                t = t.replace(" " + val.lower() + " ", " ")
        for noun, col in self.vocab.numeric_cols.items():
            m = re.search(
                rf"\b{re.escape(noun)}\b\s+between\s+(\d+(?:\.\d+)?)\s+and\s+(\d+(?:\.\d+)?)",
                t,
            )
            if m:
                filters.append(Filter(col, ">=", float(m.group(1))))
                filters.append(Filter(col, "<=", float(m.group(2))))
                conf *= 0.95
                continue
            # no digits may sit between the noun and its comparator — keeps
            # 'discount between 1 and 3 and quantity under 25' from binding
            # 'discount' to 'under 25'
            m = re.search(
                rf"\b{re.escape(noun)}\b[^\d.;]*?\b(under|below|less than|at most|over|above|more than|at least)\s+(\d+(?:\.\d+)?)",
                t,
            )
            if not m:
                m = re.search(
                    rf"\b(under|below|less than|at most|over|above|more than|at least)\s+(\d+(?:\.\d+)?)\s+{re.escape(noun)}\b",
                    t,
                )
            if m:
                word, num = m.group(1), float(m.group(2))
                op = {"under": "<", "below": "<", "less than": "<", "at most": "<=",
                      "over": ">", "above": ">", "more than": ">", "at least": ">="}[word]
                filters.append(Filter(col, op, num))
                conf *= 0.95
        return filters, conf

    # ----------------------------------------------------------------- time
    def _parse_time(self, t: str, raw_text: str, now: Optional[_dt.date],
                    conf: float, ambiguities: list[str]):
        # explicit quarter: 'q1 2024' / 'first quarter of 2024'
        m = re.search(r"\bq([1-4])\s*(?:of\s*)?(\d{4})\b", t)
        if m:
            q, y = int(m.group(1)), int(m.group(2))
            sm = 3 * (q - 1) + 1
            start = _dt.date(y, sm, 1)
            end = _dt.date(y + (q == 4), (sm + 3 - 1) % 12 + 1, 1)
            return TimeWindow(start.isoformat(), end.isoformat()), conf
        m = re.search(
            r"\b(january|february|march|april|may|june|july|august|september|october|november|december|jan|feb|mar|apr|jun|jul|aug|sep|oct|nov|dec)\s+(\d{4})\b",
            t,
        )
        if m:
            mo, y = _MONTHS[m.group(1)], int(m.group(2))
            start = _dt.date(y, mo, 1)
            end = _dt.date(y + (mo == 12), mo % 12 + 1, 1)
            return TimeWindow(start.isoformat(), end.isoformat()), conf
        m = re.search(r"\b(?:from|between)\s+(\d{4})\s+(?:to|and|through)\s+(\d{4})\b", t)
        if m:
            y1, y2 = int(m.group(1)), int(m.group(2))
            return TimeWindow(f"{y1:04d}-01-01", f"{y2 + 1:04d}-01-01"), conf
        m = re.search(r"\b(?:in|during|for)\s+(\d{4})\b", t)
        if m:
            y = int(m.group(1))
            return TimeWindow(f"{y:04d}-01-01", f"{y + 1:04d}-01-01"), conf
        m = re.search(r"\bfrom\s+(\d{4}-\d{2}-\d{2})\s+to\s+(\d{4}-\d{2}-\d{2})\b", t)
        if m:
            return TimeWindow(m.group(1), m.group(2)), conf
        rel = RELATIVE_TIME_RE.search(t)
        if rel:
            ambiguities.append("time")
            if now is None:
                # paper's headline time failure: 'last month' without a current
                # date context — the model guesses an anchor
                p_wrong = self.profile["time"]
                wrong = _hash01(raw_text, "time") < p_wrong
                anchor = _dt.date(2023, 6, 15) if wrong else _dt.date(2024, 3, 15)
                conf *= ((0.34 + 0.2 * _hash01(raw_text, "time#c")) if wrong
                         else (0.52 + 0.2 * _hash01(raw_text, "time#c2")))
            else:
                anchor = now
                conf *= 0.9
            win = self._relative_window(rel.group(0).strip(), anchor)
            if win is not None:
                return win, conf
            return None, conf * 0.6
        return None, conf

    @staticmethod
    def _relative_window(phrase: str, anchor: _dt.date) -> Optional[TimeWindow]:
        first_of_month = anchor.replace(day=1)
        if "month" in phrase:
            prev_end = first_of_month
            prev_start = (first_of_month - _dt.timedelta(days=1)).replace(day=1)
            if phrase.startswith("this"):
                return TimeWindow(first_of_month.isoformat(),
                                  anchor.isoformat(), open_ended=True)
            return TimeWindow(prev_start.isoformat(), prev_end.isoformat(), open_ended=True)
        if "quarter" in phrase:
            q = (anchor.month - 1) // 3
            qstart = _dt.date(anchor.year, 3 * q + 1, 1)
            if phrase.startswith("this"):
                return TimeWindow(qstart.isoformat(), anchor.isoformat(), open_ended=True)
            pq_end = qstart
            pq_start = _dt.date(anchor.year - (q == 0), (3 * ((q - 1) % 4)) + 1, 1)
            return TimeWindow(pq_start.isoformat(), pq_end.isoformat(), open_ended=True)
        if "year" in phrase:
            if phrase.startswith("this"):
                return TimeWindow(f"{anchor.year}-01-01", anchor.isoformat(), open_ended=True)
            return TimeWindow(f"{anchor.year - 1}-01-01", f"{anchor.year}-01-01", open_ended=True)
        m = re.search(r"(\d+)\s+days?", phrase)
        if m:
            d = int(m.group(1))
            return TimeWindow((anchor - _dt.timedelta(days=d)).isoformat(),
                              anchor.isoformat(), open_ended=True)
        if "yesterday" in phrase:
            y = anchor - _dt.timedelta(days=1)
            return TimeWindow(y.isoformat(), anchor.isoformat(), open_ended=True)
        return None

    # ---------------------------------------------------------------- having
    def _parse_having(self, t: str, conf: float):
        """'… having <anything> over 100' -> HAVING on the first measure."""
        from .signature import HavingClause

        m = re.search(
            r"\bhaving\b[^0-9]*?\b(over|above|more than|at least|under|below|less than|at most)\s+(\d+(?:\.\d+)?)",
            t,
        )
        if not m:
            return [], conf
        op = {"over": ">", "above": ">", "more than": ">", "at least": ">=",
              "under": "<", "below": "<", "less than": "<", "at most": "<="}[m.group(1)]
        return [HavingClause(0, op, float(m.group(2)))], conf * 0.92

    # ----------------------------------------------------------------- top-k
    def _parse_topk(self, t: str, levels: list[str], conf: float):
        m = re.search(r"\btop\s+(\d+)\b", t)
        if not m or not levels:
            return None, [], conf
        from .signature import OrderKey

        return int(m.group(1)), [OrderKey("measure:0", desc=True)], conf * 0.95


class MemoizedNL:
    """NL-string -> signature memo (§4): repeat NL requests skip the model."""

    def __init__(self, inner: NLCanonicalizer):
        self.inner = inner
        # one memo serves every request thread of a tenant; the inner model
        # call runs outside the lock (a lost race costs one duplicate model
        # call for the same text — setdefault keeps one canonical result)
        self._lock = make_lock("MemoizedNL._lock")
        self._memo: dict[tuple[str, Optional[str]], NLResult] = {}  # guarded-by: self._lock
        self.calls = 0  # guarded-by: self._lock
        self.memo_hits = 0  # guarded-by: self._lock

    def canonicalize(self, text: str, now: Optional[_dt.date] = None) -> NLResult:
        key = (text, now.isoformat() if now else None)
        with self._lock:
            res = self._memo.get(key)
            if res is not None:
                self.memo_hits += 1
                return res
            self.calls += 1
        res = self.inner.canonicalize(text, now)
        with self._lock:
            return self._memo.setdefault(key, res)

    def canonicalize_batch(self, texts: list[str],
                           now: Optional[_dt.date] = None) -> list[NLResult]:
        """Batch front door: memoized texts are served directly; the rest go
        to the inner canonicalizer's batch entry point in one call (falling
        back to a loop when it has none)."""
        nowk = now.isoformat() if now else None
        with self._lock:
            fresh = [t for t in texts if (t, nowk) not in self._memo]
            # preserve first-occurrence order, drop duplicates within batch
            fresh = list(dict.fromkeys(fresh))
            if fresh:
                self.calls += len(fresh)
        if fresh:
            batch_fn = getattr(self.inner, "canonicalize_batch", None)
            if batch_fn is not None:
                results = batch_fn(fresh, now)
            else:
                results = [self.inner.canonicalize(t, now) for t in fresh]
        fresh_set = set(fresh)
        with self._lock:
            if fresh:
                for t, r in zip(fresh, results):
                    self._memo.setdefault((t, nowk), r)
            out = []
            for t in texts:
                if t not in fresh_set:
                    self.memo_hits += 1
                out.append(self._memo[(t, nowk)])
        return out

    def clear(self) -> None:
        with self._lock:
            self._memo.clear()
            self.calls = 0
            self.memo_hits = 0
