"""Signature validation against the star schema (§3.5).

Before any reuse we validate that (1) all referenced measures/dimensions exist
and pass type checks, (2) the time window resolves to concrete boundaries,
(3) the implied join path is unique within the schema, and (4) unsupported
constructs trigger bypass.  Validation failures never raise out of
``validate`` — they return a structured report the middleware turns into a
conservative bypass (prefer misses over incorrect reuse).

This is the safety backstop for the NL path: LLM-emitted signatures are
arbitrary JSON and get *exactly* the same checks as SQL-derived ones.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
from collections import OrderedDict
from typing import Optional

from . import sqlparse as sp
from .schema import AmbiguousColumn, StarSchema, UnknownColumn
from .signature import Signature


@dataclasses.dataclass
class ValidationResult:
    ok: bool
    reasons: tuple[str, ...] = ()

    def __bool__(self) -> bool:
        return self.ok


class SignatureValidator:
    def __init__(self, schema: StarSchema, memo_capacity: int = 8192):
        self.schema = schema
        # validation is a pure function of (schema, signature) and the schema
        # is fixed per validator, so results are memoized by signature value:
        # repeat dashboard intents (the request-plane hot path) pay one dict
        # probe instead of re-parsing every measure expression
        self._memo: "OrderedDict[Signature, ValidationResult]" = OrderedDict()
        self._memo_capacity = memo_capacity

    # ------------------------------------------------------------------ api
    def validate(self, sig: Signature) -> ValidationResult:
        if self._memo_capacity <= 0:  # memo disabled (benchmark baseline)
            return self._validate(sig)
        cached = self._memo.get(sig)
        if cached is not None:
            self._memo.move_to_end(sig)
            return cached
        result = self._validate(sig)
        self._memo[sig] = result
        if len(self._memo) > self._memo_capacity:
            self._memo.popitem(last=False)
        return result

    def _validate(self, sig: Signature) -> ValidationResult:
        reasons: list[str] = []
        if sig.schema != self.schema.name:
            return ValidationResult(False, (f"schema mismatch: {sig.schema!r}",))
        for m in sig.measures:
            reasons.extend(self._check_measure(m.agg, m.expr, m.distinct))
        for lv in sig.levels:
            reasons.extend(self._check_level(lv))
        for f in sig.filters:
            reasons.extend(self._check_filter(f.col, f.op, f.val))
        reasons.extend(self._check_time_window(sig))
        for h in sig.having:
            if not (0 <= h.measure < len(sig.measures)):
                reasons.append(f"HAVING references measure {h.measure} out of range")
        for o in sig.order_by:
            if o.key.startswith("measure:"):
                try:
                    idx = int(o.key.split(":", 1)[1])
                except ValueError:
                    reasons.append(f"bad order key {o.key!r}")
                    continue
                if not (0 <= idx < len(sig.measures)):
                    reasons.append(f"ORDER BY measure {idx} out of range")
            elif o.key not in sig.levels:
                reasons.append(f"ORDER BY {o.key!r} not among grouping levels")
        if sig.limit is not None and (not sig.order_by or sig.limit < 0):
            reasons.append("LIMIT requires ORDER BY and a non-negative bound")
        # join-path uniqueness: every referenced dimension must exist and be
        # reachable by its single declared FK (guaranteed by schema.validate();
        # here we confirm references only name declared dimensions).
        for t in self._referenced_tables(sig):
            if t != self.schema.fact.name and self.schema.dimension(t) is None:
                reasons.append(f"no unique join path to unknown table {t!r}")
        return ValidationResult(not reasons, tuple(reasons))

    # ------------------------------------------------------------- internals
    def _referenced_tables(self, sig: Signature) -> set[str]:
        tabs: set[str] = set()
        for lv in sig.levels:
            if "." in lv:
                tabs.add(lv.split(".", 1)[0])
        for f in sig.filters:
            if "." in f.col:
                tabs.add(f.col.split(".", 1)[0])
        for m in sig.measures:
            if m.expr != "*":
                try:
                    for t in self._expr_tables(sp.parse_expr(m.expr)):
                        tabs.add(t)
                except (sp.SQLSyntaxError, sp.UnsupportedQuery):
                    pass
        return tabs

    def _expr_tables(self, e: sp.Expr) -> set[str]:
        if isinstance(e, sp.ColRef):
            return {e.table} if e.table else set()
        if isinstance(e, sp.BinOp):
            return self._expr_tables(e.left) | self._expr_tables(e.right)
        return set()

    def _check_measure(self, agg: str, expr: str, distinct: bool) -> list[str]:
        if expr == "*":
            if agg != "COUNT":
                return [f"{agg}(*) is invalid"]
            return []
        try:
            ast = sp.parse_expr(expr)
        except (sp.SQLSyntaxError, sp.UnsupportedQuery) as e:
            return [f"measure expression {expr!r}: {e}"]
        errs: list[str] = []

        def visit(node: sp.Expr) -> None:
            if isinstance(node, sp.ColRef):
                try:
                    t, col = self.schema.resolve_column(node.column, table=node.table)
                except (AmbiguousColumn, UnknownColumn) as e:
                    errs.append(str(e))
                    return
                if agg != "COUNT" and not col.is_numeric():
                    errs.append(f"{agg} over non-numeric {t}.{col.name}")
            elif isinstance(node, sp.BinOp):
                visit(node.left)
                visit(node.right)
            elif isinstance(node, sp.AggCall):
                errs.append("nested aggregate in measure expression")

        visit(ast)
        return errs

    def _check_level(self, level: str) -> list[str]:
        if "." not in level:
            return [f"grouping level {level!r} is not table-qualified"]
        t, c = level.split(".", 1)
        try:
            self.schema.resolve_column(c, table=t)
        except (AmbiguousColumn, UnknownColumn) as e:
            return [str(e)]
        return []

    def _check_filter(self, col: str, op: str, val) -> list[str]:
        if "." not in col:
            return [f"filter column {col!r} is not table-qualified"]
        t, c = col.split(".", 1)
        try:
            _, column = self.schema.resolve_column(c, table=t)
        except (AmbiguousColumn, UnknownColumn) as e:
            return [str(e)]
        # type check: comparisons on numeric columns need numeric literals
        vals = list(val) if isinstance(val, (list, tuple)) else [val]
        for v in vals:
            if column.is_numeric() and isinstance(v, str):
                return [f"filter {col} {op} {v!r}: string literal on numeric column"]
            if column.dtype == "str" and isinstance(v, (int, float)):
                return [f"filter {col} {op} {v!r}: numeric literal on string column"]
            if column.dtype == "date":
                try:
                    _dt.date.fromisoformat(str(v))
                except ValueError:
                    return [f"filter {col} {op} {v!r}: not an ISO date"]
        return []

    def _check_time_window(self, sig: Signature) -> list[str]:
        tw = sig.time_window
        if tw is None:
            return []
        try:
            s = _dt.date.fromisoformat(tw.start)
            e = _dt.date.fromisoformat(tw.end)
        except ValueError:
            return [f"time window boundaries not concrete ISO dates: {tw}"]
        if e < s:
            return [f"time window end before start: {tw}"]
        if self.schema.fact.date_column is None and self.schema.time_dimension is None:
            return ["schema has no time dimension but signature has a time window"]
        return []
