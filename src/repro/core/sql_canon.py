"""SQL -> OLAP Intent Signature canonicalization (§3.4, SQL path).

Deterministic AST normalization: identifier resolution against the star
schema, commutative predicate/operand ordering, literal canonicalization,
and time-window extraction.  Identical signatures imply identical semantics
under the §3.1 schema conditions.

Raises:
    sqlparse.UnsupportedQuery  — valid SQL outside the subset (cache bypass)
    sqlparse.SQLSyntaxError    — malformed SQL (cache bypass)
    CanonicalizationError      — schema-invalid references (cache bypass)

Request-plane fast path: :class:`SQLCanonicalizer` keeps a **parameterized
template cache**.  The query text is tokenized once into a literal-free
fingerprint plus its literal values; the fingerprint keys a cached slotted
AST (parsed once per template), and each distinct ``(literals, scope)``
binding memoizes its finished, interned :class:`Signature`.  A verbatim
dashboard re-arrival costs one dict probe (tier-0 exact-text memo); a
re-formatted arrival of a known binding costs one tokenize + two dict
probes; a warm-template arrival with fresh literals rebinds the literal
slots into the cached AST and re-runs only ``from_ast``.  The rebound parse
is structurally identical to a cold ``sqlparse.parse`` of the same text
(property-tested), so the fast path can never produce a different signature
than the cold path.
"""
from __future__ import annotations

import datetime as _dt
import re
from collections import OrderedDict
from typing import Optional

from ..analysis.sanitizer import make_lock
from . import sqlparse as sp
from .schema import AmbiguousColumn, StarSchema, UnknownColumn
from .signature import (
    Filter,
    HavingClause,
    Measure,
    OrderKey,
    Signature,
    TimeWindow,
)


class CanonicalizationError(Exception):
    """Schema-invalid SQL (unknown/ambiguous identifiers, bad joins)."""


# ------------------------------------------------------------------ helpers


def _next_day(iso: str) -> str:
    return (_dt.date.fromisoformat(iso) + _dt.timedelta(days=1)).isoformat()


def _month_window(year: int, month: int) -> tuple[str, str]:
    start = _dt.date(year, month, 1)
    end = _dt.date(year + (month == 12), month % 12 + 1, 1)
    return start.isoformat(), end.isoformat()


def _year_window(year: int) -> tuple[str, str]:
    return f"{year:04d}-01-01", f"{year + 1:04d}-01-01"


def _quarter_window(year: int, q: int) -> tuple[str, str]:
    sm = 3 * (q - 1) + 1
    start = _dt.date(year, sm, 1)
    if q == 4:
        end = _dt.date(year + 1, 1, 1)
    else:
        end = _dt.date(year, sm + 3, 1)
    return start.isoformat(), end.isoformat()


_MONTH_NAMES = {
    m.lower(): i + 1
    for i, m in enumerate(
        ["Jan", "Feb", "Mar", "Apr", "May", "Jun", "Jul", "Aug", "Sep", "Oct", "Nov", "Dec"]
    )
}


def _kind_window(kind: str, val) -> Optional[tuple[str, str]]:
    """Window for a single time-level equality value, per declared kind."""
    try:
        if kind == "year":
            return _year_window(int(val))
        if kind == "yearmonthnum":  # e.g. 199702
            v = int(val)
            return _month_window(v // 100, v % 100)
        if kind == "yearmonth_str":  # e.g. 'Mar1994'
            m = re.fullmatch(r"([A-Za-z]{3})\s?(\d{4})", str(val).strip())
            if not m or m.group(1).lower() not in _MONTH_NAMES:
                return None
            return _month_window(int(m.group(2)), _MONTH_NAMES[m.group(1).lower()])
        if kind == "yearquarter_str":  # e.g. '1997Q1'
            m = re.fullmatch(r"(\d{4})\s?Q([1-4])", str(val).strip(), re.IGNORECASE)
            if not m:
                return None
            return _quarter_window(int(m.group(1)), int(m.group(2)))
        if kind == "date":
            d = _dt.date.fromisoformat(str(val).strip())
            return d.isoformat(), _next_day(d.isoformat())
    except (ValueError, TypeError):
        return None
    return None


class _WindowAccum:
    """Intersects time-range constraints into one [start, end) window."""

    def __init__(self):
        self.start: Optional[str] = None
        self.end: Optional[str] = None

    def add(self, start: Optional[str], end: Optional[str]) -> None:
        if start is not None and (self.start is None or start > self.start):
            self.start = start
        if end is not None and (self.end is None or end < self.end):
            self.end = end

    def window(self) -> Optional[TimeWindow]:
        if self.start is None and self.end is None:
            return None
        if self.start is None or self.end is None:
            raise CanonicalizationError(
                "time window does not resolve to concrete [start, end) boundaries"
            )
        if self.end < self.start:
            # an empty window is concrete but selects nothing; normalize
            self.end = self.start
        return TimeWindow(self.start, self.end)


# ------------------------------------------------------------- canonicalizer


class _ParseCtx:
    """Resolution context for one ``from_ast`` invocation (alias map and
    joined-dimension set).  Threaded through the helpers explicitly: the
    canonicalizer instance is shared across request threads, so per-parse
    state must never live on ``self``."""

    __slots__ = ("aliases", "joined")

    def __init__(self, aliases: dict, joined: set):
        self.aliases = aliases
        self.joined = joined


class _Template:
    """One cached query template: the slotted AST plus a bounded LRU memo of
    ``(literal_values, scope) -> Signature`` bindings.  Signatures are frozen
    and interned, so sharing one instance across arrivals is safe (and is
    what makes repeat traffic hash-free)."""

    __slots__ = ("ast", "bindings")

    def __init__(self, ast: sp.Query):
        self.ast = ast
        self.bindings: "OrderedDict[tuple, Signature]" = OrderedDict()


class SQLCanonicalizer:
    def __init__(
        self,
        schema: StarSchema,
        *,
        template_cache: bool = True,
        max_templates: int = 1024,
        max_bindings_per_template: int = 4096,
    ):
        self.schema = schema
        self.template_cache = template_cache
        self.max_templates = max_templates
        self.max_bindings = max_bindings_per_template
        self.max_texts = 4 * max_bindings_per_template
        # one canonicalizer serves every request thread of a tenant (the
        # sharded-cluster regime): the LRU OrderedDicts and counters below
        # are guarded by _lock — move_to_end/popitem on a shared OrderedDict
        # can corrupt its recency list under a data race, not just drop a
        # count.  Parsing and from_ast run *outside* the lock (pure); a lost
        # cold-parse race costs one duplicate parse, never a wrong memo.
        self._lock = make_lock("SQLCanonicalizer._lock")
        self._templates: "OrderedDict[tuple, _Template]" = OrderedDict()  # guarded-by: self._lock
        # tier-0: exact text -> signature (a verbatim dashboard re-arrival
        # skips even tokenization; canonicalization is deterministic, so an
        # identical (text, scope) can only ever produce the identical result)
        self._text_memo: "OrderedDict[tuple, Signature]" = OrderedDict()  # guarded-by: self._lock
        # fast-path counters (surfaced by CacheService.stats())
        self.text_hits = 0  # guarded-by: self._lock
        self.template_hits = 0  # guarded-by: self._lock
        self.template_misses = 0  # guarded-by: self._lock
        self.binding_hits = 0  # guarded-by: self._lock
        self.binding_misses = 0  # guarded-by: self._lock

    # -- public entry
    def canonicalize(self, sql: str, scope: Optional[str] = None) -> Signature:
        if not self.template_cache:
            return self.from_ast(sp.parse(sql), scope=scope)
        tkey = (sql, scope)
        with self._lock:
            sig = self._text_memo.get(tkey)
            if sig is not None:
                self.text_hits += 1  # verbatim repeat: tokenize skipped too
                self._text_memo.move_to_end(tkey)
                return sig
        sig = self._canonicalize_template(sql, scope)
        with self._lock:
            self._text_memo[tkey] = sig
            if len(self._text_memo) > self.max_texts:
                self._text_memo.popitem(last=False)
        return sig

    def _canonicalize_template(self, sql: str, scope: Optional[str]) -> Signature:
        fp, tokens, values = sp.template_of(sql)  # pure: outside the lock
        bkey = (values, scope)
        with self._lock:
            tpl = self._templates.get(fp)
            if tpl is None:
                self.template_misses += 1  # cold tokenize + parse
            else:
                self.template_hits += 1  # fingerprint seen: parse skipped
                self._templates.move_to_end(fp)
                sig = tpl.bindings.get(bkey)
                if sig is not None:
                    self.binding_hits += 1  # memoized: from_ast skipped
                    tpl.bindings.move_to_end(bkey)
                    return sig
        if tpl is None:
            ast = sp.parse_slotted(tokens, sql)  # cold parse, outside the lock
            # cache the template even if from_ast below fails: the *parse* is
            # sound for every text with this fingerprint, and whether a given
            # literal binding canonicalizes (e.g. a time value that folds
            # into a window vs one that doesn't) is decided per binding
            with self._lock:
                tpl = self._templates.get(fp)
                if tpl is None:  # lost parse races adopt the winner's template
                    self._templates[fp] = tpl = _Template(ast)
                    if len(self._templates) > self.max_templates:
                        self._templates.popitem(last=False)
                sig = tpl.bindings.get(bkey)
                if sig is not None:
                    self.binding_hits += 1
                    tpl.bindings.move_to_end(bkey)
                    return sig
        with self._lock:
            self.binding_misses += 1  # warm template, fresh literals
        sig = self.from_ast(sp.bind_slots(tpl.ast, values), scope=scope)
        # only successful canonicalizations are memoized; failures keep
        # raising per arrival exactly like the cold path.  setdefault: a
        # concurrent binder of the same key keeps one canonical instance
        with self._lock:
            sig = tpl.bindings.setdefault(bkey, sig)
            tpl.bindings.move_to_end(bkey)
            if len(tpl.bindings) > self.max_bindings:
                tpl.bindings.popitem(last=False)
        return sig

    def template_stats(self) -> dict:
        """Template-cache counters: per-arrival outcome totals plus the
        current footprint (templates held, bindings memoized)."""
        with self._lock:
            return {
                "text_hits": self.text_hits,
                "template_hits": self.template_hits,
                "template_misses": self.template_misses,
                "binding_hits": self.binding_hits,
                "binding_misses": self.binding_misses,
                "templates": len(self._templates),
                "bindings": sum(len(t.bindings)
                                for t in self._templates.values()),
            }

    def from_ast(self, q: sp.Query, scope: Optional[str] = None) -> Signature:
        sch = self.schema
        # ---- table/alias resolution.  FROM must be the fact table; each JOIN
        # must follow a schema-declared FK->PK path to a distinct dimension.
        if q.table != sch.fact.name:
            raise CanonicalizationError(
                f"FROM {q.table!r} is not the fact table {sch.fact.name!r}"
            )
        alias_to_table: dict[str, str] = {q.alias: sch.fact.name}
        joined_dims: set[str] = set()
        for j in q.joins:
            dim = sch.dimension(j.table)
            if dim is None:
                if j.table == sch.fact.name:
                    raise sp.UnsupportedQuery("self-joins are outside the OLAP subset")
                raise CanonicalizationError(f"JOIN target {j.table!r} is not a dimension")
            if dim.name in joined_dims:
                raise sp.UnsupportedQuery(
                    f"dimension {dim.name!r} joined twice (role-playing) — bypass"
                )
            if j.alias in alias_to_table:
                raise CanonicalizationError(f"duplicate alias {j.alias!r}")
            # normalize ON order: fact.fk = dim.pk
            l_tab = self._table_of(j.left, alias_to_table, extra={j.alias: dim.name})
            r_tab = self._table_of(j.right, alias_to_table, extra={j.alias: dim.name})
            pair = {(l_tab, j.left.column), (r_tab, j.right.column)}
            want = {(sch.fact.name, dim.fact_fk), (dim.name, dim.pk)}
            if pair != want:
                raise CanonicalizationError(
                    f"join condition {pair} does not follow the schema FK path {want}"
                )
            alias_to_table[j.alias] = dim.name
            joined_dims.add(dim.name)
        # parse-scoped resolution context: threaded through the helpers
        # rather than stored on the (shared, concurrently-used) instance
        ctx = _ParseCtx(aliases=alias_to_table, joined=joined_dims)

        # ---- measures and grouping levels from the SELECT list
        measures: list[Measure] = []
        alias_to_measure: dict[str, int] = {}
        expr_to_measure: dict[str, int] = {}
        select_levels: list[str] = []
        for item in q.select:
            if isinstance(item.expr, sp.AggCall):
                m = self._measure(item.expr, ctx)
                idx = len(measures)
                measures.append(m)
                if item.alias:
                    alias_to_measure[item.alias] = idx
                expr_to_measure[f"{m.agg}|{m.expr}|{m.distinct}"] = idx
            elif isinstance(item.expr, sp.ColRef):
                select_levels.append(self._qualify(item.expr, ctx))
            else:
                raise sp.UnsupportedQuery(
                    "non-aggregate SELECT expressions are outside the OLAP subset"
                )
        if not measures:
            raise sp.UnsupportedQuery("queries without aggregation are outside the OLAP subset")

        group_levels = [self._qualify(c, ctx) for c in q.group_by]
        if set(select_levels) - set(group_levels):
            raise CanonicalizationError(
                "SELECT columns not covered by GROUP BY: "
                f"{sorted(set(select_levels) - set(group_levels))}"
            )

        # ---- filters & time window
        filters: list[Filter] = []
        wacc = _WindowAccum()
        for p in q.where:
            self._classify_predicate(p, filters, wacc, ctx)
        tw = wacc.window()

        # ---- HAVING over selected measures
        having: list[HavingClause] = []
        for p in q.having:
            having.append(
                self._having(p, alias_to_measure, expr_to_measure, ctx))

        # ---- ORDER BY / LIMIT
        order: list[OrderKey] = []
        for expr, desc in q.order_by:
            if isinstance(expr, sp.AggCall):
                m = self._measure(expr, ctx)
                k = f"{m.agg}|{m.expr}|{m.distinct}"
                if k not in expr_to_measure:
                    raise CanonicalizationError("ORDER BY aggregate not in SELECT")
                order.append(OrderKey(f"measure:{expr_to_measure[k]}", desc))
            elif isinstance(expr, sp.ColRef):
                name = expr.column
                if expr.table is None and name in alias_to_measure:
                    order.append(OrderKey(f"measure:{alias_to_measure[name]}", desc))
                else:
                    lv = self._qualify(expr, ctx)
                    if lv not in group_levels:
                        raise CanonicalizationError(f"ORDER BY {lv} not in GROUP BY")
                    order.append(OrderKey(lv, desc))
            else:
                raise sp.UnsupportedQuery("ORDER BY expression outside the OLAP subset")
        if q.limit is not None and not order:
            raise sp.UnsupportedQuery("LIMIT without ORDER BY is non-deterministic — bypass")

        return Signature(
            schema=sch.name,
            measures=tuple(measures),
            levels=tuple(group_levels),
            filters=tuple(filters),
            time_window=tw,
            having=tuple(having),
            order_by=tuple(order),
            limit=q.limit,
            scope=scope,
        )

    # ------------------------------------------------------------ resolution
    def _table_of(self, c: sp.ColRef, aliases: dict[str, str], extra=None) -> str:
        look = dict(aliases)
        if extra:
            look.update(extra)
        if c.table is not None:
            if c.table in look:
                return look[c.table]
            if c.table in self.schema.tables():
                return c.table
            raise CanonicalizationError(f"unknown table/alias {c.table!r}")
        try:
            t, _ = self.schema.resolve_column(c.column)
        except (AmbiguousColumn, UnknownColumn) as e:
            raise CanonicalizationError(str(e)) from e
        return t

    def _qualify(self, c: sp.ColRef, ctx: "_ParseCtx") -> str:
        """Resolve a column ref to canonical 'table.column'."""
        t = self._table_of(c, ctx.aliases)
        try:
            t2, col = self.schema.resolve_column(c.column, table=t)
        except (AmbiguousColumn, UnknownColumn) as e:
            raise CanonicalizationError(str(e)) from e
        if t2 != self.schema.fact.name and t2 not in ctx.joined:
            raise CanonicalizationError(
                f"column {t2}.{col.name} referenced without joining {t2!r}"
            )
        return f"{t2}.{col.name}"

    # ----------------------------------------------------------- expressions
    def _canon_expr(self, e: sp.Expr, ctx: "_ParseCtx") -> str:
        """Canonical expression string: fully-qualified identifiers, sorted
        operands under commutative ops, canonical literal formats."""
        if isinstance(e, sp.ColRef):
            return self._qualify(e, ctx)
        if isinstance(e, sp.Literal):
            v = e.value
            if isinstance(v, float) and v == int(v):
                return str(int(v))
            return repr(v) if isinstance(v, str) else str(v)
        if isinstance(e, sp.BinOp):
            l, r = self._canon_expr(e.left, ctx), self._canon_expr(e.right, ctx)
            if e.op in ("+", "*"):
                # flatten same-op chains and sort operands
                parts = sorted(self._flatten(e, e.op, ctx))
                return "(" + e.op.join(parts) + ")"
            return f"({l}{e.op}{r})"
        raise sp.UnsupportedQuery("aggregate nested inside expression")

    def _flatten(self, e: sp.Expr, op: str, ctx: "_ParseCtx") -> list[str]:
        if isinstance(e, sp.BinOp) and e.op == op:
            return self._flatten(e.left, op, ctx) + \
                self._flatten(e.right, op, ctx)
        return [self._canon_expr(e, ctx)]

    def _measure(self, a: sp.AggCall, ctx: "_ParseCtx") -> Measure:
        if a.arg is None:  # COUNT(*)
            return Measure("COUNT", "*", distinct=False)
        expr = self._canon_expr(a.arg, ctx)
        if a.distinct and a.func != "COUNT":
            raise sp.UnsupportedQuery(f"{a.func}(DISTINCT …) is outside the OLAP subset")
        self._check_measure_types(a, ctx)
        return Measure(a.func, expr, distinct=a.distinct)

    def _check_measure_types(self, a: sp.AggCall, ctx: "_ParseCtx") -> None:
        """Aggregations besides COUNT require numeric arguments."""
        if a.func == "COUNT":
            return

        def visit(e: sp.Expr) -> None:
            if isinstance(e, sp.ColRef):
                t = self._table_of(e, ctx.aliases)
                _, col = self.schema.resolve_column(e.column, table=t)
                if not col.is_numeric():
                    raise CanonicalizationError(
                        f"{a.func} over non-numeric column {t}.{col.name}"
                    )
            elif isinstance(e, sp.BinOp):
                visit(e.left)
                visit(e.right)

        visit(a.arg)

    # ------------------------------------------------------------ predicates
    def _classify_predicate(
        self, p: sp.Predicate, filters: list[Filter], wacc: _WindowAccum,
        ctx: "_ParseCtx"
    ) -> None:
        left, op, right = p.left, p.op, p.right
        # normalize literal-on-left comparisons
        if isinstance(left, sp.Literal) and isinstance(right, sp.ColRef):
            left, right = right, left
            op = {"<": ">", ">": "<", "<=": ">=", ">=": "<="}.get(op, op)
        if not isinstance(left, sp.ColRef):
            raise sp.UnsupportedQuery("predicate left side must be a column")
        col = self._qualify(left, ctx)
        tab, cname = col.split(".", 1)
        kind = self._time_kind(tab, cname)
        if kind is not None and self._try_time(col, kind, op, right, wacc):
            return
        # ordinary filter
        if op == "between":
            lo, hi = right
            filters.append(Filter(col, ">=", lo.value))
            filters.append(Filter(col, "<=", hi.value))
            return
        if op == "in":
            filters.append(Filter(col, "in", [l.value for l in right]))
            return
        if not isinstance(right, sp.Literal):
            raise sp.UnsupportedQuery("column-to-column predicates are outside the OLAP subset")
        filters.append(Filter(col, op, right.value))

    def _time_kind(self, tab: str, col: str) -> Optional[str]:
        if tab == self.schema.fact.name:
            if col == self.schema.fact.date_column:
                return "date"
            return None
        d = self.schema.dimension(tab)
        if d is None or tab != self.schema.time_dimension:
            return None
        return d.time_kind(col)

    def _try_time(self, col, kind, op, right, wacc: _WindowAccum) -> bool:
        """Fold a time predicate into the window accumulator.  Returns False
        when the predicate is time-typed but not range-expressible (it then
        stays an ordinary filter, which is still exact)."""
        def one(v):
            return _kind_window(kind, v)

        if op == "=":
            if not isinstance(right, sp.Literal):
                return False
            w = one(right.value)
            if w is None:
                return False
            wacc.add(*w)
            return True
        if op == "between":
            lo, hi = right
            wl, wh = one(lo.value), one(hi.value)
            if wl is None or wh is None:
                return False
            wacc.add(wl[0], wh[1])
            return True
        if op in ("<", "<=", ">", ">="):
            if not isinstance(right, sp.Literal):
                return False
            w = one(right.value)
            if w is None:
                return False
            start, end = w
            if op == ">=":
                wacc.add(start, None)
            elif op == ">":
                wacc.add(end, None)
            elif op == "<":
                wacc.add(None, start)
            else:  # <=
                wacc.add(None, end)
            return True
        return False  # 'in' over time levels stays an ordinary filter

    # --------------------------------------------------------------- having
    def _having(self, p: sp.Predicate, alias_idx, expr_idx,
                ctx: "_ParseCtx") -> HavingClause:
        left, op, right = p.left, p.op, p.right
        if op in ("between", "in"):
            raise sp.UnsupportedQuery("HAVING BETWEEN/IN is outside the OLAP subset")
        if isinstance(left, sp.Literal) and not isinstance(right, sp.Literal):
            left, right = right, left
            op = {"<": ">", ">": "<", "<=": ">=", ">=": "<="}.get(op, op)
        if not isinstance(right, sp.Literal):
            raise sp.UnsupportedQuery("HAVING must compare a measure to a literal")
        if isinstance(left, sp.AggCall):
            m = self._measure(left, ctx)
            k = f"{m.agg}|{m.expr}|{m.distinct}"
            if k not in expr_idx:
                raise CanonicalizationError("HAVING aggregate not in SELECT")
            return HavingClause(expr_idx[k], op, right.value)
        if isinstance(left, sp.ColRef) and left.table is None and left.column in alias_idx:
            return HavingClause(alias_idx[left.column], op, right.value)
        raise CanonicalizationError("HAVING must reference a selected measure")
