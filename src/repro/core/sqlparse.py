"""SQL parser for the paper's OLAP subset (§3.1).

Grammar (case-insensitive keywords):

    query      := SELECT select_item (',' select_item)*
                  FROM table_ref (join_clause)*
                  [WHERE conj] [GROUP BY colref (',' colref)*]
                  [HAVING conj] [ORDER BY order_item (',' order_item)*]
                  [LIMIT int]
    select_item:= expr [[AS] ident]
    join_clause:= [INNER] JOIN table_ref ON colref '=' colref
    table_ref  := ident [[AS] ident]
    conj       := pred (AND pred)*  |  '(' conj ')' (AND ...)*
    pred       := expr cmp expr | expr BETWEEN lit AND lit | expr IN '(' lit,* ')'
                  | expr [NOT] LIKE str
    expr       := term (('+'|'-') term)* ; term := factor (('*'|'/') factor)*
    factor     := lit | colref | agg '(' [DISTINCT] expr ')' | COUNT '(' '*' ')' | '(' expr ')'

Anything outside the subset — window functions (OVER), CTEs (WITH), set ops
(UNION/EXCEPT/INTERSECT), subqueries, OR disjunctions, DISTINCT projections,
outer joins — raises :class:`UnsupportedQuery`; the middleware bypasses the
cache for those, exactly as the paper prescribes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Union

KEYWORDS = {
    "select", "from", "where", "group", "by", "having", "order", "limit",
    "join", "inner", "on", "as", "and", "or", "not", "in", "between",
    "distinct", "asc", "desc", "like", "with", "union", "except", "intersect",
    "over", "left", "right", "full", "outer", "cross", "lateral", "recursive",
}
AGG_FUNCS = {"sum", "count", "min", "max", "avg"}
UNSUPPORTED_KEYWORDS = {
    "with", "union", "except", "intersect", "over", "left", "right", "full",
    "outer", "cross", "lateral", "recursive", "or",
}


class SQLSyntaxError(Exception):
    """The text is not valid SQL under our grammar."""


class UnsupportedQuery(Exception):
    """Valid-looking SQL that is outside the §3.1 subset -> cache bypass."""


# ------------------------------------------------------------------ tokenizer

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+|--[^\n]*|/\*.*?\*/)
  | (?P<num>\d+\.\d+|\.\d+|\d+)
  | (?P<str>'(?:[^']|'')*'|"(?:[^"]|"")*")
  | (?P<ident>[A-Za-z_][A-Za-z0-9_]*)
  | (?P<op><=|>=|<>|!=|=|<|>|\(|\)|,|\.|\*|/|\+|-|;)
    """,
    re.VERBOSE | re.DOTALL,
)


@dataclasses.dataclass(frozen=True)
class Token:
    kind: str  # 'num' | 'str' | 'ident' | 'kw' | 'op' | 'eof'
    value: str
    pos: int


def tokenize(sql: str) -> list[Token]:
    tokens: list[Token] = []
    i = 0
    while i < len(sql):
        m = _TOKEN_RE.match(sql, i)
        if not m:
            raise SQLSyntaxError(f"unexpected character {sql[i]!r} at {i}")
        i = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        val = m.group()
        if kind == "ident":
            low = val.lower()
            if low in KEYWORDS:
                tokens.append(Token("kw", low, m.start()))
            else:
                tokens.append(Token("ident", low, m.start()))
        elif kind == "str":
            quote = val[0]
            body = val[1:-1].replace(quote * 2, quote)
            tokens.append(Token("str", body, m.start()))
        elif kind == "op" and val == "<>":
            tokens.append(Token("op", "!=", m.start()))
        else:
            tokens.append(Token(kind or "op", val, m.start()))
    tokens.append(Token("eof", "", len(sql)))
    return tokens


# ------------------------------------------------------------------ AST nodes


@dataclasses.dataclass(frozen=True)
class ColRef:
    table: Optional[str]  # alias or table name (lowercased), None if bare
    column: str


@dataclasses.dataclass(frozen=True)
class Literal:
    value: Any  # int | float | str


@dataclasses.dataclass(frozen=True)
class BinOp:
    op: str  # '+', '-', '*', '/'
    left: "Expr"
    right: "Expr"


@dataclasses.dataclass(frozen=True)
class AggCall:
    func: str  # 'SUM' | 'COUNT' | 'MIN' | 'MAX' | 'AVG'
    arg: Optional["Expr"]  # None for COUNT(*)
    distinct: bool = False


Expr = Union[ColRef, Literal, BinOp, AggCall]


@dataclasses.dataclass(frozen=True)
class Slot:
    """Literal placeholder in a template AST (see :func:`parse_slotted`).

    ``index`` addresses the i-th num/str token of the query text (every
    num/str token is consumed as a literal by this grammar, so a sequential
    counter over consumed literal tokens matches token-stream order).
    ``negated`` marks a literal that appeared under a leading unary minus;
    :func:`bind_slots` applies the negation at bind time.
    """

    index: int
    negated: bool = False


@dataclasses.dataclass(frozen=True)
class Predicate:
    left: Expr
    op: str  # '=', '!=', '<', '<=', '>', '>=', 'in', 'between'
    right: Any  # Expr | list[Literal] | (Literal, Literal) for between


@dataclasses.dataclass(frozen=True)
class SelectItem:
    expr: Expr
    alias: Optional[str]


@dataclasses.dataclass(frozen=True)
class Join:
    table: str
    alias: str
    left: ColRef
    right: ColRef


@dataclasses.dataclass(frozen=True)
class Query:
    select: tuple[SelectItem, ...]
    table: str
    alias: str
    joins: tuple[Join, ...]
    where: tuple[Predicate, ...]
    group_by: tuple[ColRef, ...]
    having: tuple[Predicate, ...]
    order_by: tuple[tuple[Expr, bool], ...]  # (expr, desc)
    limit: Optional[int]


# -------------------------------------------------------------------- parser


class _Parser:
    def __init__(self, tokens: list[Token], sql: str, slotted: bool = False):
        self.toks = tokens
        self.sql = sql
        self.i = 0
        # slot mode: literal tokens become Slot placeholders instead of
        # converted values (the template-cache cold parse)
        self._slotted = slotted
        self._slot_i = 0

    def _take_slot(self) -> int:
        k = self._slot_i
        self._slot_i += 1
        return k

    # -- token plumbing
    def peek(self, ahead: int = 0) -> Token:
        return self.toks[min(self.i + ahead, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, kind: str, value: Optional[str] = None) -> Optional[Token]:
        t = self.peek()
        if t.kind == kind and (value is None or t.value == value):
            return self.next()
        return None

    def expect(self, kind: str, value: Optional[str] = None) -> Token:
        t = self.accept(kind, value)
        if t is None:
            got = self.peek()
            raise SQLSyntaxError(
                f"expected {value or kind} at pos {got.pos}, got {got.value!r}"
            )
        return t

    def kw(self, word: str) -> bool:
        return self.accept("kw", word) is not None

    # -- entry
    def parse(self) -> Query:
        if self.peek().kind == "kw" and self.peek().value in UNSUPPORTED_KEYWORDS:
            raise UnsupportedQuery(f"{self.peek().value.upper()} is outside the OLAP subset")
        self.expect("kw", "select")
        if self.kw("distinct"):
            raise UnsupportedQuery("SELECT DISTINCT is outside the OLAP subset")
        select = [self.select_item()]
        while self.accept("op", ","):
            select.append(self.select_item())
        self.expect("kw", "from")
        table, alias = self.table_ref()
        joins: list[Join] = []
        while True:
            if self.peek().kind == "kw" and self.peek().value in (
                "left", "right", "full", "cross", "outer", "lateral",
            ):
                raise UnsupportedQuery(f"{self.peek().value.upper()} JOIN is outside the OLAP subset")
            if self.kw("inner"):
                self.expect("kw", "join")
            elif not self.kw("join"):
                break
            jt, ja = self.table_ref()
            self.expect("kw", "on")
            l = self.colref_only()
            self.expect("op", "=")
            r = self.colref_only()
            joins.append(Join(jt, ja, l, r))
        where: tuple[Predicate, ...] = ()
        if self.kw("where"):
            where = tuple(self.conjunction())
        group_by: list[ColRef] = []
        if self.kw("group"):
            self.expect("kw", "by")
            group_by.append(self.colref_only())
            while self.accept("op", ","):
                group_by.append(self.colref_only())
        having: tuple[Predicate, ...] = ()
        if self.kw("having"):
            having = tuple(self.conjunction())
        order_by: list[tuple[Expr, bool]] = []
        if self.kw("order"):
            self.expect("kw", "by")
            order_by.append(self.order_item())
            while self.accept("op", ","):
                order_by.append(self.order_item())
        limit = None
        if self.kw("limit"):
            tok = self.expect("num")
            # convert even in slot mode so a malformed bound (e.g. LIMIT 5.5)
            # raises identically on the template path and the cold path
            limit = int(tok.value)
            if self._slotted:
                limit = Slot(self._take_slot())
        self.accept("op", ";")
        t = self.peek()
        if t.kind != "eof":
            if t.kind == "kw" and t.value in UNSUPPORTED_KEYWORDS:
                raise UnsupportedQuery(f"{t.value.upper()} is outside the OLAP subset")
            raise SQLSyntaxError(f"trailing input at pos {t.pos}: {t.value!r}")
        return Query(
            select=tuple(select), table=table, alias=alias, joins=tuple(joins),
            where=where, group_by=tuple(group_by), having=having,
            order_by=tuple(order_by), limit=limit,
        )

    # -- pieces
    def table_ref(self) -> tuple[str, str]:
        t = self.expect("ident")
        alias = t.value
        if self.kw("as"):
            alias = self.expect("ident").value
        elif self.peek().kind == "ident":
            alias = self.next().value
        return t.value, alias

    def select_item(self) -> SelectItem:
        e = self.expr()
        alias = None
        if self.kw("as"):
            alias = self.expect("ident").value
        elif self.peek().kind == "ident":
            alias = self.next().value
        return SelectItem(e, alias)

    def order_item(self) -> tuple[Expr, bool]:
        e = self.expr()
        desc = False
        if self.kw("desc"):
            desc = True
        else:
            self.kw("asc")
        return e, desc

    def colref_only(self) -> ColRef:
        e = self.factor()
        if not isinstance(e, ColRef):
            raise SQLSyntaxError(f"expected column reference near pos {self.peek().pos}")
        return e

    def conjunction(self) -> list[Predicate]:
        preds = self.pred_group()
        while self.kw("and"):
            preds.extend(self.pred_group())
        if self.peek().kind == "kw" and self.peek().value == "or":
            raise UnsupportedQuery("OR disjunctions are outside the OLAP subset")
        return preds

    def pred_group(self) -> list[Predicate]:
        # parenthesized conjunction or single predicate; lookahead to tell a
        # paren-group of predicates from a parenthesized arithmetic expr
        if self.peek().kind == "op" and self.peek().value == "(" and self._paren_is_conj():
            self.expect("op", "(")
            preds = self.conjunction()
            self.expect("op", ")")
            return preds
        return [self.predicate()]

    def _paren_is_conj(self) -> bool:
        """Lookahead: does this '(' open a predicate conjunction (vs arithmetic)?"""
        depth = 0
        j = self.i
        while j < len(self.toks):
            t = self.toks[j]
            if t.kind == "op" and t.value == "(":
                depth += 1
            elif t.kind == "op" and t.value == ")":
                depth -= 1
                if depth == 0:
                    return False
            elif depth >= 1 and (
                (t.kind == "op" and t.value in ("=", "!=", "<", "<=", ">", ">="))
                or (t.kind == "kw" and t.value in ("between", "in", "and", "or", "like", "not"))
            ):
                return True
            j += 1
        return False

    def predicate(self) -> Predicate:
        left = self.expr()
        if self.kw("not"):
            if self.peek().kind == "kw" and self.peek().value in ("in", "like", "between"):
                raise UnsupportedQuery("NOT IN / NOT LIKE / NOT BETWEEN is outside the OLAP subset")
            raise SQLSyntaxError("unexpected NOT")
        if self.kw("between"):
            lo = self.literal()
            self.expect("kw", "and")
            hi = self.literal()
            return Predicate(left, "between", (lo, hi))
        if self.kw("in"):
            self.expect("op", "(")
            if self.peek().kind == "kw" and self.peek().value == "select":
                raise UnsupportedQuery("subqueries are outside the OLAP subset")
            vals = [self.literal()]
            while self.accept("op", ","):
                vals.append(self.literal())
            self.expect("op", ")")
            return Predicate(left, "in", vals)
        if self.kw("like"):
            raise UnsupportedQuery("LIKE predicates are outside the OLAP subset")
        for op in ("<=", ">=", "!=", "=", "<", ">"):
            if self.accept("op", op):
                right = self.expr()
                return Predicate(left, op, right)
        t = self.peek()
        raise SQLSyntaxError(f"expected comparison operator at pos {t.pos}, got {t.value!r}")

    def literal(self) -> Literal:
        t = self.peek()
        if t.kind == "num":
            self.next()
            if self._slotted:
                return Literal(Slot(self._take_slot()))
            return Literal(float(t.value) if "." in t.value else int(t.value))
        if t.kind == "str":
            self.next()
            if self._slotted:
                return Literal(Slot(self._take_slot()))
            return Literal(t.value)
        if t.kind == "op" and t.value == "-":
            self.next()
            n = self.expect("num")
            if self._slotted:
                return Literal(Slot(self._take_slot(), negated=True))
            return Literal(-(float(n.value) if "." in n.value else int(n.value)))
        raise SQLSyntaxError(f"expected literal at pos {t.pos}, got {t.value!r}")

    # -- expressions
    def expr(self) -> Expr:
        e = self.term()
        while True:
            if self.accept("op", "+"):
                e = BinOp("+", e, self.term())
            elif self.accept("op", "-"):
                e = BinOp("-", e, self.term())
            else:
                return e

    def term(self) -> Expr:
        e = self.factor()
        while True:
            if self.accept("op", "*"):
                e = BinOp("*", e, self.factor())
            elif self.accept("op", "/"):
                e = BinOp("/", e, self.factor())
            else:
                return e

    def factor(self) -> Expr:
        t = self.peek()
        if t.kind == "num" or t.kind == "str" or (t.kind == "op" and t.value == "-"):
            return self.literal()
        if t.kind == "op" and t.value == "(":
            self.next()
            e = self.expr()
            self.expect("op", ")")
            return e
        if t.kind == "ident":
            name = self.next().value
            if self.peek().kind == "op" and self.peek().value == "(":
                if name not in AGG_FUNCS:
                    raise UnsupportedQuery(f"function {name.upper()!r} is outside the OLAP subset")
                self.next()  # '('
                distinct = self.kw("distinct")
                if self.accept("op", "*"):
                    if name != "count":
                        raise SQLSyntaxError(f"{name.upper()}(*) is invalid")
                    arg = None
                else:
                    arg = self.expr()
                self.expect("op", ")")
                if self.peek().kind == "kw" and self.peek().value == "over":
                    raise UnsupportedQuery("window functions are outside the OLAP subset")
                return AggCall(name.upper(), arg, distinct)
            if self.accept("op", "."):
                col = self.expect("ident").value
                return ColRef(name, col)
            return ColRef(None, name)
        raise SQLSyntaxError(f"unexpected token {t.value!r} at pos {t.pos}")


def parse(sql: str) -> Query:
    """Parse SQL text into a Query AST (raises SQLSyntaxError / UnsupportedQuery)."""
    return _Parser(tokenize(sql), sql).parse()


# ------------------------------------------------------- template extraction

_INT_SLOT, _FLOAT_SLOT, _STR_SLOT = "?i", "?f", "?s"


def template_of(sql: str) -> tuple[tuple, list[Token], tuple]:
    """Tokenize once and split the text into structure and literals: returns
    ``(fingerprint, tokens, literal_values)``.

    The fingerprint is the token stream with each literal token replaced by a
    *typed* placeholder (int-like and float-like numbers are distinguished —
    ``1`` and ``1.5`` parse differently under LIMIT), so two texts share a
    fingerprint iff they differ only in literal values.  Keyword case,
    whitespace, and comments are already normalized away by the tokenizer.
    ``literal_values`` converts each num/str token exactly as the parser's
    ``literal()`` would, in token-stream order — the currency of
    :func:`bind_slots`.
    """
    tokens = tokenize(sql)
    fp: list = []
    values: list = []
    for t in tokens:
        if t.kind == "num":
            if "." in t.value:
                fp.append(_FLOAT_SLOT)
                values.append(float(t.value))
            else:
                fp.append(_INT_SLOT)
                values.append(int(t.value))
        elif t.kind == "str":
            fp.append(_STR_SLOT)
            values.append(t.value)
        else:
            fp.append((t.kind, t.value))
    return tuple(fp), tokens, tuple(values)


def parse_slotted(tokens: list[Token], sql: str) -> Query:
    """Parse a tokenized query into a *template* AST whose literals are
    :class:`Slot` placeholders.  Raises exactly like :func:`parse` — parse
    structure never depends on literal values, only on token kinds, so one
    slotted parse is valid for every text sharing the fingerprint."""
    return _Parser(tokens, sql, slotted=True).parse()


def bind_slots(q: Query, values) -> Query:
    """Substitute concrete literal values into a slotted template AST.

    ``bind_slots(parse_slotted(tokenize(sql)), template_of(sql)[2])`` is
    structurally identical to ``parse(sql)`` (property-tested in
    tests/test_frontend_fastpath.py) — that equality is the template cache's
    correctness guarantee.
    """

    def lit(l: Literal) -> Literal:
        s = l.value
        if isinstance(s, Slot):
            v = values[s.index]
            return Literal(-v if s.negated else v)
        return l

    def expr(e: Expr) -> Expr:
        if isinstance(e, Literal):
            return lit(e)
        if isinstance(e, BinOp):
            return BinOp(e.op, expr(e.left), expr(e.right))
        if isinstance(e, AggCall):
            return AggCall(e.func, None if e.arg is None else expr(e.arg), e.distinct)
        return e  # ColRef: no literals inside

    def pred(p: Predicate) -> Predicate:
        right = p.right
        if p.op == "between":
            lo, hi = right
            right = (lit(lo), lit(hi))
        elif p.op == "in":
            right = [lit(v) for v in right]
        elif isinstance(right, (Literal, BinOp, AggCall)):
            right = expr(right)
        return Predicate(expr(p.left), p.op, right)

    limit = q.limit
    if isinstance(limit, Slot):
        limit = values[limit.index]
    return Query(
        select=tuple(SelectItem(expr(s.expr), s.alias) for s in q.select),
        table=q.table, alias=q.alias, joins=q.joins,
        where=tuple(pred(p) for p in q.where),
        group_by=q.group_by,
        having=tuple(pred(p) for p in q.having),
        order_by=tuple((expr(e), d) for e, d in q.order_by),
        limit=limit,
    )


def parse_expr(text: str) -> Expr:
    """Parse a standalone expression (used to validate LLM-emitted measure
    expressions).  '*' alone denotes COUNT(*)'s argument placeholder."""
    if text.strip() == "*":
        return Literal("*")
    p = _Parser(tokenize(text), text)
    e = p.expr()
    if p.peek().kind != "eof":
        raise SQLSyntaxError(f"trailing input in expression: {text!r}")
    return e
