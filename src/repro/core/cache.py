"""The semantic cache store (§3.5, §3.6, §6.2).

Exact-intent lookup by signature hash, plus correctness-preserving
derivations (roll-up, filter-down) found through a metadata index keyed by
measure multiset — the in-memory analogue of the paper's SQLite derivation
index (entries matching requested measures with superset dimensions or
superset filters).  LRU eviction; snapshot-based invalidation where entries
whose time window intersects updated partitions (or is open-ended) are
refreshed while closed windows remain valid.

Derivation probes are **indexed**: within a measure-multiset bucket,
candidates are further keyed by time window (every derivation requires
window equality), then by exact filter tuple (roll-up requires filter
equality) and exact level tuple (filter-down requires level equality), with
set-semantics prefilters for the strict-subset checks.  A lookup therefore
runs ``plan_rollup``/``plan_filterdown`` only on structurally *viable*
candidates — bounded by the viable subset, not the bucket — visited in the
same most-recently-stored-first order as the pre-index linear scan, so hit/
miss outcomes are unchanged (``indexed_probes=False`` keeps the linear scan
for differential testing).  Entries carrying HAVING/ORDER BY/LIMIT can never
serve a derivation and are excluded from the tier-2 index at ``put``.

Accounting is byte-aware: every entry records its table's byte footprint,
``capacity_bytes`` bounds resident bytes alongside the entry-count
``capacity`` (LRU evicts until under *both* budgets), and
``stats.bytes_cached`` / ``stats.bytes_evicted`` expose the gauge/counter
pair.  Entries also carry global recency stamps so a sharded cluster
(:mod:`repro.cluster`) can migrate them between shards deterministically
(``export_entries`` / ``rebuild``).  Instances are single-threaded by
design; the cluster provides the locking.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict
from typing import Iterable, Optional

from . import derivations as dv
from .schema import StarSchema
from .signature import Signature, TimeWindow
from .table import ResultTable


def _discard(lst: list, item) -> None:
    try:
        lst.remove(item)
    except ValueError:
        pass


# Process-wide recency clock for cluster migration: every store and every
# touch draws a strictly increasing stamp, so entries moved between shards can
# be interleaved into the target's LRU order (``lru_stamp``) and derivation
# MRU order (``store_stamp``) deterministically, without comparing wall
# clocks.  ``itertools.count.__next__`` is atomic under the GIL, so stamps
# are safe to draw from concurrent shard threads.
_STAMP = itertools.count(1)


@dataclasses.dataclass
class CacheEntry:
    signature: Signature
    table: ResultTable
    origin: str  # 'sql' | 'nl'
    snapshot_id: str
    stored_at: float
    hits: int = 0
    refreshes: int = 0  # in-place table replacements on snapshot advance
    refreshed_at: Optional[float] = None
    table_nbytes: int = 0  # byte footprint of .table (capacity_bytes budget)
    lru_stamp: int = 0  # global recency stamp: last store or touch
    store_stamp: int = 0  # global stamp of the *first* store (MRU probe order)


@dataclasses.dataclass
class CacheStats:
    hits_exact: int = 0
    hits_rollup: int = 0
    hits_filterdown: int = 0
    hits_compose: int = 0
    misses: int = 0
    stores: int = 0
    evictions: int = 0
    invalidations: int = 0
    refreshes: int = 0  # entries merged in place from a delta scan
    refresh_fallbacks: int = 0  # affected entries replaced by a full recompute
    cross_surface_hits: int = 0  # NL request served by SQL-seeded entry or v.v.
    nl_hits: int = 0
    # derivation-probe observability: viable candidates visited vs plan
    # checks actually run (linear scans visit whole buckets; the index visits
    # only structurally viable candidates)
    derivation_candidates_scanned: int = 0
    derivation_plans_attempted: int = 0
    # byte-aware accounting: bytes_cached is a gauge of the current resident
    # table bytes; bytes_evicted counts bytes removed by LRU eviction
    bytes_cached: int = 0
    bytes_evicted: int = 0

    @property
    def hits(self) -> int:
        return (self.hits_exact + self.hits_rollup + self.hits_filterdown
                + self.hits_compose)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def to_dict(self) -> dict:
        """Serializable counter snapshot (fields + derived totals) for the
        service stats endpoints — the derived values are materialized here
        so ``json.dumps`` can never silently emit a bound method."""
        d = dataclasses.asdict(self)
        d["hits"] = self.hits
        d["lookups"] = self.lookups
        d["hit_rate"] = self.hit_rate
        return d


@dataclasses.dataclass
class LookupResult:
    """Outcome of one cache probe.

    ``status`` is one of ``'hit_exact'`` (signature-key match),
    ``'hit_rollup'`` (re-aggregated from a finer-grained entry),
    ``'hit_filterdown'`` (post-filtered from a superset entry),
    ``'hit_compose'`` (flag-gated beyond-paper derivation: filter-down
    composed with roll-up in one step, e.g. a cached (region, category)
    result answering "by region WHERE category = x"), or ``'miss'``.
    ``source_key``/``source_origin``/``source_snapshot`` identify the
    serving entry and the data snapshot its table reflects.
    """

    status: str
    table: Optional[ResultTable]
    source_key: Optional[str] = None
    source_origin: Optional[str] = None
    source_snapshot: Optional[str] = None


class _TwBucket:
    """Tier-2 derivation index for one (measure bucket, time window) group:
    candidates keyed by exact filter tuple (roll-up needs filter equality)
    and by exact level tuple (filter-down needs level equality)."""

    __slots__ = ("by_filters", "by_levels")

    def __init__(self):
        self.by_filters: dict[tuple, list[str]] = {}
        self.by_levels: dict[tuple, list[str]] = {}


class _MeasureBucket:
    """Tier-1 derivation index bucket: every entry sharing a measure
    multiset, in insertion order (the linear-scan path), plus the tier-2
    time-window index over the derivation-capable subset."""

    __slots__ = ("order", "by_tw")

    def __init__(self):
        self.order: list[str] = []
        self.by_tw: dict[Optional[TimeWindow], _TwBucket] = {}


class SemanticCache:
    def __init__(
        self,
        schema: StarSchema,
        capacity: Optional[int] = None,  # max entries; None = unbounded
        enable_rollup: bool = True,
        enable_filterdown: bool = True,
        enable_compose: bool = False,  # beyond-paper: filter-down o roll-up
        level_mapper: Optional[dv.LevelMapper] = None,
        indexed_probes: bool = True,  # False: pre-index linear scan (testing)
        capacity_bytes: Optional[int] = None,  # max table bytes; None = unbounded
    ):
        self.schema = schema
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self._bytes = 0  # resident table bytes (mirrors stats.bytes_cached)
        self.enable_rollup = enable_rollup
        self.enable_filterdown = enable_filterdown
        self.enable_compose = enable_compose
        self.level_mapper = level_mapper
        self.indexed_probes = indexed_probes
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        # derivation candidate index: (scope, schema, measure multiset)
        self._by_measures: dict[tuple, _MeasureBucket] = {}
        # reverse map key -> (bucket key, signature) so eviction/invalidation
        # unindexes without scanning every bucket
        self._index_of: dict[str, tuple] = {}
        # monotonic store sequence per key: the MRU merge order of the
        # indexed probe (== position in the bucket's insertion-order list)
        self._seq = 0
        self._seq_of: dict[str, int] = {}
        self.stats = CacheStats()

    # ------------------------------------------------------------------- api
    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, sig: Signature, request_origin: str = "sql") -> LookupResult:
        key = sig.key()
        entry = self._entries.get(key)
        if entry is not None:
            self._touch(key, entry, request_origin)
            self.stats.hits_exact += 1
            return LookupResult("hit_exact", entry.table, key, entry.origin,
                                entry.snapshot_id)

        # derivation pass: only post-aggregation-free requests can be served
        # by a derivation (every planner requires it), and only candidates
        # sharing the measure multiset are admissible
        bucket = self._by_measures.get((sig.scope, sig.schema, sig.measure_key()))
        if bucket is not None and dv.no_postagg(sig) and (
                self.enable_rollup or self.enable_filterdown or self.enable_compose):
            probe = self._probe_indexed if self.indexed_probes else self._probe_linear
            hit = probe(sig, request_origin, bucket)
            if hit is not None:
                return hit
        self.stats.misses += 1
        return LookupResult("miss", None)

    # ------------------------------------------------------ derivation probes
    def _attempt(self, sig: Signature, cand_key: str, cand: CacheEntry,
                 kind: str, request_origin: str) -> Optional[LookupResult]:
        """Run one derivation plan+apply; None when it doesn't pan out."""
        self.stats.derivation_plans_attempted += 1
        if kind == "rollup":
            plan = dv.plan_rollup(sig, cand.signature, self.schema, cand_key)
            if plan is None:
                return None
            derived = dv.apply_rollup(plan, sig, cand.signature, cand.table,
                                      self.level_mapper)
            if derived is None:
                return None
            self._touch(cand_key, cand, request_origin)
            self.stats.hits_rollup += 1
            return LookupResult("hit_rollup", derived, cand_key, cand.origin,
                                cand.snapshot_id)
        if kind == "filterdown":
            plan = dv.plan_filterdown(sig, cand.signature, self.schema, cand_key)
            if plan is None:
                return None
            derived = dv.apply_filterdown(plan, sig, cand.signature, cand.table)
            self._touch(cand_key, cand, request_origin)
            self.stats.hits_filterdown += 1
            return LookupResult("hit_filterdown", derived, cand_key,
                                cand.origin, cand.snapshot_id)
        plan = dv.plan_compose(sig, cand.signature, self.schema, cand_key)
        if plan is None:
            return None
        derived = dv.apply_compose(plan, sig, cand.signature, cand.table,
                                   self.level_mapper)
        if derived is None:
            return None
        self._touch(cand_key, cand, request_origin)
        self.stats.hits_compose += 1
        return LookupResult("hit_compose", derived, cand_key, cand.origin,
                            cand.snapshot_id)

    def _probe_indexed(self, sig: Signature, request_origin: str,
                       bucket: _MeasureBucket) -> Optional[LookupResult]:
        """Gather the structurally viable candidates through the tier-2
        index, then try plans most-recently-stored first — the same visit
        order as the linear scan, restricted to candidates that can pass the
        planners' structural preconditions.  The three viability classes are
        mutually exclusive per candidate (filter equality vs strict subset;
        level equality vs inequality), mirroring the per-candidate
        rollup -> filterdown -> compose priority of the linear scan."""
        twb = bucket.by_tw.get(sig.time_window)
        if twb is None:
            return None
        seq = self._seq_of
        composable = sig.all_composable()
        cands: list[tuple[int, str, str]] = []
        if self.enable_rollup and composable:
            for k in twb.by_filters.get(sig.filters, ()):
                if self._entries[k].signature.levels != sig.levels:
                    cands.append((seq.get(k, 0), k, "rollup"))
        req_fs = sig.filters_frozen()
        if self.enable_filterdown:
            for k in twb.by_levels.get(sig.levels, ()):
                if self._entries[k].signature.filters_frozen() < req_fs:
                    cands.append((seq.get(k, 0), k, "filterdown"))
        if self.enable_compose and composable:
            for ftup, keys in twb.by_filters.items():
                if not frozenset(ftup) < req_fs:
                    continue
                for k in keys:
                    if self._entries[k].signature.levels != sig.levels:
                        cands.append((seq.get(k, 0), k, "compose"))
        cands.sort(reverse=True)
        self.stats.derivation_candidates_scanned += len(cands)
        for _, cand_key, kind in cands:
            cand = self._entries.get(cand_key)
            if cand is None:
                continue
            hit = self._attempt(sig, cand_key, cand, kind, request_origin)
            if hit is not None:
                return hit
        return None

    def _probe_linear(self, sig: Signature, request_origin: str,
                      bucket: _MeasureBucket) -> Optional[LookupResult]:
        """Pre-index behavior: walk the whole measure bucket most-recently-
        stored first, trying every derivation on every candidate.  Kept as
        the differential-testing oracle for the indexed probe."""
        for cand_key in reversed(bucket.order):
            cand = self._entries.get(cand_key)
            if cand is None:
                continue
            self.stats.derivation_candidates_scanned += 1
            for kind, enabled in (("rollup", self.enable_rollup),
                                  ("filterdown", self.enable_filterdown),
                                  ("compose", self.enable_compose)):
                if not enabled:
                    continue
                hit = self._attempt(sig, cand_key, cand, kind, request_origin)
                if hit is not None:
                    return hit
        return None

    def put(
        self,
        sig: Signature,
        table: ResultTable,
        origin: str = "sql",
        snapshot_id: str = "snap0",
    ) -> str:
        key = sig.key()
        if key in self._entries:
            # full overwrite: provenance (origin, stored_at) must track the
            # new producer, or a SQL-refreshed entry keeps reporting the
            # stale origin in provenance chains and stats forever
            e = self._entries[key]
            self._entries.move_to_end(key)
            e.table = table
            e.snapshot_id = snapshot_id
            e.origin = origin
            e.stored_at = time.monotonic()
            e.lru_stamp = next(_STAMP)
            self._set_entry_bytes(e, table.nbytes())
            self._enforce_capacity()
            return key
        e = CacheEntry(sig, table, origin, snapshot_id, time.monotonic())
        stamp = next(_STAMP)
        e.lru_stamp = e.store_stamp = stamp
        self._entries[key] = e
        self._set_entry_bytes(e, table.nbytes())
        self._seq += 1
        self._seq_of[key] = self._seq
        self._index(key, sig)
        self.stats.stores += 1
        self._enforce_capacity()
        return key

    # ----------------------------------------------- invalidation / refresh
    def affected_keys(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> list[str]:
        """Keys of the entries a data update covering [updated_start,
        updated_end) can affect (§6.2): open-ended windows and windowless
        entries always (they span everything), closed windows only when they
        intersect the updated range, every entry when the update extent is
        unknown.  The caller decides what to do with them — drop
        (``invalidate_snapshot``) or refresh in place (``refresh_entry``)."""
        out = []
        for key, e in self._entries.items():
            tw = e.signature.time_window
            if tw is None or tw.open_ended:
                out.append(key)
            elif updated_start is None or updated_end is None:
                out.append(key)  # unknown update extent: conservative
            elif tw.intersects(updated_start, updated_end):
                out.append(key)
        return out

    def invalidate_snapshot(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> int:
        """New data arrived covering [updated_start, updated_end).  Affected
        entries (see ``affected_keys``) are dropped; closed windows outside
        the range remain valid (§6.2)."""
        dropped = self.affected_keys(updated_start, updated_end)
        for key in dropped:
            self._remove(key)
            self.stats.invalidations += 1
        return len(dropped)

    def refresh_entry(
        self, key: str, table: ResultTable, snapshot_id: str, merged: bool = True
    ) -> None:
        """Bring an entry current in place after a data update, instead of
        dropping it: the working set (LRU position, hit counters, derivation
        index membership) survives the snapshot advance.  ``merged`` tells
        the stats whether the table came from a delta merge (the cheap path)
        or a full recompute fallback."""
        e = self._entries.get(key)
        if e is None:
            raise KeyError(f"cannot refresh unknown entry {key!r}")
        e.table = table
        self._set_entry_bytes(e, table.nbytes())
        e.snapshot_id = snapshot_id
        e.refreshes += 1
        e.refreshed_at = time.monotonic()
        if merged:
            self.stats.refreshes += 1
        else:
            self.stats.refresh_fallbacks += 1
        # delta merges grow tables (group unions), so a refresh can push the
        # cache over its byte budget just like a put
        self._enforce_capacity()

    def drop(self, key: str) -> bool:
        """Explicitly invalidate one entry by key; True when it existed."""
        if key not in self._entries:
            return False
        self._remove(key)
        self.stats.invalidations += 1
        return True

    def invalidate_schema_change(self) -> int:
        n = len(self._entries)
        self._entries.clear()
        self._by_measures.clear()
        self._index_of.clear()
        self._seq_of.clear()
        self._bytes = 0
        self.stats.bytes_cached = 0
        self.stats.invalidations += n
        return n

    # ------------------------------------------------------------- internals
    def _touch(self, key: str, entry: CacheEntry, request_origin: str) -> None:
        self._entries.move_to_end(key)
        entry.hits += 1
        entry.lru_stamp = next(_STAMP)
        if request_origin == "nl":
            self.stats.nl_hits += 1
        if request_origin != entry.origin:
            self.stats.cross_surface_hits += 1

    def _set_entry_bytes(self, entry: CacheEntry, nbytes: int) -> None:
        self._bytes += nbytes - entry.table_nbytes
        entry.table_nbytes = nbytes
        self.stats.bytes_cached = self._bytes

    def _index(self, key: str, sig: Signature) -> None:
        """Insert ``key`` into the derivation candidate index (tier 1 always;
        tier 2 only for entries that can actually serve a derivation)."""
        idx_key = (sig.scope, sig.schema, sig.measure_key())
        bucket = self._by_measures.setdefault(idx_key, _MeasureBucket())
        bucket.order.append(key)
        if dv.no_postagg(sig):
            # entries with HAVING/ORDER BY/LIMIT can never serve a
            # derivation; they stay out of the tier-2 viability index
            twb = bucket.by_tw.setdefault(sig.time_window, _TwBucket())
            twb.by_filters.setdefault(sig.filters, []).append(key)
            twb.by_levels.setdefault(sig.levels, []).append(key)
        self._index_of[key] = (idx_key, sig)

    def _enforce_capacity(self) -> None:
        while self._entries and (
            (self.capacity is not None and len(self._entries) > self.capacity)
            or (self.capacity_bytes is not None
                and self._bytes > self.capacity_bytes)
        ):
            self._evict_lru()

    def _evict_lru(self) -> None:
        key, e = self._entries.popitem(last=False)
        self._unindex(key)
        self._bytes -= e.table_nbytes
        self.stats.bytes_cached = self._bytes
        self.stats.bytes_evicted += e.table_nbytes
        self.stats.evictions += 1

    def _remove(self, key: str) -> None:
        e = self._entries.pop(key, None)
        if e is not None:
            self._unindex(key)
            self._bytes -= e.table_nbytes
            self.stats.bytes_cached = self._bytes

    def _unindex(self, key: str) -> None:
        rec = self._index_of.pop(key, None)
        if rec is None:
            return
        idx_key, sig = rec
        self._seq_of.pop(key, None)
        bucket = self._by_measures.get(idx_key)
        if bucket is None:
            return
        _discard(bucket.order, key)
        twb = bucket.by_tw.get(sig.time_window)
        if twb is not None:
            for sub, sub_key in ((twb.by_filters, sig.filters),
                                 (twb.by_levels, sig.levels)):
                lst = sub.get(sub_key)
                if lst is not None:
                    _discard(lst, key)
                    if not lst:
                        del sub[sub_key]
            if not twb.by_filters and not twb.by_levels:
                del bucket.by_tw[sig.time_window]
        if not bucket.order:
            del self._by_measures[idx_key]

    # ----------------------------------------------------- cluster migration
    def export_entries(self) -> list[CacheEntry]:
        """Live entries in LRU order (least-recently-used first).  Each entry
        carries its global ``lru_stamp``/``store_stamp``, so a cluster
        rebalance can deterministically interleave entries from several
        source shards (see :meth:`rebuild`)."""
        return list(self._entries.values())

    def rebuild(self, entries: Iterable[CacheEntry]) -> None:
        """Replace the cache contents with ``entries`` (shard rebalance).

        LRU order is reconstructed from ``lru_stamp`` and the derivation
        index's most-recently-stored probe order from ``store_stamp`` — the
        same global clock both stamps were drawn from — so migrated entries
        keep their recency relative to entries already resident on the target
        shard.  Entry state (tables, hit counters, snapshot ids) moves
        untouched; cumulative stats counters are preserved.  Capacity budgets
        are re-enforced afterwards (a shrink migration can evict, counted as
        ordinary evictions)."""
        entries = list(entries)
        self._entries.clear()
        self._by_measures.clear()
        self._index_of.clear()
        self._seq_of.clear()
        self._bytes = 0
        for e in sorted(entries, key=lambda e: e.lru_stamp):
            self._entries[e.signature.key()] = e
            self._bytes += e.table_nbytes
        self._seq = 0
        for e in sorted(entries, key=lambda e: e.store_stamp):
            key = e.signature.key()
            self._seq += 1
            self._seq_of[key] = self._seq
            self._index(key, e.signature)
        self.stats.bytes_cached = self._bytes
        self._enforce_capacity()

    # ---------------------------------------------------------- introspection
    def entry(self, key: str) -> Optional[CacheEntry]:
        return self._entries.get(key)

    def keys(self) -> list[str]:
        return list(self._entries.keys())

    def total_bytes(self) -> int:
        return self._bytes


# ------------------------------------------------------------- persistence


def save_cache(cache: SemanticCache, path: str) -> int:
    """Spill the cache to disk (the paper's Parquet/SQLite store analogue):
    one .npz per entry + a JSON manifest of signatures/origins/snapshots.
    Returns the number of entries written.

    Entry files are named by signature-key hash and written via temp file +
    rename, as is the manifest, so a crash mid-spill can never corrupt the
    previous generation: the surviving old manifest keeps pointing at files
    whose names (and therefore signatures) it owns.  Re-spilling to a
    directory that previously held *more* entries removes the now-stale
    ``entry_*.npz`` files — only after the new manifest is durable — so a
    later ``load_cache`` against a hand-edited or partially written manifest
    cannot resurrect them."""
    import json as _json
    import os

    import numpy as np

    os.makedirs(path, exist_ok=True)
    manifest = []
    for key, e in cache._entries.items():
        fname = f"entry_{key[:24]}.npz"
        tmp = os.path.join(path, fname + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **{n: v for n, v in e.table.columns.items()})
        os.replace(tmp, os.path.join(path, fname))
        manifest.append({
            "key": key, "file": fname, "origin": e.origin,
            "snapshot_id": e.snapshot_id, "hits": e.hits,
            "signature": e.signature.to_json(),
            "columns": e.table.names,
        })
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        _json.dump(manifest, f, default=str)
    os.replace(tmp, os.path.join(path, "manifest.json"))
    # remove stale files only once the new manifest is durable: deleting
    # first would leave a crash window where the surviving *old* manifest
    # points at files that no longer exist
    live = {m["file"] for m in manifest}
    for fname in os.listdir(path):
        stale = fname.startswith("entry_") and (
            (fname.endswith(".npz") and fname not in live)
            or fname.endswith(".npz.tmp"))  # orphans of an interrupted spill
        if stale:
            os.remove(os.path.join(path, fname))
    return len(manifest)


def load_cache(cache: SemanticCache, path: str) -> int:
    """Warm a cache from a spill directory; entries re-validate their key
    against the recomputed signature hash (tamper/versioning guard)."""
    import json as _json
    import os

    import numpy as np

    from .signature import signature_from_json
    from .table import ResultTable

    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return 0
    with open(mpath) as f:
        manifest = _json.load(f)
    loaded = 0
    for m in manifest:
        try:
            sig = signature_from_json(m["signature"])
        except (KeyError, ValueError):
            continue
        if sig.key() != m["key"]:
            continue  # schema/version drift: refuse stale entries
        data = np.load(os.path.join(path, m["file"]), allow_pickle=False)
        table = ResultTable({n: data[n] for n in m["columns"]})
        cache.put(sig, table, origin=m["origin"], snapshot_id=m["snapshot_id"])
        loaded += 1
    return loaded
