"""The semantic cache store (§3.5, §3.6, §6.2).

Exact-intent lookup by signature hash, plus correctness-preserving
derivations (roll-up, filter-down) found through a metadata index keyed by
measure multiset — the in-memory analogue of the paper's SQLite derivation
index (entries matching requested measures with superset dimensions or
superset filters).  LRU eviction; snapshot-based invalidation where entries
whose time window intersects updated partitions (or is open-ended) are
refreshed while closed windows remain valid.

Derivation probes are **indexed**: within a measure-multiset bucket,
candidates are further keyed by time window (every derivation requires
window equality), then by exact filter tuple (roll-up requires filter
equality) and exact level tuple (filter-down requires level equality), with
set-semantics prefilters for the strict-subset checks.  A lookup therefore
runs ``plan_rollup``/``plan_filterdown`` only on structurally *viable*
candidates — bounded by the viable subset, not the bucket — visited in the
same most-recently-stored-first order as the pre-index linear scan, so hit/
miss outcomes are unchanged (``indexed_probes=False`` keeps the linear scan
for differential testing).  Entries carrying HAVING/ORDER BY/LIMIT can never
serve a derivation and are excluded from the tier-2 index at ``put``.

Accounting is byte-aware: every entry records its table's byte footprint,
``capacity_bytes`` bounds resident bytes alongside the entry-count
``capacity`` (eviction runs until under *both* budgets), and
``stats.bytes_cached`` / ``stats.bytes_evicted`` expose the gauge/counter
pair.  Entries also carry global recency stamps so a sharded cluster
(:mod:`repro.cluster`) can migrate them between shards deterministically
(``export_entries`` / ``rebuild``).  Instances are single-threaded by
design; the cluster provides the locking.

Storage is tiered (:mod:`repro.storage`): with a :class:`TieredStore`
attached, eviction under the hot budgets *demotes* entries to a durable
cold tier instead of dropping them — the victim chosen by a pluggable
policy (``policy="cost"`` scores recompute-cost x decayed hits / bytes;
``policy="lru"`` preserves the exact pre-tiering evictor) — and cold hits
promote transparently back through the same lookup path (``tier="cold"``
on the result is the only observable difference).  Demoted entries keep
their metadata and derivation-index membership hot, so probe order
survives demotion.  Entries may carry a TTL (per-entry or cache default),
expired lazily at lookup.  ``save_cache``/``load_cache`` are thin shims
over the store's crash-safe manifest.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Iterable, Optional

from . import derivations as dv
from .schema import StarSchema
from .signature import Signature, TimeWindow
from .table import ResultTable
from ..storage import policy as _policy


def _discard(lst: list, item) -> None:
    try:
        lst.remove(item)
    except ValueError:
        pass


class _StampClock:
    """Process-wide recency clock for cluster migration and warm restart:
    every store and every touch draws a strictly increasing stamp, so entries
    moved between shards can be interleaved into the target's LRU order
    (``lru_stamp``) and derivation MRU order (``store_stamp``)
    deterministically, without comparing wall clocks.  A warm restart calls
    :func:`advance_stamp` with the highest persisted stamp so fresh stamps
    stay strictly above restored ones.  The internal lock is a plain leaf
    mutex held only for the increment (deliberately not sanitized, like the
    sanitizer's own bookkeeping lock)."""

    __slots__ = ("_lock", "_v")

    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0  # guarded-by: self._lock

    def __next__(self) -> int:
        with self._lock:
            self._v += 1
            return self._v

    def advance_to(self, floor: int) -> None:
        with self._lock:
            if floor > self._v:
                self._v = floor


_STAMP = _StampClock()


def advance_stamp(floor: int) -> None:
    """Ensure future stamps exceed ``floor`` (warm-restart stamp adoption)."""
    _STAMP.advance_to(floor)


@dataclasses.dataclass
class CacheEntry:
    signature: Signature
    table: Optional[ResultTable]  # None while demoted to the cold tier
    origin: str  # 'sql' | 'nl'
    snapshot_id: str
    stored_at: float
    hits: int = 0
    refreshes: int = 0  # in-place table replacements on snapshot advance
    refreshed_at: Optional[float] = None
    table_nbytes: int = 0  # byte footprint of .table (capacity_bytes budget)
    lru_stamp: int = 0  # global recency stamp: last store or touch
    store_stamp: int = 0  # global stamp of the *first* store (MRU probe order)
    version: int = 0  # bumped on every table rewrite (put-overwrite/refresh);
    #                   the store skips payload rewrites for matching versions
    cost_ms: float = 0.0  # execute-stage cost of the producing miss (policy input)
    ttl_s: Optional[float] = None  # per-entry TTL override; None = cache default
    last_used_at: float = 0.0  # monotonic time of last store/touch (hit decay)


@dataclasses.dataclass
class CacheStats:
    hits_exact: int = 0
    hits_rollup: int = 0
    hits_filterdown: int = 0
    hits_compose: int = 0
    misses: int = 0
    stores: int = 0
    evictions: int = 0
    invalidations: int = 0
    refreshes: int = 0  # entries merged in place from a delta scan
    refresh_fallbacks: int = 0  # affected entries replaced by a full recompute
    cross_surface_hits: int = 0  # NL request served by SQL-seeded entry or v.v.
    nl_hits: int = 0
    # derivation-probe observability: viable candidates visited vs plan
    # checks actually run (linear scans visit whole buckets; the index visits
    # only structurally viable candidates)
    derivation_candidates_scanned: int = 0
    derivation_plans_attempted: int = 0
    # byte-aware accounting: bytes_cached is a gauge of the current resident
    # table bytes; bytes_evicted counts bytes removed by LRU eviction
    bytes_cached: int = 0
    bytes_evicted: int = 0
    # tiered storage (PR 8): demotions move a hot table to the cold tier,
    # promotions bring one back on a cold hit; cold_drops count entries the
    # policy (or cold budget / damage) removed from the cold tier entirely;
    # bytes_cold is the gauge of cold-resident table bytes
    demotions: int = 0
    promotions: int = 0
    cold_drops: int = 0
    ttl_expiries: int = 0  # entries lazily expired by TTL at lookup time
    bytes_cold: int = 0

    @property
    def hits(self) -> int:
        return (self.hits_exact + self.hits_rollup + self.hits_filterdown
                + self.hits_compose)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def to_dict(self) -> dict:
        """Serializable counter snapshot (fields + derived totals) for the
        service stats endpoints — the derived values are materialized here
        so ``json.dumps`` can never silently emit a bound method."""
        d = dataclasses.asdict(self)
        d["hits"] = self.hits
        d["lookups"] = self.lookups
        d["hit_rate"] = self.hit_rate
        return d


@dataclasses.dataclass
class LookupResult:
    """Outcome of one cache probe.

    ``status`` is one of ``'hit_exact'`` (signature-key match),
    ``'hit_rollup'`` (re-aggregated from a finer-grained entry),
    ``'hit_filterdown'`` (post-filtered from a superset entry),
    ``'hit_compose'`` (flag-gated beyond-paper derivation: filter-down
    composed with roll-up in one step, e.g. a cached (region, category)
    result answering "by region WHERE category = x"), or ``'miss'``.
    ``source_key``/``source_origin``/``source_snapshot`` identify the
    serving entry and the data snapshot its table reflects.  ``tier`` is
    ``"cold"`` when the serving entry was promoted from the cold tier for
    this request (``tier:cold`` provenance downstream), else ``None`` —
    appended last so positional construction stays source-compatible.
    """

    status: str
    table: Optional[ResultTable]
    source_key: Optional[str] = None
    source_origin: Optional[str] = None
    source_snapshot: Optional[str] = None
    tier: Optional[str] = None


class _TwBucket:
    """Tier-2 derivation index for one (measure bucket, time window) group:
    candidates keyed by exact filter tuple (roll-up needs filter equality)
    and by exact level tuple (filter-down needs level equality)."""

    __slots__ = ("by_filters", "by_levels")

    def __init__(self):
        self.by_filters: dict[tuple, list[str]] = {}
        self.by_levels: dict[tuple, list[str]] = {}


class _MeasureBucket:
    """Tier-1 derivation index bucket: every entry sharing a measure
    multiset, in insertion order (the linear-scan path), plus the tier-2
    time-window index over the derivation-capable subset."""

    __slots__ = ("order", "by_tw")

    def __init__(self):
        self.order: list[str] = []
        self.by_tw: dict[Optional[TimeWindow], _TwBucket] = {}


class SemanticCache:
    def __init__(
        self,
        schema: StarSchema,
        capacity: Optional[int] = None,  # max entries; None = unbounded
        enable_rollup: bool = True,
        enable_filterdown: bool = True,
        enable_compose: bool = False,  # beyond-paper: filter-down o roll-up
        level_mapper: Optional[dv.LevelMapper] = None,
        indexed_probes: bool = True,  # False: pre-index linear scan (testing)
        capacity_bytes: Optional[int] = None,  # max table bytes; None = unbounded
        policy: Optional[str] = None,  # 'lru' | 'cost'; None = auto (lru
        #                                without a store, cost with one)
        store=None,  # repro.storage.engine.TieredStore (cold tier); None = all-hot
        cold_capacity_bytes: Optional[int] = None,  # cold-tier byte budget
        ttl_s: Optional[float] = None,  # default entry TTL; None = no expiry
        hit_half_life_s: float = _policy.DEFAULT_HALF_LIFE_S,
        write_through: bool = False,  # also spill puts/refreshes (durable
        #                               working set, not just demotions)
    ):
        self.schema = schema
        self.capacity = capacity
        self.capacity_bytes = capacity_bytes
        self._bytes = 0  # resident table bytes (mirrors stats.bytes_cached)
        self.enable_rollup = enable_rollup
        self.enable_filterdown = enable_filterdown
        self.enable_compose = enable_compose
        self.level_mapper = level_mapper
        self.indexed_probes = indexed_probes
        self.policy = policy
        self.store = store
        self.cold_capacity_bytes = cold_capacity_bytes
        self.ttl_s = ttl_s
        self.hit_half_life_s = hit_half_life_s
        self.write_through = write_through
        self._policies = {
            "lru": _policy.LruPolicy(),
            "cost": _policy.CostPolicy(half_life_s=hit_half_life_s),
        }
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        # cold-tier metas: demoted entries keep their CacheEntry (stamps, hit
        # counters, index membership) with table=None; the bytes live in the
        # attached store.  Insertion order is demotion order (oldest first).
        self._cold: "OrderedDict[str, CacheEntry]" = OrderedDict()
        self._cold_bytes = 0  # mirrors stats.bytes_cold
        # derivation candidate index: (scope, schema, measure multiset)
        self._by_measures: dict[tuple, _MeasureBucket] = {}
        # reverse map key -> (bucket key, signature) so eviction/invalidation
        # unindexes without scanning every bucket
        self._index_of: dict[str, tuple] = {}
        # monotonic store sequence per key: the MRU merge order of the
        # indexed probe (== position in the bucket's insertion-order list)
        self._seq = 0
        self._seq_of: dict[str, int] = {}
        # stale-on-error morgue: the last tables of TTL-expired hot entries,
        # kept (bounded, LRU) so degraded serving can offer an *explicitly
        # tagged* stale answer when the backend is down.  Never consulted by
        # lookup() — only by peek_stale(), and only the resilience plane
        # calls that.
        self._morgue: "OrderedDict[str, object]" = OrderedDict()
        self.morgue_capacity = 128
        self.stats = CacheStats()
        # lifecycle audit log (repro.obs.audit.AuditLog); None = disabled,
        # so every emission site pays one attribute load + None check.
        # Label fields (tenant=..., shard=...) ride on every event.
        self.audit = None
        self._audit_labels: dict = {}

    def set_audit(self, audit, **labels) -> None:
        """Attach (or detach, with ``None``) the obs plane's cache-lifecycle
        audit log.  ``labels`` (``tenant=...``, ``shard=...``) are stamped
        onto every event this cache emits."""
        self.audit = audit
        self._audit_labels = dict(labels)

    def _emit_audit(self, event: str, key: str, **fields) -> None:
        # callers pre-check `self.audit is not None`: the disabled hot path
        # never pays this call.  The record is built in place and appended
        # directly (no kwargs re-splat) — `hit` rides the warm path, where
        # this is a measurable share of request latency.
        rec = {"ts": time.time(), "event": event, "key": key}
        rec.update(self._audit_labels)
        rec.update(fields)
        self.audit.append(rec)

    def _policy_inputs(self, e: CacheEntry, now: float) -> dict:
        """The same per-entry policy inputs ``entries_summary`` reports —
        attached to evict/demote audit events so ``python -m repro.obs
        explain`` can narrate *why* the policy chose this victim."""
        return {
            "age_s": round(now - e.stored_at, 3),
            "idle_s": round(now - e.last_used_at, 3),
            "hits": e.hits,
            "decayed_hits": round(
                _policy.decayed_hits(e, now, self.hit_half_life_s), 4),
            "cost_ms": e.cost_ms,
            "nbytes": e.table_nbytes,
            "score": round(
                _policy.cost_benefit_score(e, now, self.hit_half_life_s), 6),
            "policy": self._resolve_policy().name,
        }

    # ------------------------------------------------------------------- api
    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, sig: Signature, request_origin: str = "sql") -> LookupResult:
        key = sig.key()
        now = time.monotonic()
        tier = None
        entry = self._entries.get(key)
        if entry is not None and self._expired(entry, now):
            self._expire(key)
            entry = None
        if entry is None and key in self._cold:
            if self._expired(self._cold[key], now):
                self._expire(key)
            else:
                entry = self._promote(key)
                tier = "cold"
        if entry is not None:
            # capture before re-enforcing capacity: a tiny hot budget could
            # demote the just-promoted entry again and null its table
            table = entry.table
            origin, snap = entry.origin, entry.snapshot_id
            self._touch(key, entry, request_origin)
            self.stats.hits_exact += 1
            if self.audit is not None:
                # built in place (no kwargs hop): this site rides the warm
                # path, where every dict round-trip is measurable
                rec = {"ts": time.time(), "event": "hit", "key": key}
                rec.update(self._audit_labels)
                rec["tier"] = tier or "hot"
                rec["origin"] = origin
                rec["snapshot"] = snap
                rec["request_origin"] = request_origin
                rec["hits"] = entry.hits
                self.audit.append(rec)
            if tier == "cold":
                self._enforce_capacity()
            return LookupResult("hit_exact", table, key, origin, snap,
                                tier=tier)

        # derivation pass: only post-aggregation-free requests can be served
        # by a derivation (every planner requires it), and only candidates
        # sharing the measure multiset are admissible
        bucket = self._by_measures.get((sig.scope, sig.schema, sig.measure_key()))
        if bucket is not None and dv.no_postagg(sig) and (
                self.enable_rollup or self.enable_filterdown or self.enable_compose):
            probe = self._probe_indexed if self.indexed_probes else self._probe_linear
            hit = probe(sig, request_origin, bucket)
            if hit is not None:
                return hit
        self.stats.misses += 1
        return LookupResult("miss", None)

    # ------------------------------------------------------ derivation probes
    def _attempt(self, sig: Signature, cand_key: str, cand: CacheEntry,
                 kind: str, request_origin: str) -> Optional[LookupResult]:
        """Run one derivation plan+apply; None when it doesn't pan out.

        Plans run on metadata only, so a cold candidate is promoted (its
        table loaded from the store) only *after* its plan succeeds — a
        structurally unviable cold entry costs no IO."""
        self.stats.derivation_plans_attempted += 1
        now = time.monotonic()
        if self._expired(cand, now):
            self._expire(cand_key)
            return None
        planner = {"rollup": dv.plan_rollup, "filterdown": dv.plan_filterdown,
                   "compose": dv.plan_compose}[kind]
        plan = planner(sig, cand.signature, self.schema, cand_key)
        if plan is None:
            return None
        tier = None
        if cand.table is None:
            cand = self._promote(cand_key)
            if cand is None:
                return None  # damaged payload: the cold meta was dropped
            tier = "cold"
        if kind == "rollup":
            derived = dv.apply_rollup(plan, sig, cand.signature, cand.table,
                                      self.level_mapper)
        elif kind == "filterdown":
            derived = dv.apply_filterdown(plan, sig, cand.signature, cand.table)
        else:
            derived = dv.apply_compose(plan, sig, cand.signature, cand.table,
                                       self.level_mapper)
        if derived is None:
            if tier == "cold":
                self._enforce_capacity()
            return None
        origin, snap = cand.origin, cand.snapshot_id
        self._touch(cand_key, cand, request_origin)
        status = {"rollup": "hit_rollup", "filterdown": "hit_filterdown",
                  "compose": "hit_compose"}[kind]
        setattr(self.stats, f"hits_{kind}",
                getattr(self.stats, f"hits_{kind}") + 1)
        if self.audit is not None:
            # key = the *requested* signature; src_key = the cached entry
            # that served it (the false-hit audit checks src_key liveness)
            self._emit_audit("derivation_hit", sig.key(), src_key=cand_key,
                             derivation=kind, tier=tier or "hot",
                             origin=origin, snapshot=snap,
                             request_origin=request_origin)
        if tier == "cold":
            self._enforce_capacity()
        return LookupResult(status, derived, cand_key, origin, snap, tier=tier)

    def _probe_indexed(self, sig: Signature, request_origin: str,
                       bucket: _MeasureBucket) -> Optional[LookupResult]:
        """Gather the structurally viable candidates through the tier-2
        index, then try plans most-recently-stored first — the same visit
        order as the linear scan, restricted to candidates that can pass the
        planners' structural preconditions.  The three viability classes are
        mutually exclusive per candidate (filter equality vs strict subset;
        level equality vs inequality), mirroring the per-candidate
        rollup -> filterdown -> compose priority of the linear scan."""
        twb = bucket.by_tw.get(sig.time_window)
        if twb is None:
            return None
        seq = self._seq_of
        composable = sig.all_composable()
        cands: list[tuple[int, str, str]] = []
        if self.enable_rollup and composable:
            for k in twb.by_filters.get(sig.filters, ()):
                if self._entry_any(k).signature.levels != sig.levels:
                    cands.append((seq.get(k, 0), k, "rollup"))
        req_fs = sig.filters_frozen()
        if self.enable_filterdown:
            for k in twb.by_levels.get(sig.levels, ()):
                if self._entry_any(k).signature.filters_frozen() < req_fs:
                    cands.append((seq.get(k, 0), k, "filterdown"))
        if self.enable_compose and composable:
            for ftup, keys in twb.by_filters.items():
                if not frozenset(ftup) < req_fs:
                    continue
                for k in keys:
                    if self._entry_any(k).signature.levels != sig.levels:
                        cands.append((seq.get(k, 0), k, "compose"))
        cands.sort(reverse=True)
        self.stats.derivation_candidates_scanned += len(cands)
        for _, cand_key, kind in cands:
            cand = self._entry_any(cand_key)
            if cand is None:
                continue
            hit = self._attempt(sig, cand_key, cand, kind, request_origin)
            if hit is not None:
                return hit
        return None

    def _probe_linear(self, sig: Signature, request_origin: str,
                      bucket: _MeasureBucket) -> Optional[LookupResult]:
        """Pre-index behavior: walk the whole measure bucket most-recently-
        stored first, trying every derivation on every candidate.  Kept as
        the differential-testing oracle for the indexed probe."""
        # snapshot: _attempt may expire/drop candidates, mutating the bucket
        for cand_key in list(reversed(bucket.order)):
            cand = self._entry_any(cand_key)
            if cand is None:
                continue
            self.stats.derivation_candidates_scanned += 1
            for kind, enabled in (("rollup", self.enable_rollup),
                                  ("filterdown", self.enable_filterdown),
                                  ("compose", self.enable_compose)):
                if not enabled:
                    continue
                hit = self._attempt(sig, cand_key, cand, kind, request_origin)
                if hit is not None:
                    return hit
        return None

    def put(
        self,
        sig: Signature,
        table: ResultTable,
        origin: str = "sql",
        snapshot_id: str = "snap0",
        *,
        cost_ms: float = 0.0,
        ttl_s: Optional[float] = None,
    ) -> str:
        key = sig.key()
        now = time.monotonic()
        if key in self._cold:
            # overwrite of a demoted entry: pull the meta back hot (its index
            # membership and stamps survive) and fall through to the
            # overwrite path below
            e = self._cold.pop(key)
            self._cold_bytes -= e.table_nbytes
            self.stats.bytes_cold = self._cold_bytes
            self._entries[key] = e
            self._bytes += e.table_nbytes
        if key in self._entries:
            # full overwrite: provenance (origin, stored_at) must track the
            # new producer, or a SQL-refreshed entry keeps reporting the
            # stale origin in provenance chains and stats forever
            e = self._entries[key]
            self._entries.move_to_end(key)
            e.table = table
            e.snapshot_id = snapshot_id
            e.origin = origin
            e.stored_at = now
            e.last_used_at = now
            e.lru_stamp = next(_STAMP)
            e.version += 1
            if cost_ms:
                e.cost_ms = cost_ms
            if ttl_s is not None:
                e.ttl_s = ttl_s
            self._set_entry_bytes(e, table.nbytes())
            if self.audit is not None:
                self._emit_audit("put", key, overwrite=True, origin=origin,
                                 snapshot=snapshot_id, nbytes=e.table_nbytes,
                                 cost_ms=e.cost_ms, version=e.version)
            self._maybe_write_through(key, e)
            self._enforce_capacity()
            return key
        e = CacheEntry(sig, table, origin, snapshot_id, now,
                       cost_ms=cost_ms, ttl_s=ttl_s, last_used_at=now)
        stamp = next(_STAMP)
        e.lru_stamp = e.store_stamp = stamp
        self._entries[key] = e
        self._set_entry_bytes(e, table.nbytes())
        self._seq += 1
        self._seq_of[key] = self._seq
        self._index(key, sig)
        self.stats.stores += 1
        if self.audit is not None:
            self._emit_audit("put", key, overwrite=False, origin=origin,
                             snapshot=snapshot_id, nbytes=e.table_nbytes,
                             cost_ms=e.cost_ms, ttl_s=e.ttl_s)
        self._maybe_write_through(key, e)
        self._enforce_capacity()
        return key

    # ----------------------------------------------- invalidation / refresh
    def affected_keys(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> list[str]:
        """Keys of the entries a data update covering [updated_start,
        updated_end) can affect (§6.2): open-ended windows and windowless
        entries always (they span everything), closed windows only when they
        intersect the updated range, every entry when the update extent is
        unknown.  The caller decides what to do with them — drop
        (``invalidate_snapshot``) or refresh in place (``refresh_entry``).
        Cold-tier entries are included: a demoted table is just as stale."""
        out = []
        for key, e in list(self._entries.items()) + list(self._cold.items()):
            tw = e.signature.time_window
            if tw is None or tw.open_ended:
                out.append(key)
            elif updated_start is None or updated_end is None:
                out.append(key)  # unknown update extent: conservative
            elif tw.intersects(updated_start, updated_end):
                out.append(key)
        return out

    def invalidate_snapshot(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> int:
        """New data arrived covering [updated_start, updated_end).  Affected
        entries (see ``affected_keys``) are dropped; closed windows outside
        the range remain valid (§6.2)."""
        dropped = self.affected_keys(updated_start, updated_end)
        for key in dropped:
            self._remove(key)
            self.stats.invalidations += 1
            if self.audit is not None:
                self._emit_audit("drop", key, reason="snapshot_invalidation",
                                 updated_start=updated_start,
                                 updated_end=updated_end)
        return len(dropped)

    def refresh_entry(
        self, key: str, table: ResultTable, snapshot_id: str, merged: bool = True
    ) -> None:
        """Bring an entry current in place after a data update, instead of
        dropping it: the working set (LRU position, hit counters, derivation
        index membership) survives the snapshot advance.  ``merged`` tells
        the stats whether the table came from a delta merge (the cheap path)
        or a full recompute fallback."""
        e = self._entries.get(key)
        if e is None and key in self._cold:
            # refreshing a demoted entry replaces its table wholesale — no
            # need to read the stale cold payload; just pull the meta hot
            e = self._cold.pop(key)
            self._cold_bytes -= e.table_nbytes
            self.stats.bytes_cold = self._cold_bytes
            self._entries[key] = e
            self._bytes += e.table_nbytes
        if e is None:
            raise KeyError(f"cannot refresh unknown entry {key!r}")
        e.table = table
        self._set_entry_bytes(e, table.nbytes())
        e.snapshot_id = snapshot_id
        e.refreshes += 1
        e.version += 1
        e.refreshed_at = time.monotonic()
        if merged:
            self.stats.refreshes += 1
        else:
            self.stats.refresh_fallbacks += 1
        if self.audit is not None:
            self._emit_audit("refresh", key, snapshot=snapshot_id,
                             merged=merged, nbytes=e.table_nbytes,
                             version=e.version)
        self._maybe_write_through(key, e)
        # delta merges grow tables (group unions), so a refresh can push the
        # cache over its byte budget just like a put
        self._enforce_capacity()

    def drop(self, key: str) -> bool:
        """Explicitly invalidate one entry by key; True when it existed."""
        if key not in self._entries and key not in self._cold:
            return False
        self._remove(key)
        self.stats.invalidations += 1
        if self.audit is not None:
            self._emit_audit("drop", key, reason="explicit_invalidation")
        return True

    def invalidate_schema_change(self) -> int:
        n = len(self._entries) + len(self._cold)
        if self.audit is not None:
            for key in list(self._entries) + list(self._cold):
                self._emit_audit("drop", key, reason="schema_change")
        self._entries.clear()
        self._cold.clear()
        # a schema change makes stale tables structurally wrong, not merely
        # old: degraded serving must never offer them
        self._morgue.clear()
        self._by_measures.clear()
        self._index_of.clear()
        self._seq_of.clear()
        self._bytes = 0
        self._cold_bytes = 0
        self.stats.bytes_cached = 0
        self.stats.bytes_cold = 0
        self.stats.invalidations += n
        if self.store is not None:
            self.store.purge()
        return n

    # ------------------------------------------------------------- internals
    def _touch(self, key: str, entry: CacheEntry, request_origin: str) -> None:
        self._entries.move_to_end(key)
        entry.hits += 1
        entry.lru_stamp = next(_STAMP)
        entry.last_used_at = time.monotonic()
        if request_origin == "nl":
            self.stats.nl_hits += 1
        if request_origin != entry.origin:
            self.stats.cross_surface_hits += 1

    def _entry_any(self, key: str) -> Optional[CacheEntry]:
        """Hot entry, or the cold-tier meta (table=None) for a demoted one."""
        e = self._entries.get(key)
        return e if e is not None else self._cold.get(key)

    # ------------------------------------------------------------ TTL expiry
    def _expired(self, e: CacheEntry, now: float) -> bool:
        ttl = e.ttl_s if e.ttl_s is not None else self.ttl_s
        if ttl is None:
            return False
        born = e.refreshed_at if e.refreshed_at is not None else e.stored_at
        return (now - born) > ttl

    def _expire(self, key: str) -> None:
        """Lazy TTL expiry: drop the entry from whichever tier holds it (and
        its durable record — an expired entry must not resurrect on replay).
        A resident table moves to the morgue first so degraded serving can
        still offer it, explicitly tagged, when the backend is down."""
        e = self._entries.get(key)
        morgued = False
        if e is not None and e.table is not None:
            self._morgue[key] = e.table
            self._morgue.move_to_end(key)
            while len(self._morgue) > self.morgue_capacity:
                self._morgue.popitem(last=False)
            morgued = True
        tier = "hot" if e is not None else "cold"
        self._remove(key)
        self.stats.ttl_expiries += 1
        if self.audit is not None:
            self._emit_audit("ttl_expiry", key, tier=tier, morgued=morgued)

    def peek_stale(self, sig: Signature):
        """A possibly-stale table for this exact signature, or None — the
        degraded-serving read.  Checks the hot tier (even if TTL-expired),
        the cold tier via a non-mutating payload read (no promotion, no
        counter churn), then the morgue of TTL-expired tables.  Never
        derives, never touches hit accounting: callers *must* tag anything
        served from here (``degraded:stale``)."""
        key = sig.key()
        e = self._entries.get(key)
        if e is not None and e.table is not None:
            if self.audit is not None:
                self._emit_audit("stale_serve", key, source="hot",
                                 snapshot=e.snapshot_id)
            return e.table
        if key in self._cold and self.store is not None:
            table = self.store.peek(key)
            if table is not None:
                if self.audit is not None:
                    self._emit_audit("stale_serve", key, source="cold",
                                     snapshot=self._cold[key].snapshot_id)
                return table
        table = self._morgue.get(key)
        if table is not None and self.audit is not None:
            self._emit_audit("morgue_serve", key, source="morgue")
        return table

    # -------------------------------------------------------------- tiering
    def _resolve_policy(self):
        name = self.policy
        if name is None:
            name = "cost" if self.store is not None else "lru"
        return self._policies[name]

    def _maybe_write_through(self, key: str, e: CacheEntry) -> None:
        if self.store is not None and self.write_through:
            self.store.spill(key, e, e.table)

    def _promote(self, key: str) -> Optional[CacheEntry]:
        """Bring a demoted entry back hot.  ``None`` (and the cold meta is
        dropped) when the payload is damaged — a cold read never turns into
        a false hit.  A *transient* read failure (IO errors, cold breaker
        open) is a miss too, but the cold entry is kept: the durable replica
        is intact and serves again once the tier recovers."""
        e = self._cold.get(key)
        if e is None:
            return None
        try:
            table = self.store.promote(key) if self.store is not None else None
        except OSError:
            return None  # unavailable, not damaged: keep the replica
        if table is None:
            self._drop_cold(key, reason="damaged_payload")
            return None
        del self._cold[key]
        self._cold_bytes -= e.table_nbytes
        self.stats.bytes_cold = self._cold_bytes
        e.table = table
        self._entries[key] = e
        self._bytes += e.table_nbytes
        self._set_entry_bytes(e, table.nbytes())
        self.stats.promotions += 1
        if self.audit is not None:
            self._emit_audit("promote", key, nbytes=e.table_nbytes,
                             hits=e.hits)
        return e

    def _drop_cold(self, key: str, reason: str = "cold_capacity") -> None:
        """Remove a cold-tier entry entirely (budget pressure or damage)."""
        e = self._cold.pop(key, None)
        if e is None:
            return
        self._cold_bytes -= e.table_nbytes
        self.stats.bytes_cold = self._cold_bytes
        self._unindex(key)
        if self.store is not None:
            self.store.delete(key)
        self.stats.cold_drops += 1
        self.stats.bytes_evicted += e.table_nbytes
        if self.audit is not None:
            self._emit_audit("evict", key, tier="cold", disposition="drop",
                             reason=reason,
                             **self._policy_inputs(e, time.monotonic()))

    def ensure_loaded(self, key: str) -> Optional[CacheEntry]:
        """The entry with its table resident, promoting from cold if needed
        (refresh merges need the actual table).  None if unknown/damaged."""
        e = self._entries.get(key)
        if e is not None:
            return e
        if key in self._cold:
            # no capacity re-enforcement here: the caller is mid-mutation
            # (refresh) and needs the table resident; the following
            # refresh/put re-enforces budgets
            return self._promote(key)
        return None

    def _set_entry_bytes(self, entry: CacheEntry, nbytes: int) -> None:
        self._bytes += nbytes - entry.table_nbytes
        entry.table_nbytes = nbytes
        self.stats.bytes_cached = self._bytes

    def _index(self, key: str, sig: Signature) -> None:
        """Insert ``key`` into the derivation candidate index (tier 1 always;
        tier 2 only for entries that can actually serve a derivation)."""
        idx_key = (sig.scope, sig.schema, sig.measure_key())
        bucket = self._by_measures.setdefault(idx_key, _MeasureBucket())
        bucket.order.append(key)
        if dv.no_postagg(sig):
            # entries with HAVING/ORDER BY/LIMIT can never serve a
            # derivation; they stay out of the tier-2 viability index
            twb = bucket.by_tw.setdefault(sig.time_window, _TwBucket())
            twb.by_filters.setdefault(sig.filters, []).append(key)
            twb.by_levels.setdefault(sig.levels, []).append(key)
        self._index_of[key] = (idx_key, sig)

    def _enforce_capacity(self) -> None:
        while self._entries and (
            (self.capacity is not None and len(self._entries) > self.capacity)
            or (self.capacity_bytes is not None
                and self._bytes > self.capacity_bytes)
        ):
            self._evict_one()
        self._enforce_cold_capacity()

    def _evict_one(self) -> None:
        """Evict one hot entry under capacity pressure.  With a store
        attached the policy decides demote-to-cold (write-behind spill, the
        meta keeps its index membership and stamps) vs drop; without one this
        is the pre-PR 8 eviction, byte-for-byte."""
        now = time.monotonic()
        pol = self._resolve_policy()
        key = pol.victim(self._entries, now)
        e = self._entries.pop(key)
        self._bytes -= e.table_nbytes
        self.stats.bytes_cached = self._bytes
        if self.store is not None and pol.admit_cold(e, now):
            table, e.table = e.table, None
            self._cold[key] = e
            self._cold_bytes += e.table_nbytes
            self.stats.bytes_cold = self._cold_bytes
            self.stats.demotions += 1
            if self.audit is not None:
                self._emit_audit("demote", key, tier="hot",
                                 reason="hot_capacity",
                                 **self._policy_inputs(e, now))
            self.store.spill(key, e, table)
        else:
            self._unindex(key)
            if self.store is not None:
                # the policy chose drop, not demote: the durable copy (if
                # write-through made one) must go too, or replay resurrects it
                self.store.delete(key)
            self.stats.bytes_evicted += e.table_nbytes
            self.stats.evictions += 1
            if self.audit is not None:
                self._emit_audit("evict", key, tier="hot",
                                 disposition="drop", reason="hot_capacity",
                                 **self._policy_inputs(e, now))

    def _enforce_cold_capacity(self) -> None:
        if self.cold_capacity_bytes is None or not self._cold:
            return
        now = time.monotonic()
        while self._cold and self._cold_bytes > self.cold_capacity_bytes:
            # lowest benefit density goes first, like the hot tier
            key = min(self._cold, key=lambda k: _policy.cost_benefit_score(
                self._cold[k], now, self.hit_half_life_s))
            self._drop_cold(key)

    def _remove(self, key: str) -> None:
        e = self._entries.pop(key, None)
        if e is None:
            e = self._cold.pop(key, None)
            if e is not None:
                self._cold_bytes -= e.table_nbytes
                self.stats.bytes_cold = self._cold_bytes
        else:
            self._bytes -= e.table_nbytes
            self.stats.bytes_cached = self._bytes
        if e is not None:
            self._unindex(key)
            if self.store is not None:
                self.store.delete(key)

    def _unindex(self, key: str) -> None:
        rec = self._index_of.pop(key, None)
        if rec is None:
            return
        idx_key, sig = rec
        self._seq_of.pop(key, None)
        bucket = self._by_measures.get(idx_key)
        if bucket is None:
            return
        _discard(bucket.order, key)
        twb = bucket.by_tw.get(sig.time_window)
        if twb is not None:
            for sub, sub_key in ((twb.by_filters, sig.filters),
                                 (twb.by_levels, sig.levels)):
                lst = sub.get(sub_key)
                if lst is not None:
                    _discard(lst, key)
                    if not lst:
                        del sub[sub_key]
            if not twb.by_filters and not twb.by_levels:
                del bucket.by_tw[sig.time_window]
        if not bucket.order:
            del self._by_measures[idx_key]

    # ----------------------------------------------------- cluster migration
    def export_entries(self) -> list[CacheEntry]:
        """Live entries in LRU order (least-recently-used first), hot tier
        then cold metas (``table is None`` marks a demoted entry whose bytes
        live in the shared store).  Each entry carries its global
        ``lru_stamp``/``store_stamp``, so a cluster rebalance can
        deterministically interleave entries from several source shards (see
        :meth:`rebuild`)."""
        return list(self._entries.values()) + list(self._cold.values())

    def rebuild(self, entries: Iterable[CacheEntry]) -> None:
        """Replace the cache contents with ``entries`` (shard rebalance /
        warm-restart adoption).

        LRU order is reconstructed from ``lru_stamp`` and the derivation
        index's most-recently-stored probe order from ``store_stamp`` — the
        same global clock both stamps were drawn from — so migrated entries
        keep their recency relative to entries already resident on the target
        shard.  Entry state (tables, hit counters, snapshot ids) moves
        untouched; cumulative stats counters are preserved.  Table-less
        entries (cold metas) land in the cold tier — kept only when a store
        is attached to serve their payloads.  Capacity budgets are
        re-enforced afterwards (a shrink migration can evict, counted as
        ordinary evictions)."""
        entries = list(entries)
        self._entries.clear()
        self._cold.clear()
        self._by_measures.clear()
        self._index_of.clear()
        self._seq_of.clear()
        self._bytes = 0
        self._cold_bytes = 0
        kept = []
        for e in sorted(entries, key=lambda e: e.lru_stamp):
            key = e.signature.key()
            if e.table is not None:
                self._entries[key] = e
                self._bytes += e.table_nbytes
            elif self.store is not None and self.store.has(key):
                self._cold[key] = e
                self._cold_bytes += e.table_nbytes
            else:
                continue  # cold meta with no serving store: unservable
            kept.append(e)
        self._seq = 0
        for e in sorted(kept, key=lambda e: e.store_stamp):
            key = e.signature.key()
            self._seq += 1
            self._seq_of[key] = self._seq
            self._index(key, e.signature)
        self.stats.bytes_cached = self._bytes
        self.stats.bytes_cold = self._cold_bytes
        self._enforce_capacity()

    # -------------------------------------------------------- store lifecycle
    def attach_store(self, store, entries: Iterable[CacheEntry] = (),
                     write_through: Optional[bool] = None) -> int:
        """Attach a cold-tier store and adopt replayed entries (warm
        restart).  Adopted metas merge with anything already resident via
        :meth:`rebuild` — live entries win key conflicts (they are newer
        than the replayed copy)."""
        self.store = store
        if write_through is not None:
            self.write_through = write_through
        adopted = list(entries)
        if adopted:
            live = {e.signature.key() for e in self._entries.values()}
            live.update(e.signature.key() for e in self._cold.values())
            adopted = [e for e in adopted if e.signature.key() not in live]
            self.rebuild(self.export_entries() + adopted)
        return len(adopted)

    def detach_store(self) -> None:
        """Drop the store reference; cold metas become unservable and are
        removed (their durable records remain on disk for the next open)."""
        self.store = None
        for key in list(self._cold.keys()):
            e = self._cold.pop(key)
            self._cold_bytes -= e.table_nbytes
            self._unindex(key)
        self._cold_bytes = 0
        self.stats.bytes_cold = 0

    def persist_hot(self) -> int:
        """Spill every hot entry to the store (write-behind; clean versions
        cost only a metadata record).  The graceful-shutdown half of warm
        restart.  Returns the number of entries scheduled."""
        if self.store is None:
            return 0
        n = 0
        for key, e in self._entries.items():
            self.store.spill(key, e, e.table)
            n += 1
        return n

    # ---------------------------------------------------------- introspection
    def entry(self, key: str) -> Optional[CacheEntry]:
        return self._entries.get(key)

    def keys(self) -> list[str]:
        return list(self._entries.keys())

    def cold_keys(self) -> list[str]:
        return list(self._cold.keys())

    def total_bytes(self) -> int:
        return self._bytes

    def tier_stats(self) -> dict:
        """Per-tier observability for the service stats endpoint."""
        return {
            "hot_entries": len(self._entries),
            "cold_entries": len(self._cold),
            "hot_bytes": self._bytes,
            "cold_bytes": self._cold_bytes,
            "promotions": self.stats.promotions,
            "demotions": self.stats.demotions,
            "cold_drops": self.stats.cold_drops,
            "ttl_expiries": self.stats.ttl_expiries,
            "policy": self._resolve_policy().name,
            "store": self.store.stats() if self.store is not None else None,
        }

    def entries_summary(self, limit: int = 256) -> list[dict]:
        """Per-entry policy inputs (age, decayed hits, score) so eviction
        decisions are observable; hot tier first, then cold."""
        now = time.monotonic()
        out = []
        for tier, entries in (("hot", self._entries), ("cold", self._cold)):
            for key, e in entries.items():
                if len(out) >= limit:
                    return out
                out.append({
                    "key": key,
                    "tier": tier,
                    "age_s": now - e.stored_at,
                    "idle_s": now - e.last_used_at,
                    "hits": e.hits,
                    "decayed_hits": _policy.decayed_hits(
                        e, now, self.hit_half_life_s),
                    "cost_ms": e.cost_ms,
                    "nbytes": e.table_nbytes,
                    "score": _policy.cost_benefit_score(
                        e, now, self.hit_half_life_s),
                    "ttl_s": e.ttl_s if e.ttl_s is not None else self.ttl_s,
                    "version": e.version,
                })
        return out


# ------------------------------------------------------------- persistence


def save_cache(cache: SemanticCache, path: str) -> int:
    """Spill the cache to disk — now a thin shim over the tiered store
    (:mod:`repro.storage`): one ``.npz`` payload per entry plus the
    crash-safe manifest (checkpoint + CRC-framed WAL, both written via
    temp file + fsync + atomic rename).  Returns the number of live entries.

    Incremental: an entry whose durable record already matches its
    ``version``/``snapshot_id`` costs only a metadata log record, not a
    payload rewrite.  Keys present on disk but no longer live in the cache
    are tombstoned (and their payload files removed), so a later
    ``load_cache`` cannot resurrect them.  When the cache already has this
    very directory attached as its store, the attached engine is reused
    (its pending write-behind state stays coherent)."""
    import os

    from ..storage.engine import TieredStore

    target = os.path.abspath(path)
    attached = cache.store is not None and cache.store.path == target
    store = cache.store if attached else TieredStore(target, async_spill=False)
    if not attached:
        store.open()
    live: dict = {}
    for key, e in cache._entries.items():
        live[key] = (e, e.table)
    for key, e in cache._cold.items():
        if attached:
            live[key] = (e, None)  # already durable in this very store
        else:
            t = cache.store.peek(key) if cache.store is not None else None
            if t is not None:
                live[key] = (e, t)
    for key, (e, t) in live.items():
        if t is not None:
            store.spill(key, e, t)
    for key in store.keys():
        if key not in live:
            store.delete(key)
    store.flush()
    store.compact()
    if not attached:
        store.close(compact=False)
    return len(live)


def load_cache(cache: SemanticCache, path: str) -> int:
    """Warm a cache from a spill directory — a shim over the tiered store's
    manifest replay.  Entries re-validate their key against the recomputed
    signature hash (tamper/versioning guard), payloads re-verify their
    sha256, and the persisted ``lru_stamp``/``store_stamp`` ride back in so
    LRU order and derivation probe MRU order reconstruct deterministically
    (pre-PR 8 this reset both by re-``put``-ing every entry)."""
    import os

    from ..storage.engine import TieredStore

    store = TieredStore(os.path.abspath(path), async_spill=False)
    entries = store.open()
    adopted = []
    for e in entries:
        key = e.signature.key()
        table = store.peek(key)
        if table is None:
            continue  # damaged payload: never a false hit
        e.table = table
        e.table_nbytes = int(table.nbytes())
        adopted.append(e)
    store.close(compact=False)
    if adopted:
        live = set(cache.keys()) | set(cache.cold_keys())
        adopted = [e for e in adopted if e.signature.key() not in live]
        cache.rebuild(cache.export_entries() + adopted)
    return len(adopted)
