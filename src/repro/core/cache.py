"""The semantic cache store (§3.5, §3.6, §6.2).

Exact-intent lookup by signature hash, plus correctness-preserving
derivations (roll-up, filter-down) found through a metadata index keyed by
measure multiset — the in-memory analogue of the paper's SQLite derivation
index (entries matching requested measures with superset dimensions or
superset filters).  LRU eviction; snapshot-based invalidation where entries
whose time window intersects updated partitions (or is open-ended) are
refreshed while closed windows remain valid.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional

from . import derivations as dv
from .schema import StarSchema
from .signature import Signature
from .table import ResultTable


@dataclasses.dataclass
class CacheEntry:
    signature: Signature
    table: ResultTable
    origin: str  # 'sql' | 'nl'
    snapshot_id: str
    stored_at: float
    hits: int = 0


@dataclasses.dataclass
class CacheStats:
    hits_exact: int = 0
    hits_rollup: int = 0
    hits_filterdown: int = 0
    hits_compose: int = 0
    misses: int = 0
    stores: int = 0
    evictions: int = 0
    invalidations: int = 0
    cross_surface_hits: int = 0  # NL request served by SQL-seeded entry or v.v.
    nl_hits: int = 0

    @property
    def hits(self) -> int:
        return (self.hits_exact + self.hits_rollup + self.hits_filterdown
                + self.hits_compose)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def to_dict(self) -> dict:
        """Serializable counter snapshot (fields + derived totals) for the
        service stats endpoints — the derived values are materialized here
        so ``json.dumps`` can never silently emit a bound method."""
        d = dataclasses.asdict(self)
        d["hits"] = self.hits
        d["lookups"] = self.lookups
        d["hit_rate"] = self.hit_rate
        return d


@dataclasses.dataclass
class LookupResult:
    status: str  # 'hit_exact' | 'hit_rollup' | 'hit_filterdown' | 'miss'
    table: Optional[ResultTable]
    source_key: Optional[str] = None
    source_origin: Optional[str] = None


class SemanticCache:
    def __init__(
        self,
        schema: StarSchema,
        capacity: Optional[int] = None,  # max entries; None = unbounded
        enable_rollup: bool = True,
        enable_filterdown: bool = True,
        enable_compose: bool = False,  # beyond-paper: filter-down o roll-up
        level_mapper: Optional[dv.LevelMapper] = None,
    ):
        self.schema = schema
        self.capacity = capacity
        self.enable_rollup = enable_rollup
        self.enable_filterdown = enable_filterdown
        self.enable_compose = enable_compose
        self.level_mapper = level_mapper
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        # derivation candidate index: (scope, measure multiset) -> keys
        self._by_measures: dict[tuple, list[str]] = {}
        # reverse map key -> index bucket so eviction/invalidation unindexes
        # in O(1) instead of scanning every bucket
        self._index_of: dict[str, tuple] = {}
        self.stats = CacheStats()

    # ------------------------------------------------------------------- api
    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, sig: Signature, request_origin: str = "sql") -> LookupResult:
        key = sig.key()
        entry = self._entries.get(key)
        if entry is not None:
            self._touch(key, entry, request_origin)
            self.stats.hits_exact += 1
            return LookupResult("hit_exact", entry.table, key, entry.origin)

        # derivation pass over candidates sharing the measure multiset,
        # most-recently-used first
        idx_key = (sig.scope, sig.schema, sig.measure_key())
        for cand_key in reversed(self._by_measures.get(idx_key, ())):
            cand = self._entries.get(cand_key)
            if cand is None:
                continue
            if self.enable_rollup:
                plan = dv.plan_rollup(sig, cand.signature, self.schema, cand_key)
                if plan is not None:
                    derived = dv.apply_rollup(
                        plan, sig, cand.signature, cand.table, self.level_mapper
                    )
                    if derived is not None:
                        self._touch(cand_key, cand, request_origin)
                        self.stats.hits_rollup += 1
                        return LookupResult("hit_rollup", derived, cand_key, cand.origin)
            if self.enable_filterdown:
                plan = dv.plan_filterdown(sig, cand.signature, self.schema, cand_key)
                if plan is not None:
                    derived = dv.apply_filterdown(plan, sig, cand.signature, cand.table)
                    self._touch(cand_key, cand, request_origin)
                    self.stats.hits_filterdown += 1
                    return LookupResult("hit_filterdown", derived, cand_key, cand.origin)
            if self.enable_compose:
                plan = dv.plan_compose(sig, cand.signature, self.schema, cand_key)
                if plan is not None:
                    derived = dv.apply_compose(
                        plan, sig, cand.signature, cand.table, self.level_mapper)
                    if derived is not None:
                        self._touch(cand_key, cand, request_origin)
                        self.stats.hits_compose += 1
                        return LookupResult("hit_compose", derived, cand_key, cand.origin)
        self.stats.misses += 1
        return LookupResult("miss", None)

    def put(
        self,
        sig: Signature,
        table: ResultTable,
        origin: str = "sql",
        snapshot_id: str = "snap0",
    ) -> str:
        key = sig.key()
        if key in self._entries:
            self._entries.move_to_end(key)
            self._entries[key].table = table
            self._entries[key].snapshot_id = snapshot_id
            return key
        self._entries[key] = CacheEntry(sig, table, origin, snapshot_id, time.monotonic())
        idx_key = (sig.scope, sig.schema, sig.measure_key())
        self._by_measures.setdefault(idx_key, []).append(key)
        self._index_of[key] = idx_key
        self.stats.stores += 1
        while self.capacity is not None and len(self._entries) > self.capacity:
            self._evict_lru()
        return key

    # ---------------------------------------------------------- invalidation
    def invalidate_snapshot(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> int:
        """New data arrived covering [updated_start, updated_end).  Entries
        with open-ended windows, no window at all (they span everything), or a
        window intersecting the updated partition are dropped; closed windows
        outside the range remain valid (§6.2)."""
        dropped = []
        for key, e in self._entries.items():
            tw = e.signature.time_window
            if tw is None or tw.open_ended:
                dropped.append(key)
            elif updated_start is not None and updated_end is not None:
                if tw.intersects(updated_start, updated_end):
                    dropped.append(key)
            else:  # unknown update extent: conservative — drop everything
                dropped.append(key)
        for key in dropped:
            self._remove(key)
            self.stats.invalidations += 1
        return len(dropped)

    def invalidate_schema_change(self) -> int:
        n = len(self._entries)
        self._entries.clear()
        self._by_measures.clear()
        self._index_of.clear()
        self.stats.invalidations += n
        return n

    # ------------------------------------------------------------- internals
    def _touch(self, key: str, entry: CacheEntry, request_origin: str) -> None:
        self._entries.move_to_end(key)
        entry.hits += 1
        if request_origin == "nl":
            self.stats.nl_hits += 1
        if request_origin != entry.origin:
            self.stats.cross_surface_hits += 1

    def _evict_lru(self) -> None:
        key, _ = self._entries.popitem(last=False)
        self._unindex(key)
        self.stats.evictions += 1

    def _remove(self, key: str) -> None:
        if key in self._entries:
            del self._entries[key]
            self._unindex(key)

    def _unindex(self, key: str) -> None:
        idx_key = self._index_of.pop(key, None)
        if idx_key is None:
            return
        keys = self._by_measures.get(idx_key)
        if keys is not None:
            try:
                keys.remove(key)
            except ValueError:
                pass
            if not keys:
                del self._by_measures[idx_key]

    # ---------------------------------------------------------- introspection
    def entry(self, key: str) -> Optional[CacheEntry]:
        return self._entries.get(key)

    def keys(self) -> list[str]:
        return list(self._entries.keys())

    def total_bytes(self) -> int:
        return sum(e.table.nbytes() for e in self._entries.values())


# ------------------------------------------------------------- persistence


def save_cache(cache: SemanticCache, path: str) -> int:
    """Spill the cache to disk (the paper's Parquet/SQLite store analogue):
    one .npz per entry + a JSON manifest of signatures/origins/snapshots.
    Returns the number of entries written."""
    import json as _json
    import os

    import numpy as np

    os.makedirs(path, exist_ok=True)
    manifest = []
    for i, (key, e) in enumerate(cache._entries.items()):
        fname = f"entry_{i:06d}.npz"
        np.savez(os.path.join(path, fname),
                 **{n: v for n, v in e.table.columns.items()})
        manifest.append({
            "key": key, "file": fname, "origin": e.origin,
            "snapshot_id": e.snapshot_id, "hits": e.hits,
            "signature": e.signature.to_json(),
            "columns": e.table.names,
        })
    with open(os.path.join(path, "manifest.json"), "w") as f:
        _json.dump(manifest, f, default=str)
    return len(manifest)


def load_cache(cache: SemanticCache, path: str) -> int:
    """Warm a cache from a spill directory; entries re-validate their key
    against the recomputed signature hash (tamper/versioning guard)."""
    import json as _json
    import os

    import numpy as np

    from .signature import signature_from_json
    from .table import ResultTable

    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return 0
    with open(mpath) as f:
        manifest = _json.load(f)
    loaded = 0
    for m in manifest:
        try:
            sig = signature_from_json(m["signature"])
        except (KeyError, ValueError):
            continue
        if sig.key() != m["key"]:
            continue  # schema/version drift: refuse stale entries
        data = np.load(os.path.join(path, m["file"]), allow_pickle=False)
        table = ResultTable({n: data[n] for n in m["columns"]})
        cache.put(sig, table, origin=m["origin"], snapshot_id=m["snapshot_id"])
        loaded += 1
    return loaded
