"""The semantic cache store (§3.5, §3.6, §6.2).

Exact-intent lookup by signature hash, plus correctness-preserving
derivations (roll-up, filter-down) found through a metadata index keyed by
measure multiset — the in-memory analogue of the paper's SQLite derivation
index (entries matching requested measures with superset dimensions or
superset filters).  LRU eviction; snapshot-based invalidation where entries
whose time window intersects updated partitions (or is open-ended) are
refreshed while closed windows remain valid.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional

from . import derivations as dv
from .schema import StarSchema
from .signature import Signature
from .table import ResultTable


@dataclasses.dataclass
class CacheEntry:
    signature: Signature
    table: ResultTable
    origin: str  # 'sql' | 'nl'
    snapshot_id: str
    stored_at: float
    hits: int = 0
    refreshes: int = 0  # in-place table replacements on snapshot advance
    refreshed_at: Optional[float] = None


@dataclasses.dataclass
class CacheStats:
    hits_exact: int = 0
    hits_rollup: int = 0
    hits_filterdown: int = 0
    hits_compose: int = 0
    misses: int = 0
    stores: int = 0
    evictions: int = 0
    invalidations: int = 0
    refreshes: int = 0  # entries merged in place from a delta scan
    refresh_fallbacks: int = 0  # affected entries replaced by a full recompute
    cross_surface_hits: int = 0  # NL request served by SQL-seeded entry or v.v.
    nl_hits: int = 0

    @property
    def hits(self) -> int:
        return (self.hits_exact + self.hits_rollup + self.hits_filterdown
                + self.hits_compose)

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return self.hits / n if n else 0.0

    def to_dict(self) -> dict:
        """Serializable counter snapshot (fields + derived totals) for the
        service stats endpoints — the derived values are materialized here
        so ``json.dumps`` can never silently emit a bound method."""
        d = dataclasses.asdict(self)
        d["hits"] = self.hits
        d["lookups"] = self.lookups
        d["hit_rate"] = self.hit_rate
        return d


@dataclasses.dataclass
class LookupResult:
    """Outcome of one cache probe.

    ``status`` is one of ``'hit_exact'`` (signature-key match),
    ``'hit_rollup'`` (re-aggregated from a finer-grained entry),
    ``'hit_filterdown'`` (post-filtered from a superset entry),
    ``'hit_compose'`` (flag-gated beyond-paper derivation: filter-down
    composed with roll-up in one step, e.g. a cached (region, category)
    result answering "by region WHERE category = x"), or ``'miss'``.
    ``source_key``/``source_origin``/``source_snapshot`` identify the
    serving entry and the data snapshot its table reflects.
    """

    status: str
    table: Optional[ResultTable]
    source_key: Optional[str] = None
    source_origin: Optional[str] = None
    source_snapshot: Optional[str] = None


class SemanticCache:
    def __init__(
        self,
        schema: StarSchema,
        capacity: Optional[int] = None,  # max entries; None = unbounded
        enable_rollup: bool = True,
        enable_filterdown: bool = True,
        enable_compose: bool = False,  # beyond-paper: filter-down o roll-up
        level_mapper: Optional[dv.LevelMapper] = None,
    ):
        self.schema = schema
        self.capacity = capacity
        self.enable_rollup = enable_rollup
        self.enable_filterdown = enable_filterdown
        self.enable_compose = enable_compose
        self.level_mapper = level_mapper
        self._entries: "OrderedDict[str, CacheEntry]" = OrderedDict()
        # derivation candidate index: (scope, measure multiset) -> keys
        self._by_measures: dict[tuple, list[str]] = {}
        # reverse map key -> index bucket so eviction/invalidation unindexes
        # in O(1) instead of scanning every bucket
        self._index_of: dict[str, tuple] = {}
        self.stats = CacheStats()

    # ------------------------------------------------------------------- api
    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, sig: Signature, request_origin: str = "sql") -> LookupResult:
        key = sig.key()
        entry = self._entries.get(key)
        if entry is not None:
            self._touch(key, entry, request_origin)
            self.stats.hits_exact += 1
            return LookupResult("hit_exact", entry.table, key, entry.origin,
                                entry.snapshot_id)

        # derivation pass over candidates sharing the measure multiset,
        # most-recently-used first
        idx_key = (sig.scope, sig.schema, sig.measure_key())
        for cand_key in reversed(self._by_measures.get(idx_key, ())):
            cand = self._entries.get(cand_key)
            if cand is None:
                continue
            if self.enable_rollup:
                plan = dv.plan_rollup(sig, cand.signature, self.schema, cand_key)
                if plan is not None:
                    derived = dv.apply_rollup(
                        plan, sig, cand.signature, cand.table, self.level_mapper
                    )
                    if derived is not None:
                        self._touch(cand_key, cand, request_origin)
                        self.stats.hits_rollup += 1
                        return LookupResult("hit_rollup", derived, cand_key,
                                            cand.origin, cand.snapshot_id)
            if self.enable_filterdown:
                plan = dv.plan_filterdown(sig, cand.signature, self.schema, cand_key)
                if plan is not None:
                    derived = dv.apply_filterdown(plan, sig, cand.signature, cand.table)
                    self._touch(cand_key, cand, request_origin)
                    self.stats.hits_filterdown += 1
                    return LookupResult("hit_filterdown", derived, cand_key,
                                        cand.origin, cand.snapshot_id)
            if self.enable_compose:
                plan = dv.plan_compose(sig, cand.signature, self.schema, cand_key)
                if plan is not None:
                    derived = dv.apply_compose(
                        plan, sig, cand.signature, cand.table, self.level_mapper)
                    if derived is not None:
                        self._touch(cand_key, cand, request_origin)
                        self.stats.hits_compose += 1
                        return LookupResult("hit_compose", derived, cand_key,
                                            cand.origin, cand.snapshot_id)
        self.stats.misses += 1
        return LookupResult("miss", None)

    def put(
        self,
        sig: Signature,
        table: ResultTable,
        origin: str = "sql",
        snapshot_id: str = "snap0",
    ) -> str:
        key = sig.key()
        if key in self._entries:
            # full overwrite: provenance (origin, stored_at) must track the
            # new producer, or a SQL-refreshed entry keeps reporting the
            # stale origin in provenance chains and stats forever
            e = self._entries[key]
            self._entries.move_to_end(key)
            e.table = table
            e.snapshot_id = snapshot_id
            e.origin = origin
            e.stored_at = time.monotonic()
            return key
        self._entries[key] = CacheEntry(sig, table, origin, snapshot_id, time.monotonic())
        idx_key = (sig.scope, sig.schema, sig.measure_key())
        self._by_measures.setdefault(idx_key, []).append(key)
        self._index_of[key] = idx_key
        self.stats.stores += 1
        while self.capacity is not None and len(self._entries) > self.capacity:
            self._evict_lru()
        return key

    # ----------------------------------------------- invalidation / refresh
    def affected_keys(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> list[str]:
        """Keys of the entries a data update covering [updated_start,
        updated_end) can affect (§6.2): open-ended windows and windowless
        entries always (they span everything), closed windows only when they
        intersect the updated range, every entry when the update extent is
        unknown.  The caller decides what to do with them — drop
        (``invalidate_snapshot``) or refresh in place (``refresh_entry``)."""
        out = []
        for key, e in self._entries.items():
            tw = e.signature.time_window
            if tw is None or tw.open_ended:
                out.append(key)
            elif updated_start is None or updated_end is None:
                out.append(key)  # unknown update extent: conservative
            elif tw.intersects(updated_start, updated_end):
                out.append(key)
        return out

    def invalidate_snapshot(
        self, updated_start: Optional[str] = None, updated_end: Optional[str] = None
    ) -> int:
        """New data arrived covering [updated_start, updated_end).  Affected
        entries (see ``affected_keys``) are dropped; closed windows outside
        the range remain valid (§6.2)."""
        dropped = self.affected_keys(updated_start, updated_end)
        for key in dropped:
            self._remove(key)
            self.stats.invalidations += 1
        return len(dropped)

    def refresh_entry(
        self, key: str, table: ResultTable, snapshot_id: str, merged: bool = True
    ) -> None:
        """Bring an entry current in place after a data update, instead of
        dropping it: the working set (LRU position, hit counters, derivation
        index membership) survives the snapshot advance.  ``merged`` tells
        the stats whether the table came from a delta merge (the cheap path)
        or a full recompute fallback."""
        e = self._entries.get(key)
        if e is None:
            raise KeyError(f"cannot refresh unknown entry {key!r}")
        e.table = table
        e.snapshot_id = snapshot_id
        e.refreshes += 1
        e.refreshed_at = time.monotonic()
        if merged:
            self.stats.refreshes += 1
        else:
            self.stats.refresh_fallbacks += 1

    def drop(self, key: str) -> bool:
        """Explicitly invalidate one entry by key; True when it existed."""
        if key not in self._entries:
            return False
        self._remove(key)
        self.stats.invalidations += 1
        return True

    def invalidate_schema_change(self) -> int:
        n = len(self._entries)
        self._entries.clear()
        self._by_measures.clear()
        self._index_of.clear()
        self.stats.invalidations += n
        return n

    # ------------------------------------------------------------- internals
    def _touch(self, key: str, entry: CacheEntry, request_origin: str) -> None:
        self._entries.move_to_end(key)
        entry.hits += 1
        if request_origin == "nl":
            self.stats.nl_hits += 1
        if request_origin != entry.origin:
            self.stats.cross_surface_hits += 1

    def _evict_lru(self) -> None:
        key, _ = self._entries.popitem(last=False)
        self._unindex(key)
        self.stats.evictions += 1

    def _remove(self, key: str) -> None:
        if key in self._entries:
            del self._entries[key]
            self._unindex(key)

    def _unindex(self, key: str) -> None:
        idx_key = self._index_of.pop(key, None)
        if idx_key is None:
            return
        keys = self._by_measures.get(idx_key)
        if keys is not None:
            try:
                keys.remove(key)
            except ValueError:
                pass
            if not keys:
                del self._by_measures[idx_key]

    # ---------------------------------------------------------- introspection
    def entry(self, key: str) -> Optional[CacheEntry]:
        return self._entries.get(key)

    def keys(self) -> list[str]:
        return list(self._entries.keys())

    def total_bytes(self) -> int:
        return sum(e.table.nbytes() for e in self._entries.values())


# ------------------------------------------------------------- persistence


def save_cache(cache: SemanticCache, path: str) -> int:
    """Spill the cache to disk (the paper's Parquet/SQLite store analogue):
    one .npz per entry + a JSON manifest of signatures/origins/snapshots.
    Returns the number of entries written.

    Entry files are named by signature-key hash and written via temp file +
    rename, as is the manifest, so a crash mid-spill can never corrupt the
    previous generation: the surviving old manifest keeps pointing at files
    whose names (and therefore signatures) it owns.  Re-spilling to a
    directory that previously held *more* entries removes the now-stale
    ``entry_*.npz`` files — only after the new manifest is durable — so a
    later ``load_cache`` against a hand-edited or partially written manifest
    cannot resurrect them."""
    import json as _json
    import os

    import numpy as np

    os.makedirs(path, exist_ok=True)
    manifest = []
    for key, e in cache._entries.items():
        fname = f"entry_{key[:24]}.npz"
        tmp = os.path.join(path, fname + ".tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **{n: v for n, v in e.table.columns.items()})
        os.replace(tmp, os.path.join(path, fname))
        manifest.append({
            "key": key, "file": fname, "origin": e.origin,
            "snapshot_id": e.snapshot_id, "hits": e.hits,
            "signature": e.signature.to_json(),
            "columns": e.table.names,
        })
    tmp = os.path.join(path, "manifest.json.tmp")
    with open(tmp, "w") as f:
        _json.dump(manifest, f, default=str)
    os.replace(tmp, os.path.join(path, "manifest.json"))
    # remove stale files only once the new manifest is durable: deleting
    # first would leave a crash window where the surviving *old* manifest
    # points at files that no longer exist
    live = {m["file"] for m in manifest}
    for fname in os.listdir(path):
        stale = fname.startswith("entry_") and (
            (fname.endswith(".npz") and fname not in live)
            or fname.endswith(".npz.tmp"))  # orphans of an interrupted spill
        if stale:
            os.remove(os.path.join(path, fname))
    return len(manifest)


def load_cache(cache: SemanticCache, path: str) -> int:
    """Warm a cache from a spill directory; entries re-validate their key
    against the recomputed signature hash (tamper/versioning guard)."""
    import json as _json
    import os

    import numpy as np

    from .signature import signature_from_json
    from .table import ResultTable

    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return 0
    with open(mpath) as f:
        manifest = _json.load(f)
    loaded = 0
    for m in manifest:
        try:
            sig = signature_from_json(m["signature"])
        except (KeyError, ValueError):
            continue
        if sig.key() != m["key"]:
            continue  # schema/version drift: refuse stale entries
        data = np.load(os.path.join(path, m["file"]), allow_pickle=False)
        table = ResultTable({n: data[n] for n in m["columns"]})
        cache.put(sig, table, origin=m["origin"], snapshot_id=m["snapshot_id"])
        loaded += 1
    return loaded
