"""Correctness-preserving reuse beyond exact hits (§3.6).

Two derivations, each guarded by explicit preconditions:

* **Roll-up** — re-aggregate a finer-grained cached entry.  Permitted only for
  composable aggregations (SUM, COUNT, MIN, MAX); AVG / COUNT DISTINCT /
  ratios are rejected.  Requires summarizable hierarchies (functional
  child->parent mapping) and NULL-preserving semantics.
* **Filter-down** — post-filter a cached superset.  The cached result must
  contain the filter attributes needed for the tighter predicate (i.e. they
  are grouping columns of the cached entry).

Both are disabled when either signature carries ORDER BY / LIMIT / HAVING:
re-aggregation or post-filtering can alter top-k membership and group
survival.  Drill-down (finer <- coarser) is unsupported — query-level caching
lacks the detail data.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from .schema import StarSchema
from .signature import COMPOSABLE_AGGS, Signature
from .table import ResultTable, eval_predicate

# A hierarchy value mapper: (dim, fine_level, coarse_level, fine_values) ->
# coarse values.  Built from dimension tables by the dataset/executor; roll-up
# across hierarchy levels is only attempted when a mapper is available and the
# hierarchy is declared summarizable.
LevelMapper = Callable[[str, str, str, np.ndarray], np.ndarray]


@dataclasses.dataclass(frozen=True)
class DerivationPlan:
    kind: str  # 'rollup' | 'filterdown'
    cached_key: str
    # rollup: requested level -> cached level it derives from (same = identity)
    level_map: tuple[tuple[str, str], ...] = ()
    # filterdown: the extra predicates to apply to cached rows
    extra_filters: tuple = ()
    # requested-measure index -> cached-measure index
    measure_map: tuple[int, ...] = ()


def no_postagg(sig: Signature) -> bool:
    """True when a signature carries no HAVING/ORDER BY/LIMIT — the shared
    precondition of every derivation (the cache's index prefilters on it)."""
    return not sig.having and not sig.order_by and sig.limit is None


_no_postagg = no_postagg  # internal alias (pre-index name)


def _match_measures(requested: Signature, cached: Signature) -> Optional[tuple[int, ...]]:
    """Map each requested measure to a distinct cached measure with identical
    (agg, expr, distinct).  None if the multisets differ."""
    used = [False] * len(cached.measures)
    out: list[int] = []
    for m in requested.measures:
        for j, c in enumerate(cached.measures):
            if not used[j] and (c.agg, c.expr, c.distinct) == (m.agg, m.expr, m.distinct):
                used[j] = True
                out.append(j)
                break
        else:
            return None
    if not all(used):
        return None
    return tuple(out)


# ------------------------------------------------------------------- roll-up


def plan_rollup(
    requested: Signature, cached: Signature, schema: StarSchema, cached_key: str
) -> Optional[DerivationPlan]:
    """Check roll-up preconditions; return an executable plan or None."""
    if requested.schema != cached.schema or requested.scope != cached.scope:
        return None
    if not (_no_postagg(requested) and _no_postagg(cached)):
        return None
    if not (requested.all_composable() and cached.all_composable()):
        return None  # precondition (i): composable aggregation only
    mm = _match_measures(requested, cached)
    if mm is None:
        return None
    if requested.filters != cached.filters or requested.time_window != cached.time_window:
        return None
    if requested.levels == cached.levels:
        return None  # that would be an exact hit, not a derivation
    level_map: list[tuple[str, str]] = []
    for lv in requested.levels:
        if lv in cached.levels:
            level_map.append((lv, lv))
            continue
        src = _finer_source(lv, cached.levels, schema)
        if src is None:
            return None  # not derivable: drill-down or cross-hierarchy
        level_map.append((lv, src))
    return DerivationPlan(
        kind="rollup", cached_key=cached_key,
        level_map=tuple(level_map), measure_map=mm,
    )


def _finer_source(coarse: str, cached_levels: tuple[str, ...], schema: StarSchema) -> Optional[str]:
    """Find a cached level that is a strict descendant of ``coarse`` within a
    summarizable hierarchy of the same dimension (precondition ii).

    Memoized *on the schema instance* (the level lattice is a pure function
    of the frozen schema, and roll-up planning re-asks the same (coarse,
    cached-levels) pairs for every probe of a recurring dashboard intent) —
    a schema-keyed global cache would both pin dead schemas process-wide and
    re-hash the whole nested schema per probe."""
    memo = schema.__dict__.get("_lattice_memo")
    if memo is None:
        memo = {}
        object.__setattr__(schema, "_lattice_memo", memo)
    k = (coarse, cached_levels)
    if k not in memo:
        memo[k] = _finer_source_cold(coarse, cached_levels, schema)
    return memo[k]


def _finer_source_cold(coarse: str, cached_levels: tuple[str, ...],
                       schema: StarSchema) -> Optional[str]:
    if "." not in coarse:
        return None
    dim_name, col = coarse.split(".", 1)
    dim = schema.dimension(dim_name)
    if dim is None:
        return None
    h = dim.hierarchy_of(col)
    if h is None or not h.summarizable:
        return None
    for cand in cached_levels:
        if not cand.startswith(dim_name + "."):
            continue
        fine = cand.split(".", 1)[1]
        if h.is_ancestor(col, fine):
            return cand
    return None


def apply_rollup(
    plan: DerivationPlan,
    requested: Signature,
    cached: Signature,
    table: ResultTable,
    mapper: Optional[LevelMapper],
) -> Optional[ResultTable]:
    """Execute a roll-up plan on the cached result (numpy; results are small)."""
    n = table.num_rows
    # 1. derive requested level columns (identity or hierarchy mapping)
    key_cols: dict[str, np.ndarray] = {}
    for req_lv, src_lv in plan.level_map:
        src = table.columns[src_lv]
        if req_lv == src_lv:
            key_cols[req_lv] = src
        else:
            if mapper is None:
                return None
            dim = req_lv.split(".", 1)[0]
            mapped = mapper(dim, src_lv.split(".", 1)[1], req_lv.split(".", 1)[1], src)
            if mapped is None:
                return None
            key_cols[req_lv] = mapped
    # 2. group rows by the composite requested key
    if key_cols:
        inverse, uniques = _group_inverse(list(key_cols.values()), n)
        n_groups = len(next(iter(uniques)))
    else:
        inverse = np.zeros(n, dtype=np.int64)
        uniques = []
        n_groups = 1 if n > 0 else 0
    # 3. re-aggregate each requested measure from its cached source column
    out: dict[str, np.ndarray] = {}
    for lv, u in zip(key_cols.keys(), uniques):
        out[lv] = u
    for ri, ci in enumerate(plan.measure_map):
        agg = requested.measures[ri].agg
        src = table.columns[f"m{ci}"]
        out[f"m{ri}"] = _reaggregate(agg, src, inverse, n_groups)
    # preserve canonical column order: sorted levels then measures
    ordered = {lv: out[lv] for lv in requested.levels}
    for ri in range(len(requested.measures)):
        ordered[f"m{ri}"] = out[f"m{ri}"]
    return ResultTable(ordered)


def _group_inverse(cols: list[np.ndarray], n: int) -> tuple[np.ndarray, list[np.ndarray]]:
    """Factorize a composite key into (inverse indices, unique values percol)."""
    if n == 0:
        return np.zeros(0, dtype=np.int64), [c[:0] for c in cols]
    codes = np.zeros(n, dtype=np.int64)
    dims: list[np.ndarray] = []
    for c in cols:
        u, inv = np.unique(c, return_inverse=True)
        codes = codes * len(u) + inv
        dims.append(u)
    ucodes, inverse = np.unique(codes, return_inverse=True)
    # decode unique composite codes back to per-column values
    uniques: list[np.ndarray] = []
    rem = ucodes
    for u in reversed(dims):
        uniques.append(u[rem % len(u)])
        rem = rem // len(u)
    uniques.reverse()
    return inverse, uniques


def _extreme_at(agg: str, src: np.ndarray, inverse: np.ndarray,
                out: np.ndarray) -> np.ndarray:
    """NaN-aware grouped MIN/MAX scatter shared by roll-up, the refresh
    merge algebra, and the executor's numpy oracle: NaN sources are masked
    out of the ``.at`` call (which would otherwise raise ``RuntimeWarning:
    invalid value encountered``) and their destination groups re-poisoned
    afterwards — a NaN child value still yields a NaN parent, exactly what a
    direct recompute over the NaN-bearing rows produces, without the
    float-compare warnings."""
    red = np.minimum if agg == "MIN" else np.maximum
    ok = ~np.isnan(src)
    red.at(out, inverse[ok], src[ok])
    if not ok.all():
        out[np.unique(inverse[~ok])] = np.nan
    return out


def _reaggregate(agg: str, src: np.ndarray, inverse: np.ndarray, n_groups: int) -> np.ndarray:
    """COUNT rolls up as SUM of counts; SUM/MIN/MAX as themselves (§3.6)."""
    if agg in ("SUM", "COUNT"):
        out = np.zeros(n_groups, dtype=np.float64 if src.dtype.kind == "f" else np.int64)
        np.add.at(out, inverse, src)
        return out
    if agg in ("MIN", "MAX"):
        if src.dtype.kind == "f":
            ident = np.inf if agg == "MIN" else -np.inf
            return _extreme_at(agg, src, inverse,
                               np.full(n_groups, ident, dtype=src.dtype))
        red = np.minimum if agg == "MIN" else np.maximum
        ident = np.iinfo(np.int64).max if agg == "MIN" else np.iinfo(np.int64).min
        out = np.full(n_groups, ident, dtype=np.int64)
        red.at(out, inverse, src)
        return out
    raise AssertionError(f"non-composable agg {agg} escaped precondition check")


# --------------------------------------------------------------- filter-down


def plan_filterdown(
    requested: Signature, cached: Signature, schema: StarSchema, cached_key: str
) -> Optional[DerivationPlan]:
    """Check filter-down preconditions; return an executable plan or None."""
    if requested.schema != cached.schema or requested.scope != cached.scope:
        return None
    if not (_no_postagg(requested) and _no_postagg(cached)):
        return None  # precondition (iii): no ORDER BY / LIMIT
    mm = _match_measures(requested, cached)
    if mm is None:
        return None
    if requested.levels != cached.levels:
        return None
    if requested.time_window != cached.time_window:
        return None
    req_fs, c_fs = requested.filters_frozen(), cached.filters_frozen()
    extra = req_fs - c_fs
    if not extra or c_fs - req_fs:
        return None  # must be a strict tightening
    # precondition (i): every extra filter attribute must be present among the
    # cached grouping columns (the only attributes the cached result retains)
    for f in extra:
        if f.col not in cached.levels:
            return None
    return DerivationPlan(
        kind="filterdown", cached_key=cached_key,
        extra_filters=tuple(sorted(extra, key=lambda f: f.sort_key())), measure_map=mm,
    )


# ------------------------------------------------- composed derivation
# (beyond-paper, flag-gated: filter-down then roll-up in one step — e.g.
#  cached (region, category) answers "by region WHERE category='x'")


def plan_compose(
    requested: Signature, cached: Signature, schema: StarSchema, cached_key: str
) -> Optional[DerivationPlan]:
    if requested.schema != cached.schema or requested.scope != cached.scope:
        return None
    if not (_no_postagg(requested) and _no_postagg(cached)):
        return None
    if not (requested.all_composable() and cached.all_composable()):
        return None
    mm = _match_measures(requested, cached)
    if mm is None:
        return None
    if requested.time_window != cached.time_window:
        return None
    req_fs, c_fs = requested.filters_frozen(), cached.filters_frozen()
    extra = req_fs - c_fs
    if not extra or c_fs - req_fs:
        return None
    for f in extra:
        if f.col not in cached.levels:
            return None  # filter attribute not retained by the cached result
    if requested.levels == cached.levels:
        return None  # that is plain filter-down, handled separately
    level_map: list[tuple[str, str]] = []
    for lv in requested.levels:
        if lv in cached.levels:
            level_map.append((lv, lv))
            continue
        src = _finer_source(lv, cached.levels, schema)
        if src is None:
            return None
        level_map.append((lv, src))
    return DerivationPlan(
        kind="compose", cached_key=cached_key, level_map=tuple(level_map),
        extra_filters=tuple(sorted(extra, key=lambda f: f.sort_key())),
        measure_map=mm,
    )


def apply_compose(
    plan: DerivationPlan, requested: Signature, cached: Signature,
    table: ResultTable, mapper: Optional[LevelMapper],
) -> Optional[ResultTable]:
    mask = np.ones(table.num_rows, dtype=bool)
    for f in plan.extra_filters:
        mask &= eval_predicate(table.columns[f.col], f.op, f.val)
    return apply_rollup(plan, requested, cached, table.mask(mask), mapper)


def apply_filterdown(
    plan: DerivationPlan, requested: Signature, cached: Signature, table: ResultTable
) -> ResultTable:
    mask = np.ones(table.num_rows, dtype=bool)
    for f in plan.extra_filters:
        mask &= eval_predicate(table.columns[f.col], f.op, f.val)
    filtered = table.mask(mask)
    ordered = {lv: filtered.columns[lv] for lv in requested.levels}
    for ri, ci in enumerate(plan.measure_map):
        ordered[f"m{ri}"] = filtered.columns[f"m{ci}"]
    return ResultTable(ordered)
