"""Star/snowflake schema model.

The paper (§3.1) scopes the cache to dashboard-style aggregations over a star or
snowflake schema with a single fact table and dimension joins along schema-defined
foreign keys.  This module is the schema contract every other core component
(canonicalizer, validator, derivations, OLAP executor) works against.

Terminology follows the paper: a *dimension* is a conceptual grouping (Time,
Geography); a *level* is a granularity within a dimension hierarchy
(Year > Quarter > Month).  Hierarchies are declared fine -> coarse and are
functional (each child maps to exactly one parent) unless flagged otherwise —
roll-up derivations require summarizability (§3.6).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

NUMERIC = ("int", "float")


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    dtype: str  # 'int' | 'float' | 'str' | 'date'

    def is_numeric(self) -> bool:
        return self.dtype in NUMERIC


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """An ordered list of levels, finest first (e.g. day < month < quarter < year)."""

    name: str
    levels: tuple[str, ...]  # column names within the owning dimension, fine -> coarse
    summarizable: bool = True  # functional child->parent mapping at every step

    def is_ancestor(self, coarse: str, fine: str) -> bool:
        """True iff ``coarse`` is a strict ancestor of ``fine`` in this hierarchy."""
        if coarse not in self.levels or fine not in self.levels:
            return False
        return self.levels.index(coarse) > self.levels.index(fine)


@dataclasses.dataclass(frozen=True)
class Dimension:
    """A dimension table joined to the fact along a schema-defined foreign key.

    Role-playing dimensions (one physical table joined twice, e.g. pickup/dropoff
    dates) must be declared as *separate* Dimension objects with distinct names
    and distinct fact FKs — this is what keeps join paths unique (§3.3).
    """

    name: str
    fact_fk: str  # foreign-key column on the fact table
    pk: str  # primary-key column on this dimension
    columns: tuple[Column, ...]
    hierarchies: tuple[Hierarchy, ...] = ()
    # Time semantics per column, for window canonicalization (§3.3): maps a
    # column name to one of {'date','year','yearmonthnum','yearmonth_str',
    # 'yearquarter_str'}.  Levels without an entry stay ordinary filters.
    time_kinds: tuple[tuple[str, str], ...] = ()

    def time_kind(self, col: str) -> Optional[str]:
        for c, k in self.time_kinds:
            if c == col:
                return k
        return None

    def column(self, name: str) -> Optional[Column]:
        for c in self.columns:
            if c.name == name:
                return c
        return None

    def hierarchy_of(self, level: str) -> Optional[Hierarchy]:
        for h in self.hierarchies:
            if level in h.levels:
                return h
        return None


@dataclasses.dataclass(frozen=True)
class FactTable:
    name: str
    columns: tuple[Column, ...]  # measures + foreign keys + degenerate dims
    date_column: Optional[str] = None  # raw date column used for time windows

    def column(self, name: str) -> Optional[Column]:
        for c in self.columns:
            if c.name == name:
                return c
        return None


class AmbiguousColumn(Exception):
    """An unqualified column name resolves to more than one (table, column)."""


class UnknownColumn(Exception):
    """A column reference does not exist anywhere in the schema."""


@dataclasses.dataclass(frozen=True)
class StarSchema:
    name: str
    fact: FactTable
    dimensions: tuple[Dimension, ...]
    # The dimension (by name) that carries the time hierarchy, if any.  Time
    # windows (§3.3) are expressed against either fact.date_column or this
    # dimension's date-valued pk attribute.
    time_dimension: Optional[str] = None

    # ------------------------------------------------------------------ lookup
    def dimension(self, name: str) -> Optional[Dimension]:
        for d in self.dimensions:
            if d.name == name:
                return d
        return None

    def tables(self) -> dict[str, tuple[Column, ...]]:
        out = {self.fact.name: self.fact.columns}
        for d in self.dimensions:
            out[d.name] = d.columns
        return out

    def resolve_column(self, name: str, table: Optional[str] = None) -> tuple[str, Column]:
        """Resolve a (possibly unqualified) column reference to (table, Column).

        Raises AmbiguousColumn when an unqualified name appears in several
        tables — the paper bypasses such requests rather than guessing.
        """
        if table is not None:
            cols = self.tables().get(table)
            if cols is None:
                raise UnknownColumn(f"unknown table {table!r}")
            for c in cols:
                if c.name == name:
                    return table, c
            raise UnknownColumn(f"column {table}.{name} does not exist")
        hits: list[tuple[str, Column]] = []
        for tname, cols in self.tables().items():
            for c in cols:
                if c.name == name:
                    hits.append((tname, c))
        if not hits:
            raise UnknownColumn(f"column {name!r} does not exist in schema {self.name!r}")
        if len(hits) > 1:
            raise AmbiguousColumn(
                f"column {name!r} is ambiguous: {[t for t, _ in hits]}"
            )
        return hits[0]

    def join_path(self, dim_name: str) -> str:
        """Return the fact FK joining ``dim_name``; unique by construction.

        Uniqueness holds because role-playing joins are modeled as separate
        Dimension objects.  A dimension name that does not exist raises.
        """
        d = self.dimension(dim_name)
        if d is None:
            raise UnknownColumn(f"unknown dimension {dim_name!r}")
        return d.fact_fk

    def time_levels(self) -> tuple[str, ...]:
        """Levels of the time dimension's primary hierarchy (fine->coarse)."""
        if self.time_dimension is None:
            return ()
        d = self.dimension(self.time_dimension)
        if d is None or not d.hierarchies:
            return ()
        return d.hierarchies[0].levels

    def is_time_level(self, dim: str, col: str) -> bool:
        return self.time_dimension is not None and dim == self.time_dimension

    def validate(self) -> None:
        """Structural self-check (used by tests and workload constructors)."""
        fact_cols = {c.name for c in self.fact.columns}
        seen_fks: set[str] = set()
        for d in self.dimensions:
            if d.fact_fk not in fact_cols:
                raise ValueError(f"dim {d.name}: fk {d.fact_fk} missing from fact")
            if d.fact_fk in seen_fks:
                raise ValueError(f"fk {d.fact_fk} reused — join path not unique")
            seen_fks.add(d.fact_fk)
            if d.column(d.pk) is None:
                raise ValueError(f"dim {d.name}: pk {d.pk} missing")
            for h in d.hierarchies:
                for lvl in h.levels:
                    if d.column(lvl) is None:
                        raise ValueError(f"dim {d.name}: hierarchy level {lvl} missing")
        if self.time_dimension is not None and self.dimension(self.time_dimension) is None:
            raise ValueError(f"time dimension {self.time_dimension!r} missing")
