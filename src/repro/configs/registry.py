"""Assigned architecture configs (10) + the trainable canonicalizer model.

Each entry is selectable via ``--arch <id>`` in the launchers.  Reduced
same-family configs for CPU smoke tests come from :func:`reduced`.
"""
from __future__ import annotations

import dataclasses

from ..models.model import ModelConfig

CONFIGS: dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


# --------------------------------------------------------------- dense LMs
_reg(ModelConfig(
    name="qwen3-32b", kind="dense", n_layers=64, d_model=5120, n_heads=64,
    kv_heads=8, head_dim=128, d_ff=25600, vocab=151936,
    activation="swiglu", qk_norm=True, rope_theta=1e6,
))
_reg(ModelConfig(
    name="nemotron-4-340b", kind="dense", n_layers=96, d_model=18432, n_heads=96,
    kv_heads=8, head_dim=192, d_ff=73728, vocab=256000,
    activation="squared_relu",
))
_reg(ModelConfig(
    name="gemma-2b", kind="dense", n_layers=18, d_model=2048, n_heads=8,
    kv_heads=1, head_dim=256, d_ff=16384, vocab=256000,
    activation="geglu", tie_embeddings=True,
))
_reg(ModelConfig(
    name="chatglm3-6b", kind="dense", n_layers=28, d_model=4096, n_heads=32,
    kv_heads=2, head_dim=128, d_ff=13696, vocab=65024,
    activation="swiglu", rope_fraction=0.5,  # 2D/partial rotary
))
# ------------------------------------------------------------------- audio
_reg(ModelConfig(
    name="musicgen-large", kind="dense", n_layers=48, d_model=2048, n_heads=32,
    kv_heads=32, head_dim=64, d_ff=8192, vocab=2048,
    activation="gelu", embed_inputs=True,  # EnCodec frame embeddings stub
))
# --------------------------------------------------------------------- MoE
_reg(ModelConfig(
    name="kimi-k2-1t-a32b", kind="moe", n_layers=61, d_model=7168, n_heads=64,
    kv_heads=8, head_dim=112, d_ff=2048, vocab=163840,
    activation="swiglu", n_experts=384, top_k=8, n_shared_experts=1,
    dense_layers=1,
))
_reg(ModelConfig(
    name="qwen3-moe-235b-a22b", kind="moe", n_layers=94, d_model=4096, n_heads=64,
    kv_heads=4, head_dim=128, d_ff=1536, vocab=151936,
    activation="swiglu", qk_norm=True, n_experts=128, top_k=8,
))
# --------------------------------------------------------------------- VLM
_reg(ModelConfig(
    name="pixtral-12b", kind="dense", n_layers=40, d_model=5120, n_heads=32,
    kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
    activation="swiglu", embed_inputs=True,  # ViT patch embeddings stub
))
# ------------------------------------------------------------------ hybrid
_reg(ModelConfig(
    name="zamba2-7b", kind="hybrid", n_layers=81, d_model=3584, n_heads=32,
    kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    activation="geglu", ssm_state=64, ssm_heads=112, ssm_head_dim=64,
    d_inner=7168, attn_every=6,
))
# --------------------------------------------------------------------- SSM
_reg(ModelConfig(
    name="mamba2-780m", kind="ssm", n_layers=48, d_model=1536, n_heads=1,
    kv_heads=1, head_dim=64, d_ff=0, vocab=50280,
    activation="swiglu", ssm_state=128, ssm_heads=48, ssm_head_dim=64,
    d_inner=3072,
))
# ------------------------------------------- trainable canonicalizer (ours)
_reg(ModelConfig(
    name="canonicalizer-100m", kind="dense", n_layers=12, d_model=768, n_heads=12,
    kv_heads=4, head_dim=64, d_ff=2048, vocab=8192,
    activation="swiglu", qk_norm=True,
))

ASSIGNED = [n for n in CONFIGS if n != "canonicalizer-100m"]

# archs with sub-quadratic sequence mixing: the only ones eligible for the
# long_500k shape (full attention at 524k context is out of scope — DESIGN.md)
SUBQUADRATIC = ("zamba2-7b", "mamba2-780m")


def get(name: str) -> ModelConfig:
    return CONFIGS[name]


def reduced(name: str) -> ModelConfig:
    """Same-family tiny config for single-CPU smoke tests."""
    cfg = CONFIGS[name]
    kw = dict(
        name=cfg.name + "-smoke", n_layers=2, d_model=64, d_ff=128, vocab=256,
        n_heads=4, kv_heads=max(1, min(cfg.kv_heads, 2)), head_dim=16,
    )
    if cfg.kind == "moe":
        kw.update(n_experts=8, top_k=2, d_ff=64,
                  n_shared_experts=cfg.n_shared_experts,
                  dense_layers=min(cfg.dense_layers, 1))
    if cfg.kind in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_heads=4, ssm_head_dim=16, d_inner=64)
    if cfg.kind == "hybrid":
        kw.update(n_layers=5, attn_every=2)
    return dataclasses.replace(cfg, **kw)
