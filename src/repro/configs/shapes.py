"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Shapes (per assignment):
  train_4k    — train_step,  seq 4,096,  global batch 256
  prefill_32k — serve prefill, seq 32,768, global batch 32
  decode_32k  — serve_step (1 new token, KV cache of 32,768), batch 128
  long_500k   — serve_step, cache 524,288, batch 1 (sub-quadratic archs only)

``input_specs`` returns ShapeDtypeStructs (no allocation); audio/vlm archs get
precomputed frame/patch embeddings for prefill/train (modality frontend stub)
and token ids for decode.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, reduced_seq: int | None = None):
    """ShapeDtypeStruct stand-ins for every model input of this step."""
    s = reduced_seq or shape.seq_len
    b = shape.global_batch
    if shape.kind == "train":
        if cfg.embed_inputs:
            return {"embeddings": sds((b, s, cfg.d_model), cfg.dtype),
                    "labels": sds((b, s), jnp.int32)}
        return {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.embed_inputs:
            return {"embeddings": sds((b, s, cfg.d_model), cfg.dtype)}
        return {"tokens": sds((b, s), jnp.int32)}
    if shape.kind == "decode":
        mod = cfg.build()
        caches = jax.eval_shape(lambda: mod.make_cache(cfg, b, s))
        return {
            "token": sds((b,), jnp.int32),
            "caches": caches,
            "pos": sds((b,), jnp.int32),
        }
    raise ValueError(shape.kind)
