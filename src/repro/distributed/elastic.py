"""Elastic scaling and straggler/failure handling.

On 1000+-node fleets the failure model is: a pod (or node) drops, the job
controller rebuilds the mesh without it, and training resumes from the last
checkpoint — checkpoints store logical arrays (training/checkpoint.py), so a
restore onto any mesh shape is well-defined.  This module provides the
controller-side pieces:

  * ``plan_remesh``  — choose a new mesh shape after losing devices,
  * ``ElasticController`` — restart loop: run -> failure -> remesh -> restore,
  * ``StragglerPolicy`` — per-step deadline tracking; a host that repeatedly
    exceeds the deadline is reported for exclusion at the next remesh
    (TPU SPMD steps are globally synchronous, so mitigation == exclusion, not
    work-stealing).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axis_names: tuple
    devices_used: int


def plan_remesh(n_devices: int, model_parallel: int,
                prefer_pods: bool = True) -> MeshPlan:
    """Largest (pod, data, model) grid that fits the surviving devices while
    preserving the model-parallel degree (params resharding across a changed
    TP degree is a different checkpoint layout; elastic rescale keeps TP
    fixed and flexes data/pod)."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with {n_devices} devices")
    data = n_devices // model_parallel
    # prefer a pod axis when the data extent splits evenly in 2
    if prefer_pods and data % 2 == 0 and data >= 4:
        return MeshPlan((2, data // 2, model_parallel), ("pod", "data", "model"),
                        2 * (data // 2) * model_parallel)
    return MeshPlan((data, model_parallel), ("data", "model"),
                    data * model_parallel)


def build_mesh(plan: MeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    if len(devices) < plan.devices_used:
        raise ValueError("not enough devices for plan")
    import numpy as np

    arr = np.asarray(devices[: plan.devices_used]).reshape(plan.shape)
    return jax.sharding.Mesh(arr, plan.axis_names)


@dataclasses.dataclass
class StragglerPolicy:
    deadline_factor: float = 2.0  # x median step time
    strikes_to_exclude: int = 3
    window: int = 50

    def __post_init__(self):
        self._times: list[float] = []
        self._strikes: dict[int, int] = {}

    def observe(self, host_id: int, step_time: float) -> None:
        self._times.append(step_time)
        self._times = self._times[-self.window:]
        med = sorted(self._times)[len(self._times) // 2]
        if step_time > self.deadline_factor * med and len(self._times) >= 5:
            self._strikes[host_id] = self._strikes.get(host_id, 0) + 1
        else:
            self._strikes[host_id] = 0

    def excluded_hosts(self) -> list[int]:
        return [h for h, s in self._strikes.items() if s >= self.strikes_to_exclude]


class ElasticController:
    """Run a restartable job; on simulated/real device loss, re-plan the mesh
    and restart from the latest checkpoint."""

    def __init__(self, run_fn: Callable[[object], dict], model_parallel: int):
        self.run_fn = run_fn  # receives a Mesh, returns result dict
        self.model_parallel = model_parallel
        self.restarts = 0

    def run(self, max_restarts: int = 3, fail_injector: Optional[Callable] = None):
        devices = list(jax.devices())
        while True:
            plan = plan_remesh(len(devices), self.model_parallel)
            mesh = build_mesh(plan, devices)
            try:
                if fail_injector is not None:
                    fail_injector(self.restarts)
                return self.run_fn(mesh)
            except DeviceLossError as e:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                devices = [d for d in devices if d.id not in e.lost_ids]
                time.sleep(0.01)  # backoff placeholder


class DeviceLossError(RuntimeError):
    def __init__(self, lost_ids):
        super().__init__(f"lost devices {lost_ids}")
        self.lost_ids = set(lost_ids)
