"""Sharding rules and activation-sharding hints.

Parameter shardings are derived from param-tree paths (Megatron-style TP over
the 'model' axis, batch over ('pod','data')).  Activation hints are applied
through a context: layer code calls ``hint(x, 'residual')`` and the launcher
decides what (if anything) that means on the active mesh — empty context means
no constraint, so single-device smoke tests trace the same code.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE_HINTS: contextvars.ContextVar[dict] = contextvars.ContextVar(
    "repro_sharding_hints", default={}
)


@contextlib.contextmanager
def sharding_hints(hints: dict[str, P]):
    token = _ACTIVE_HINTS.set(dict(hints))
    try:
        yield
    finally:
        _ACTIVE_HINTS.reset(token)


def hint(x, name: str):
    """Apply the named activation-sharding constraint if one is active."""
    spec = _ACTIVE_HINTS.get().get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------- param rules


def batch_axes(mesh_axis_names) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names)


def param_spec(path: str, shape: tuple, model_size: int, stacked: bool) -> P:
    """TP sharding rule for one parameter, by its tree path.

    ``stacked``: the leading axis is the scanned layer axis; rules shift by 1.
    Output-dim sharding applies only when divisible by the model-axis size —
    small models (e.g. gemma-2b's 8 q-heads) replicate instead, which is the
    honest cost of narrow models on wide meshes.
    """
    off = 1 if stacked else 0

    def dim_ok(i: int) -> bool:
        return shape[i + off] % model_size == 0

    def spec(*axes) -> P:
        return P(*([None] * off + list(axes)))

    leaf = path.split("/")[-1]
    if leaf in ("embed",):
        return P("model", None) if shape[0] % model_size == 0 else P(None, None)
    if leaf in ("lm_head",):
        return P(None, "model") if shape[1] % model_size == 0 else P(None, None)
    if leaf in ("wq", "wk", "wv", "w1", "w3", "wz", "wx", "in_up"):
        return spec(None, "model") if dim_ok(1) else spec(None, None)
    if leaf in ("wo", "w2", "out_proj"):
        return spec("model", None) if dim_ok(0) else spec(None, None)
    if leaf in ("moe_w1", "moe_w3"):  # (Es, El, D, F) expert-sharded
        return spec("model", None, None, None)
    if leaf in ("moe_w2",):
        return spec("model", None, None, None)
    if leaf in ("conv",):  # depthwise conv (K, d_inner)
        return spec(None, "model") if dim_ok(1) else spec(None, None)
    # norms, biases, routers, dt/A params: replicated
    return spec(*([None] * (len(shape) - off)))


def tree_param_specs(params_shape, model_size: int, stacked_prefixes=("layers",)):
    """Build a PartitionSpec pytree parallel to a params shape-tree."""

    def walk(tree, path, stacked):
        if isinstance(tree, dict):
            return {
                k: walk(v, f"{path}/{k}" if path else k,
                        stacked or k in stacked_prefixes)
                for k, v in tree.items()
            }
        return param_spec(path, tree.shape, model_size, stacked)

    return walk(params_shape, "", False)


def named_sharding_tree(spec_tree, mesh) -> object:
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
