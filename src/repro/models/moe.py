"""Mixture-of-Experts FFN with capacity-bounded gather dispatch.

TPU/pjit-native expert parallelism without shard_map: expert weights are laid
out (E_shards, E_local, D, F) with the shard axis partitioned over 'model'.
A ``lax.scan`` over the E_local axis processes one expert *per model shard*
per step — each step gathers that expert's tokens (capacity-bounded, computed
with a static-size ``top_k`` trick), runs the expert GEMMs, and scatter-adds
the gated outputs.  GSPMD keeps each shard's gather/GEMM local to its experts
and inserts one activation all-reduce per step, the same collective a TP MLP
would pay.

FLOP count matches real top-k routing (T·k·2DF·capacity_slack), unlike dense
masked dispatch which would be E/k times too large — this matters for the
roofline numbers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .model import scan_layers

from ..distributed.sharding import hint


def moe_param_shapes(cfg, e_shards: int) -> dict:
    from .model import ShapeLeaf

    e_local = cfg.n_experts // e_shards
    glu = cfg.activation in ("swiglu", "geglu")
    shapes = {
        "router": ShapeLeaf((cfg.d_model, cfg.n_experts), jnp.float32),
        "moe_w1": ShapeLeaf((e_shards, e_local, cfg.d_model, cfg.d_ff)),
        "moe_w2": ShapeLeaf((e_shards, e_local, cfg.d_ff, cfg.d_model)),
    }
    if glu:
        shapes["moe_w3"] = ShapeLeaf((e_shards, e_local, cfg.d_model, cfg.d_ff))
    if cfg.n_shared_experts:
        shapes["shared_w1"] = ShapeLeaf((cfg.d_model, cfg.d_ff * cfg.n_shared_experts))
        shapes["shared_w2"] = ShapeLeaf((cfg.d_ff * cfg.n_shared_experts, cfg.d_model))
        if glu:
            shapes["shared_w3"] = ShapeLeaf((cfg.d_model, cfg.d_ff * cfg.n_shared_experts))
    return shapes


def moe_ffn(p, x, cfg):
    """x: (B, S, D) -> (B, S, D).  Top-k routing with capacity factor."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e = cfg.n_experts
    k = cfg.top_k
    e_shards, e_local = p["moe_w1"].shape[0], p["moe_w1"].shape[1]
    # per-(shard, local-expert) capacity; slack absorbs routing imbalance
    cap = min(t, max(8, int(t * k / e * cfg.capacity_factor)))

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    gates, ids = jax.lax.top_k(logits, k)  # (T, k)
    gates = jax.nn.softmax(gates, axis=-1).astype(x.dtype)

    glu = cfg.activation in ("swiglu", "geglu")
    act = jax.nn.silu if cfg.activation != "geglu" else jax.nn.gelu

    # pad token table with a zero row: capacity overflow and empty slots
    # gather row T and contribute nothing
    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)

    def step(y, inp):
        """Process experts {s * e_local + j : s in [0, e_shards)} at once."""
        w1, w2, w3, j = inp  # w1: (Es, D, F) sharded over 'model' on axis 0
        expert_ids = jnp.arange(e_shards) * e_local + j  # (Es,)
        # match[t, k, es]: token t's k-th route hits shard es's expert j
        match = ids[None, :, :] == expert_ids[:, None, None]  # (Es, T, k)
        tok_gate = jnp.where(match, gates[None], 0.0)  # (Es, T, k)
        tok_hit = match.any(axis=-1)  # (Es, T)
        tok_gate_sum = tok_gate.sum(axis=-1)  # (Es, T)
        # capacity-bounded token selection per shard-expert (static size)
        prio = jnp.where(tok_hit, jnp.arange(t)[None, :], t)
        sel = jax.lax.top_k(-prio, cap)[1]  # (Es, cap) indices of first hits
        sel_idx = jnp.take_along_axis(prio, sel, axis=1)  # (Es, cap); t == fill
        gate_sel = jnp.take_along_axis(
            jnp.concatenate([tok_gate_sum, jnp.zeros((e_shards, 1), tok_gate_sum.dtype)], 1),
            sel_idx, axis=1,
        )  # (Es, cap)
        xe = xpad[sel_idx]  # (Es, cap, D)
        h = jnp.einsum("ecd,edf->ecf", xe, w1)
        if glu:
            h = act(h) * jnp.einsum("ecd,edf->ecf", xe, w3)
        elif cfg.activation == "squared_relu":
            r = jax.nn.relu(h)
            h = r * r
        else:
            h = act(h)
        out = jnp.einsum("ecf,efd->ecd", h, w2)  # (Es, cap, D)
        out = out * gate_sel[..., None].astype(out.dtype)
        # scatter-add into the token table (padded row swallows fills)
        y = y.at[sel_idx.reshape(-1)].add(out.reshape(-1, d))
        return y, None

    w1 = jnp.swapaxes(p["moe_w1"], 0, 1)  # (El, Es, D, F): scan over El
    w2 = jnp.swapaxes(p["moe_w2"], 0, 1)
    w3 = jnp.swapaxes(p["moe_w3"], 0, 1) if glu else jnp.zeros_like(w1)
    y0 = jnp.zeros((t + 1, d), x.dtype)
    y, _ = scan_layers(step, y0, (w1, w2, w3, jnp.arange(e_local)))
    y = y[:t]

    if cfg.n_shared_experts:
        h = xt @ p["shared_w1"]
        if glu:
            h = act(h) * (xt @ p["shared_w3"])
        else:
            h = act(h)
        y = y + h @ p["shared_w2"]
    return hint(y.reshape(b, s, d), "residual")
