"""Model configuration, the common Model interface, and the layer-scan
wrapper (switchable to full unroll for trip-count-complete cost analysis).

Every assigned architecture is an instance of ModelConfig dispatched to one of
the family implementations (transformer / moe inside transformer.py, ssm in
mamba2.py, hybrid in zamba2.py).  Parameters are plain nested dicts of arrays;
layer stacks are stored stacked (leading layer axis) and executed with
``jax.lax.scan`` so the lowered HLO stays small at 96 layers.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

# When set, layer stacks fully unroll instead of lowering to a while loop.
# XLA's HloCostAnalysis counts loop bodies ONCE (it does not multiply by trip
# count), so the dry-run lowers an unrolled variant purely for FLOP/byte
# accounting; the compiled artifact stays scanned.
_UNROLL = contextvars.ContextVar("repro_scan_unroll", default=False)


@contextlib.contextmanager
def unrolled_scans():
    token = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(token)


def scan_layers(f, init, xs, length=None):
    if _UNROLL.get():
        return jax.lax.scan(f, init, xs, length=length, unroll=True)
    return jax.lax.scan(f, init, xs, length=length)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str  # 'dense' | 'moe' | 'ssm' | 'hybrid'
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0  # chatglm-style partial rotary ("RoPE 2d")
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    dense_layers: int = 0  # leading dense layers before MoE stack (kimi-style)
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0
    attn_every: int = 0  # zamba: one shared attention block every N mamba layers
    conv_kernel: int = 4
    # modality stub: prefill consumes precomputed frame/patch embeddings
    embed_inputs: bool = False
    dtype: Any = jnp.bfloat16

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline accounting)."""
        import numpy as np

        shapes = self.param_shapes()
        total = 0

        def walk(t):
            nonlocal total
            if isinstance(t, dict):
                for v in t.values():
                    walk(v)
            else:
                total += int(np.prod(t.shape))

        walk(shapes)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.kind != "moe":
            return self.param_count()
        total = self.param_count()
        moe_layers = self.n_layers - self.dense_layers
        expert_params = moe_layers * self.n_experts * (
            (2 if self.activation not in ("swiglu", "geglu") else 3)
            * self.d_model * self.d_ff
        )
        active_expert = expert_params * (self.top_k + self.n_shared_experts) / self.n_experts
        return int(total - expert_params + active_expert)

    def param_shapes(self):
        from . import mamba2, transformer, zamba2

        if self.kind in ("dense", "moe"):
            return transformer.param_shapes(self)
        if self.kind == "ssm":
            return mamba2.param_shapes(self)
        if self.kind == "hybrid":
            return zamba2.param_shapes(self)
        raise ValueError(self.kind)

    def build(self):
        """Return the family module exposing init/train/prefill/decode fns."""
        from . import mamba2, transformer, zamba2

        return {"dense": transformer, "moe": transformer,
                "ssm": mamba2, "hybrid": zamba2}[self.kind]


def shapes_to_struct(shapes, dtype):
    """Map a shape-tree to ShapeDtypeStructs (used by dry-run/eval_shape)."""
    import jax

    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, getattr(s, "dtype", None) or dtype),
        shapes,
    )


class ShapeLeaf:
    """A shape-tree leaf: shape + optional dtype override."""

    __slots__ = ("shape", "dtype")

    def __init__(self, shape, dtype=None):
        self.shape = tuple(shape)
        self.dtype = dtype

    def __repr__(self):
        return f"ShapeLeaf{self.shape}"
