"""Mamba2 (SSD) attention-free LM.

Each block: RMSNorm -> {z, x, B, C, dt} projections -> short causal depthwise
conv on the x path -> chunked SSD scan (kernels/ssd_scan) -> D-skip ->
silu(z) gating -> output projection.  The serving "KV cache" is the per-layer
(conv buffer, SSM state) pair — O(1) in sequence length, which is what makes
the long_500k decode shape feasible for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from ..kernels.ssd_scan.ops import ssd_scan
from ..kernels.ssd_scan.ref import ssd_decode_step
from .layers import rmsnorm
from .model import ModelConfig, ShapeLeaf, scan_layers


def block_param_shapes(cfg: ModelConfig) -> dict:
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    return {
        "ln1": ShapeLeaf((cfg.d_model,)),
        "wz": ShapeLeaf((cfg.d_model, di)),
        "wx": ShapeLeaf((cfg.d_model, di)),
        "wb": ShapeLeaf((cfg.d_model, n)),
        "wc": ShapeLeaf((cfg.d_model, n)),
        "wdt": ShapeLeaf((cfg.d_model, h)),
        "dt_bias": ShapeLeaf((h,), jnp.float32),
        "a_log": ShapeLeaf((h,), jnp.float32),
        "d_skip": ShapeLeaf((h,), jnp.float32),
        "conv": ShapeLeaf((cfg.conv_kernel, di)),
        "out_proj": ShapeLeaf((di, cfg.d_model)),
    }


def param_shapes(cfg: ModelConfig) -> dict:
    block = block_param_shapes(cfg)
    out = {
        "embed": ShapeLeaf((cfg.vocab, cfg.d_model)),
        "layers": {k: ShapeLeaf((cfg.n_layers, *v.shape), v.dtype)
                   for k, v in block.items()},
        "final_norm": ShapeLeaf((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ShapeLeaf((cfg.d_model, cfg.vocab))
    return out


def init_params(cfg: ModelConfig, key):
    from .transformer import init_params as tinit

    params = tinit(cfg, key)  # generic scaled-normal init on the shape tree
    # SSD-specific init: negative decay rates, small positive dt bias
    lp = params["layers"]
    lp["a_log"] = jnp.log(jnp.linspace(1.0, 8.0, cfg.ssm_heads))[None, :].repeat(cfg.n_layers, 0)
    lp["dt_bias"] = jnp.full((cfg.n_layers, cfg.ssm_heads), -2.0, jnp.float32)
    lp["d_skip"] = jnp.ones((cfg.n_layers, cfg.ssm_heads), jnp.float32)
    return params


def _causal_conv(x, w):
    """x: (B, S, di); w: (K, di) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is 4: unrolled taps beat a conv op at this size
        out = out + xp[:, i: i + x.shape[1]] * w[i][None, None, :]
    return out


def mamba_block(cfg: ModelConfig, lp, x, state=None, conv_buf=None):
    """x: (B, S, D).  Train/prefill when state is None; else one-step decode
    with state (B, H, P, N) and conv_buf (B, K-1, di)."""
    b, s, d = x.shape
    h_heads, p_dim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = rmsnorm(x, lp["ln1"])
    z = xin @ lp["wz"]
    xc = xin @ lp["wx"]
    bm = (xin @ lp["wb"]).astype(jnp.float32)
    cm = (xin @ lp["wc"]).astype(jnp.float32)
    dt = jax.nn.softplus((xin @ lp["wdt"]).astype(jnp.float32) + lp["dt_bias"])
    a = -jnp.exp(lp["a_log"])

    if state is None:
        x_raw = xc  # pre-conv stream: what the decode conv buffer must hold
        xc = _causal_conv(xc, lp["conv"])
        xc = jax.nn.silu(xc)
        xr = xc.reshape(b, s, h_heads, p_dim)
        y = ssd_scan(xr, dt, a, bm, cm, chunk=128)
        y = y + xr * lp["d_skip"][None, None, :, None].astype(y.dtype)
        y = (y.reshape(b, s, -1) * jax.nn.silu(z)).astype(x.dtype)
        out = y @ lp["out_proj"]
        new_state = None
        new_buf = x_raw[:, -(cfg.conv_kernel - 1):] if s >= cfg.conv_kernel - 1 else None
    else:
        # decode: conv over the rolling buffer, single SSD step
        window = jnp.concatenate([conv_buf, xc], axis=1)  # (B, K, di)
        xt = (window * lp["conv"][None]).sum(axis=1, keepdims=True)
        xt = jax.nn.silu(xt)
        xr = xt.reshape(b, h_heads, p_dim)
        y, new_state = ssd_decode_step(
            state, xr, dt[:, 0], a, bm[:, 0], cm[:, 0])
        y = y + xr * lp["d_skip"][None, :, None].astype(y.dtype)
        y = (y.reshape(b, 1, -1) * jax.nn.silu(z)).astype(x.dtype)
        out = y @ lp["out_proj"]
        new_buf = window[:, 1:]
    return hint(x + out, "residual"), new_state, new_buf


# ---------------------------------------------------------------- interface


def forward(cfg: ModelConfig, params, tokens=None, embeddings=None):
    from .transformer import embed_tokens, logits_fn

    x = embeddings.astype(cfg.dtype) if embeddings is not None else embed_tokens(cfg, params, tokens)

    def step(carry, lp):
        y, _, _ = mamba_block(cfg, lp, carry)
        return y, 0

    x, _ = scan_layers(step, x, params["layers"])
    return logits_fn(cfg, params, x)


def loss_fn(cfg: ModelConfig, params, batch):
    from .transformer import loss_fn as tl

    logits = forward(cfg, params, tokens=batch.get("tokens"),
                     embeddings=batch.get("embeddings"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(cfg: ModelConfig, params, tokens=None, embeddings=None, cache_len: int = 0):
    """Returns (last logits, {'state','conv'}, pos).  cache_len is moot for
    SSM (state is O(1)); kept for interface parity."""
    from .transformer import embed_tokens, logits_fn
    from ..kernels.ssd_scan.ref import ssd_final_state

    x = embeddings.astype(cfg.dtype) if embeddings is not None else embed_tokens(cfg, params, tokens)
    b, s = x.shape[0], x.shape[1]
    states, bufs = [], []

    def step(carry, lp):
        xin = rmsnorm(carry, lp["ln1"])
        x_raw = xin @ lp["wx"]  # pre-conv stream (decode conv buffer)
        xc = jax.nn.silu(_causal_conv(x_raw, lp["conv"]))
        bm = (xin @ lp["wb"]).astype(jnp.float32)
        cm = (xin @ lp["wc"]).astype(jnp.float32)
        dt = jax.nn.softplus((xin @ lp["wdt"]).astype(jnp.float32) + lp["dt_bias"])
        a = -jnp.exp(lp["a_log"])
        xr = xc.reshape(b, s, cfg.ssm_heads, cfg.ssm_head_dim)
        st = ssd_final_state(xr, dt, a, bm, cm)
        y, _, _ = mamba_block(cfg, lp, carry)
        buf = x_raw[:, -(cfg.conv_kernel - 1):]
        return y, (st, buf)

    x, (states, bufs) = scan_layers(step, x, params["layers"])
    logits = logits_fn(cfg, params, x[:, -1:])
    pos = jnp.full((b,), s, jnp.int32)
    return logits[:, 0], {"state": states, "conv": bufs}, pos


def decode_step(cfg: ModelConfig, params, token, caches, pos):
    from .transformer import embed_tokens, logits_fn

    x = embed_tokens(cfg, params, token[:, None])

    def step(carry, inp):
        lp, st, buf = inp
        y, new_st, new_buf = mamba_block(cfg, lp, carry, state=st, conv_buf=buf)
        return y, (new_st, new_buf)

    x, (states, bufs) = scan_layers(step, x, (params["layers"], caches["state"], caches["conv"]))
    logits = logits_fn(cfg, params, x)
    return logits[:, 0], {"state": states, "conv": bufs}, pos + 1


def make_cache(cfg: ModelConfig, batch: int, cache_len: int = 0):
    l = cfg.n_layers
    return {
        "state": jnp.zeros((l, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
        "conv": jnp.zeros((l, batch, cfg.conv_kernel - 1, cfg.d_inner), cfg.dtype),
    }
