"""Zamba2 hybrid: Mamba2 backbone with a *shared* attention block.

One set of attention+MLP weights is re-applied after every ``attn_every``
mamba layers (the Zamba2 signature move: global attention capacity at a tiny
parameter cost).  The serving cache is therefore hybrid: per-mamba-layer
(conv, SSM state) pairs plus per-*application* KV caches for the shared block
(same weights, separate caches).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import AttnParamsSpec, attention, attn_param_shapes, mlp, mlp_param_shapes, rmsnorm
from .mamba2 import block_param_shapes, mamba_block
from .model import ModelConfig, ShapeLeaf, scan_layers


def _attn_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def param_shapes(cfg: ModelConfig) -> dict:
    mblock = block_param_shapes(cfg)
    aspec = AttnParamsSpec(cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd, cfg.qk_norm)
    shared = {k: ShapeLeaf(v) for k, v in attn_param_shapes(aspec).items()}
    shared.update({f"mlp_{k}": ShapeLeaf(v) for k, v in
                   mlp_param_shapes(cfg.d_model, cfg.d_ff, cfg.activation).items()})
    shared["ln1"] = ShapeLeaf((cfg.d_model,))
    shared["ln2"] = ShapeLeaf((cfg.d_model,))
    out = {
        "embed": ShapeLeaf((cfg.vocab, cfg.d_model)),
        "mamba": {k: ShapeLeaf((cfg.n_layers, *v.shape), v.dtype)
                  for k, v in mblock.items()},
        "shared_attn": shared,
        "final_norm": ShapeLeaf((cfg.d_model,)),
        "lm_head": ShapeLeaf((cfg.d_model, cfg.vocab)),
    }
    return out


def init_params(cfg: ModelConfig, key):
    from .transformer import init_params as tinit

    params = tinit(cfg, key)
    lp = params["mamba"]
    lp["a_log"] = jnp.log(jnp.linspace(1.0, 8.0, cfg.ssm_heads))[None, :].repeat(cfg.n_layers, 0)
    lp["dt_bias"] = jnp.full((cfg.n_layers, cfg.ssm_heads), -2.0, jnp.float32)
    lp["d_skip"] = jnp.ones((cfg.n_layers, cfg.ssm_heads), jnp.float32)
    return params


def _shared_block(cfg, sp, x, kv_cache=None, cache_pos=None):
    h, kv = attention(
        sp, rmsnorm(x, sp["ln1"]),
        n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=cfg.hd,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        kv_cache=kv_cache, cache_pos=cache_pos,
    )
    x = x + h
    h = mlp({k[4:]: v for k, v in sp.items() if k.startswith("mlp_")},
            rmsnorm(x, sp["ln2"]), cfg.activation)
    return x + h, kv


def _segments(cfg: ModelConfig):
    """[(start, length, apply_attn_after)] covering all mamba layers."""
    segs = []
    start = 0
    while start < cfg.n_layers:
        ln = min(cfg.attn_every, cfg.n_layers - start)
        segs.append((start, ln, start + ln <= cfg.n_layers and ln == cfg.attn_every))
        start += ln
    return segs


def _slice_stack(tree, start, length):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0), tree)


def forward(cfg: ModelConfig, params, tokens=None, embeddings=None):
    from .transformer import embed_tokens, logits_fn

    x = embeddings.astype(cfg.dtype) if embeddings is not None else embed_tokens(cfg, params, tokens)

    def mstep(carry, lp):
        y, _, _ = mamba_block(cfg, lp, carry)
        return y, 0

    for start, ln, attn_after in _segments(cfg):
        seg = _slice_stack(params["mamba"], start, ln)
        x, _ = scan_layers(mstep, x, seg)
        if attn_after:
            x, _ = _shared_block(cfg, params["shared_attn"], x)
    return logits_fn(cfg, params, x)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, tokens=batch.get("tokens"),
                     embeddings=batch.get("embeddings"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def prefill(cfg: ModelConfig, params, tokens=None, embeddings=None, cache_len: int = 0):
    from .transformer import embed_tokens, logits_fn
    from ..kernels.ssd_scan.ref import ssd_final_state
    from .mamba2 import _causal_conv

    x = embeddings.astype(cfg.dtype) if embeddings is not None else embed_tokens(cfg, params, tokens)
    b, s = x.shape[0], x.shape[1]

    def mstep(carry, lp):
        xin = rmsnorm(carry, lp["ln1"])
        x_raw = xin @ lp["wx"]  # pre-conv stream (decode conv buffer)
        xc = jax.nn.silu(_causal_conv(x_raw, lp["conv"]))
        bm = (xin @ lp["wb"]).astype(jnp.float32)
        cm = (xin @ lp["wc"]).astype(jnp.float32)
        dt = jax.nn.softplus((xin @ lp["wdt"]).astype(jnp.float32) + lp["dt_bias"])
        a = -jnp.exp(lp["a_log"])
        xr = xc.reshape(b, s, cfg.ssm_heads, cfg.ssm_head_dim)
        st = ssd_final_state(xr, dt, a, bm, cm)
        y, _, _ = mamba_block(cfg, lp, carry)
        return y, (st, x_raw[:, -(cfg.conv_kernel - 1):])

    states, bufs, attn_kv = [], [], []
    for start, ln, attn_after in _segments(cfg):
        seg = _slice_stack(params["mamba"], start, ln)
        x, (st, buf) = scan_layers(mstep, x, seg)
        states.append(st)
        bufs.append(buf)
        if attn_after:
            x, kv = _shared_block(cfg, params["shared_attn"], x)
            k, v = kv
            if cache_len > s:
                pad = ((0, 0), (0, 0), (0, cache_len - s), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            attn_kv.append((k, v))
    caches = {
        "state": jnp.concatenate(states, axis=0),
        "conv": jnp.concatenate(bufs, axis=0),
        "attn_k": jnp.stack([k for k, _ in attn_kv]),
        "attn_v": jnp.stack([v for _, v in attn_kv]),
    }
    logits = logits_fn(cfg, params, x[:, -1:])
    return logits[:, 0], caches, jnp.full((b,), s, jnp.int32)


def decode_step(cfg: ModelConfig, params, token, caches, pos):
    from .transformer import embed_tokens, logits_fn

    x = embed_tokens(cfg, params, token[:, None])

    def mstep(carry, inp):
        lp, st, buf = inp
        y, new_st, new_buf = mamba_block(cfg, lp, carry, state=st, conv_buf=buf)
        return y, (new_st, new_buf)

    new_states, new_bufs = [], []
    new_k, new_v = [], []
    app = 0
    for start, ln, attn_after in _segments(cfg):
        seg = _slice_stack(params["mamba"], start, ln)
        st = jax.lax.slice_in_dim(caches["state"], start, start + ln, axis=0)
        buf = jax.lax.slice_in_dim(caches["conv"], start, start + ln, axis=0)
        x, (nst, nbuf) = scan_layers(mstep, x, (seg, st, buf))
        new_states.append(nst)
        new_bufs.append(nbuf)
        if attn_after:
            kv = (caches["attn_k"][app], caches["attn_v"][app])
            x, (k, v) = _shared_block(cfg, params["shared_attn"], x,
                                      kv_cache=kv, cache_pos=pos)
            new_k.append(k)
            new_v.append(v)
            app += 1
    caches = {
        "state": jnp.concatenate(new_states, axis=0),
        "conv": jnp.concatenate(new_bufs, axis=0),
        "attn_k": jnp.stack(new_k),
        "attn_v": jnp.stack(new_v),
    }
    logits = logits_fn(cfg, params, x)
    return logits[:, 0], caches, pos + 1


def make_cache(cfg: ModelConfig, batch: int, cache_len: int):
    apps = _attn_apps(cfg)
    return {
        "state": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, cfg.d_inner),
                          cfg.dtype),
        "attn_k": jnp.zeros((apps, batch, cfg.kv_heads, cache_len, cfg.hd), cfg.dtype),
        "attn_v": jnp.zeros((apps, batch, cfg.kv_heads, cache_len, cfg.hd), cfg.dtype),
    }
