"""Shared model layers: norms, RoPE variants, GQA attention, GLU MLPs.

All layers are pure functions over param dicts.  Weight layout is chosen for
TP: projection matrices keep the sharded dimension last (wq/wk/wv/w1/w3) or
first (wo/w2) so the 'model'-axis rules in distributed/sharding.py apply.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from ..kernels.decode_attn.ops import decode_attention
from ..kernels.flash_attn.ops import flash_attention


def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ------------------------------------------------------------------- rotary


def rope_angles(positions, head_dim: int, theta: float, fraction: float = 1.0):
    """positions: (...,) -> (cos, sin) of shape (..., rot/2)."""
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., rot/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, fraction: float = 1.0):
    """x: (B, S, H, Dh); cos/sin: (B, S, rot/2) or (S, rot/2)."""
    dh = x.shape[-1]
    rot = int(dh * fraction) // 2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype) if rot < dh else yr.astype(x.dtype)


# ---------------------------------------------------------------- attention


@dataclasses.dataclass(frozen=True)
class AttnParamsSpec:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    qk_norm: bool


def attn_param_shapes(s: AttnParamsSpec) -> dict:
    shapes = {
        "wq": (s.d_model, s.n_heads * s.head_dim),
        "wk": (s.d_model, s.kv_heads * s.head_dim),
        "wv": (s.d_model, s.kv_heads * s.head_dim),
        "wo": (s.n_heads * s.head_dim, s.d_model),
    }
    if s.qk_norm:
        shapes["q_norm"] = (s.head_dim,)
        shapes["k_norm"] = (s.head_dim,)
    return shapes


def attention(p, x, *, n_heads, kv_heads, head_dim, qk_norm=False,
              rope_theta=1e4, rope_fraction=1.0, positions=None,
              kv_cache=None, cache_pos=None):
    """GQA attention.

    Training/prefill: x (B, S, D), kv_cache None -> (out, (k, v)) where k/v are
    (B, Hkv, S, Dh) for cache seeding.
    Decode: x (B, 1, D), kv_cache = (k, v) preallocated (B, Hkv, Smax, Dh),
    cache_pos (B,) current lengths -> (out, updated cache).
    """
    b, s, d = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, kv_heads, head_dim)
    if qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if positions is None:
        positions = jnp.arange(s)[None, :] if cache_pos is None else cache_pos[:, None]
    cos, sin = rope_angles(positions, head_dim, rope_theta, rope_fraction)
    q = apply_rope(q, cos, sin, rope_fraction)
    k = apply_rope(k, cos, sin, rope_fraction)

    if kv_cache is None:
        qh = hint(q.transpose(0, 2, 1, 3), "attn_heads")  # (B, H, S, Dh)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        out = flash_attention(qh, kh, vh, causal=True)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * head_dim)
        return out @ p["wo"], (kh, vh)

    ck, cv = kv_cache  # (B, Hkv, Smax, Dh)
    idx = cache_pos  # (B,)
    knew = k.reshape(b, kv_heads, head_dim)  # decode: s == 1
    vnew = v.reshape(b, kv_heads, head_dim)
    bidx = jnp.arange(b)
    ck = ck.at[bidx, :, idx, :].set(knew.astype(ck.dtype))
    cv = cv.at[bidx, :, idx, :].set(vnew.astype(cv.dtype))
    qd = q.reshape(b, n_heads, head_dim)
    out = decode_attention(qd, ck, cv, idx + 1)
    out = out.reshape(b, 1, n_heads * head_dim)
    return out @ p["wo"], (ck, cv)


# --------------------------------------------------------------------- MLPs


def mlp_param_shapes(d_model: int, d_ff: int, activation: str) -> dict:
    if activation in ("swiglu", "geglu"):
        return {"w1": (d_model, d_ff), "w3": (d_model, d_ff), "w2": (d_ff, d_model)}
    return {"w1": (d_model, d_ff), "w2": (d_ff, d_model)}  # squared_relu / gelu


def mlp(p, x, activation: str):
    h = x @ p["w1"]
    if activation == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif activation == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"])
    elif activation == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    h = hint(h, "mlp_hidden")
    return h @ p["w2"]
