"""Decoder-only transformer (dense and MoE families).

Layer stacks are stored stacked (leading axis = layer) and executed with
``jax.lax.scan``, keeping the HLO size constant in depth — essential for
compiling 61-96-layer configs quickly in the dry-run.  The same code serves:

  * ``loss_fn``     — training forward + cross-entropy (train_4k shapes),
  * ``prefill``     — full-sequence forward returning seeded KV caches,
  * ``decode_step`` — one-token step against preallocated KV caches.

Audio/VLM archs (musicgen/pixtral) set ``embed_inputs=True``: prefill
consumes precomputed frame/patch embeddings (the modality frontend stub) while
decode consumes token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .layers import attention, attn_param_shapes, mlp, mlp_param_shapes, rmsnorm, AttnParamsSpec
from .model import ModelConfig, ShapeLeaf, scan_layers
from .moe import moe_ffn, moe_param_shapes


# ------------------------------------------------------------- param shapes


def _stack(shapes: dict, n: int) -> dict:
    return {
        k: ShapeLeaf((n, *v.shape), getattr(v, "dtype", None))
        for k, v in shapes.items()
    }


def param_shapes(cfg: ModelConfig) -> dict:
    aspec = AttnParamsSpec(cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd, cfg.qk_norm)
    attn = {k: ShapeLeaf(v) for k, v in attn_param_shapes(aspec).items()}
    norms = {"ln1": ShapeLeaf((cfg.d_model,)), "ln2": ShapeLeaf((cfg.d_model,))}

    def dense_block():
        return {**attn, **{f"mlp_{k}": ShapeLeaf(v) for k, v in
                           mlp_param_shapes(cfg.d_model, cfg.d_ff, cfg.activation).items()},
                **norms}

    out: dict = {"embed": ShapeLeaf((cfg.vocab, cfg.d_model))}
    if cfg.kind == "moe":
        n_moe = cfg.n_layers - cfg.dense_layers
        # dense stack uses a wider FFN (typical for kimi-style leading layers):
        # fall back to 4*d_model when d_ff is the per-expert width
        dense_ff = max(cfg.d_ff, 4 * cfg.d_model)
        if cfg.dense_layers:
            dblock = {**attn,
                      **{f"mlp_{k}": ShapeLeaf(v) for k, v in
                         mlp_param_shapes(cfg.d_model, dense_ff, cfg.activation).items()},
                      **norms}
            out["dense_layers"] = _stack(dblock, cfg.dense_layers)
        e_shards = 16 if cfg.n_experts % 16 == 0 else 1
        mblock = {**attn, **moe_param_shapes(cfg, e_shards), **norms}
        out["layers"] = _stack(mblock, n_moe)
    else:
        out["layers"] = _stack(dense_block(), cfg.n_layers)
    out["final_norm"] = ShapeLeaf((cfg.d_model,))
    if not cfg.tie_embeddings:
        out["lm_head"] = ShapeLeaf((cfg.d_model, cfg.vocab))
    return out


def init_params(cfg: ModelConfig, key):
    """Random init matching the family's param_shapes (scaled normal)."""
    shapes = cfg.param_shapes()  # dispatches on cfg.kind
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, ShapeLeaf))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, leaf in zip(keys, leaves):
        shape = leaf.shape
        dtype = leaf.dtype or cfg.dtype
        if len(shape) >= 2:
            fan_in = shape[-2]
            w = jax.random.normal(k, shape, jnp.float32) * (fan_in ** -0.5)
        else:
            w = jnp.zeros(shape, jnp.float32)
        out.append(w.astype(dtype))
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------------------ forward


def _block(cfg: ModelConfig, lp: dict, x, kv_cache=None, cache_pos=None):
    h, kv = attention(
        lp, rmsnorm(x, lp["ln1"]),
        n_heads=cfg.n_heads, kv_heads=cfg.kv_heads, head_dim=cfg.hd,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        rope_fraction=cfg.rope_fraction, kv_cache=kv_cache, cache_pos=cache_pos,
    )
    x = hint(x + h, "residual")
    hin = rmsnorm(x, lp["ln2"])
    if "moe_w1" in lp:
        h = moe_ffn(lp, hin, cfg)
    else:
        h = mlp({k[4:]: v for k, v in lp.items() if k.startswith("mlp_")},
                hin, cfg.activation)
    return hint(x + h, "residual"), kv


def _run_stack(cfg, stack_params, x, collect_kv: bool):
    """scan over stacked layers; optionally collect per-layer KV for caching."""

    def step(carry, lp):
        y, kv = _block(cfg, lp, carry)
        return y, (kv if collect_kv else 0)

    x, kvs = scan_layers(step, x, stack_params)
    return x, kvs


def embed_tokens(cfg, params, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.kind in ("dense", "moe"):
        x = x * (cfg.d_model ** 0.5) if cfg.name.startswith("gemma") else x
    return x


def logits_fn(cfg, params, x):
    x = rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return (x @ head.astype(x.dtype)).astype(jnp.float32)


def forward(cfg: ModelConfig, params, tokens=None, embeddings=None):
    """Training/scoring forward -> logits (B, S, V)."""
    x = embeddings.astype(cfg.dtype) if embeddings is not None else embed_tokens(cfg, params, tokens)
    x = hint(x, "residual")
    if "dense_layers" in params:
        x, _ = _run_stack(cfg, params["dense_layers"], x, collect_kv=False)
    x, _ = _run_stack(cfg, params["layers"], x, collect_kv=False)
    return logits_fn(cfg, params, x)


def loss_fn(cfg: ModelConfig, params, batch):
    """batch: {'tokens' or 'embeddings', 'labels'} -> mean xent loss."""
    logits = forward(cfg, params,
                     tokens=batch.get("tokens"), embeddings=batch.get("embeddings"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ------------------------------------------------------------------ serving


def prefill(cfg: ModelConfig, params, tokens=None, embeddings=None, cache_len: int = 0):
    """Seed KV caches.  Returns (last-token logits, caches, positions)."""
    x = embeddings.astype(cfg.dtype) if embeddings is not None else embed_tokens(cfg, params, tokens)
    b, s = x.shape[0], x.shape[1]
    caches = {}
    if "dense_layers" in params:
        x, kv = _run_stack(cfg, params["dense_layers"], x, collect_kv=True)
        caches["dense_layers"] = _extend(kv, cache_len, s)
    x, kv = _run_stack(cfg, params["layers"], x, collect_kv=True)
    caches["layers"] = _extend(kv, cache_len, s)
    logits = logits_fn(cfg, params, x[:, -1:])
    pos = jnp.full((b,), s, jnp.int32)
    return logits[:, 0], caches, pos


def _extend(kv, cache_len: int, s: int):
    """Pad prefill KV (L, B, Hkv, S, Dh) out to the serving cache length."""
    k, v = kv
    if cache_len > s:
        pad = ((0, 0), (0, 0), (0, 0), (0, cache_len - s), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    return k, v


def decode_step(cfg: ModelConfig, params, token, caches, pos):
    """One decode step.  token: (B,) int32; returns (logits, caches, pos+1)."""
    x = embed_tokens(cfg, params, token[:, None])
    new_caches = {}

    def run(stack_params, cache, x):
        def step(carry, inp):
            lp, (ck, cv) = inp
            y, kv = _block(cfg, lp, carry, kv_cache=(ck, cv), cache_pos=pos)
            return y, kv

        x, kv = scan_layers(step, x, (stack_params, cache))
        return x, kv

    if "dense_layers" in params:
        x, kv = run(params["dense_layers"], caches["dense_layers"], x)
        new_caches["dense_layers"] = kv
    x, kv = run(params["layers"], caches["layers"], x)
    new_caches["layers"] = kv
    logits = logits_fn(cfg, params, x)
    return logits[:, 0], new_caches, pos + 1


def make_cache(cfg: ModelConfig, batch: int, cache_len: int, stacks=None):
    """Preallocated zero KV caches (used by decode-only dry-run shapes)."""
    out = {}
    n_dense = cfg.dense_layers if cfg.kind == "moe" else 0
    if n_dense:
        shape = (n_dense, batch, cfg.kv_heads, cache_len, cfg.hd)
        out["dense_layers"] = (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))
    n = cfg.n_layers - n_dense
    shape = (n, batch, cfg.kv_heads, cache_len, cfg.hd)
    out["layers"] = (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))
    return out
