"""Cost-benefit admission/eviction scoring for the tiered cache.

The currency (after Szépkúti, "Caching in Multidimensional Databases") is
*recompute cost x reuse probability / bytes*, not recency:

    score(e) = (cost_ms + floor) * (1 + decayed_hits(e)) / max(nbytes, 1)

* ``cost_ms`` is the stored execute-stage timing for the entry's query — what
  a miss would pay again (``floor`` keeps never-timed entries comparable);
* ``decayed_hits`` is the hit count decayed exponentially with idle time
  (half-life ``half_life_s``) — a frequency estimate that forgets, so a
  burst a week ago does not pin an entry forever;
* dividing by ``table_nbytes`` makes the score a per-byte benefit density:
  under a byte budget, evicting the lowest-density entry frees the most
  bytes per unit of future cost incurred.

Two policies share one duck-typed surface (``victim(entries, now)`` over the
cache's LRU-ordered hot dict, ``admit_cold(entry, now)`` for demote-vs-drop):

* :class:`LruPolicy` — the pre-PR 8 behavior, kept as the differential
  oracle (``policy="lru"``): victim = front of the OrderedDict, every victim
  admitted to the cold tier.
* :class:`CostPolicy` — scans only the ``sample`` oldest entries (the LRU
  prefix) and evicts the min-score one: scan-resistant (one-touch scans age
  to the front and score near zero) without ever evicting the hot MRU tail.

This module deliberately imports nothing from the rest of ``repro`` so
``core.cache`` can import it at module scope without a cycle; entries are
duck-typed (``hits``, ``cost_ms``, ``table_nbytes``, ``last_used_at``).
"""
from __future__ import annotations

import math
from typing import Optional

__all__ = ["decayed_hits", "cost_benefit_score", "LruPolicy", "CostPolicy",
           "make_policy", "DEFAULT_HALF_LIFE_S", "COST_FLOOR_MS",
           "DEFAULT_SAMPLE"]

DEFAULT_HALF_LIFE_S = 600.0
COST_FLOOR_MS = 0.05
DEFAULT_SAMPLE = 64


def decayed_hits(entry, now: float,
                 half_life_s: float = DEFAULT_HALF_LIFE_S) -> float:
    """Hit count decayed by idle time: ``hits * 2^(-idle / half_life)``."""
    hits = float(getattr(entry, "hits", 0))
    if hits <= 0.0:
        return 0.0
    last = getattr(entry, "last_used_at", None)
    if last is None or half_life_s <= 0.0:
        return hits
    idle = max(0.0, now - last)
    return hits * math.pow(2.0, -idle / half_life_s)


def cost_benefit_score(entry, now: float,
                       half_life_s: float = DEFAULT_HALF_LIFE_S) -> float:
    """Per-byte benefit density of keeping ``entry`` (higher = keep)."""
    cost = max(float(getattr(entry, "cost_ms", 0.0)), 0.0) + COST_FLOOR_MS
    benefit = cost * (1.0 + decayed_hits(entry, now, half_life_s))
    nbytes = max(int(getattr(entry, "table_nbytes", 0)), 1)
    return benefit / nbytes


class LruPolicy:
    """Plain LRU: the differential oracle (pre-PR 8 eviction order)."""

    name = "lru"

    def victim(self, entries, now: float) -> str:
        return next(iter(entries))

    def admit_cold(self, entry, now: float) -> bool:
        return True


class CostPolicy:
    """Cost-benefit eviction over a sample of the LRU-oldest entries."""

    name = "cost"

    def __init__(self, half_life_s: float = DEFAULT_HALF_LIFE_S,
                 sample: int = DEFAULT_SAMPLE,
                 demote_min_score: float = 0.0):
        self.half_life_s = half_life_s
        self.sample = max(1, sample)
        self.demote_min_score = demote_min_score

    def victim(self, entries, now: float) -> str:
        best_key = None
        best_score = math.inf
        for i, (key, e) in enumerate(entries.items()):
            if i >= self.sample:
                break
            s = cost_benefit_score(e, now, self.half_life_s)
            if s < best_score:
                best_score = s
                best_key = key
        return best_key

    def admit_cold(self, entry, now: float) -> bool:
        if self.demote_min_score <= 0.0:
            return True
        return (cost_benefit_score(entry, now, self.half_life_s)
                >= self.demote_min_score)


def make_policy(name: Optional[str], **kwargs):
    """``"lru"`` | ``"cost"`` -> policy instance (extra kwargs to CostPolicy)."""
    if name in (None, "lru"):
        return LruPolicy()
    if name == "cost":
        return CostPolicy(**kwargs)
    raise ValueError(f"unknown cache policy {name!r} (expected 'lru'|'cost')")
