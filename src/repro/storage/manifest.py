"""Crash-safe manifest: checkpoint + append-only CRC-framed WAL.

Layout inside a store directory::

    manifest.json   checkpoint — a JSON *array* of put-records, written
                    tmp+fsync+atomic-rename (the PR 3 ``save_cache`` format,
                    so pre-PR 8 spill directories replay unchanged)
    manifest.log    WAL — one JSON object per line, each carrying a CRC32 of
                    its own canonical serialization; appended + flushed +
                    fsync'd per record

Replay is checkpoint first, then the log in order.  Log records carry an
``op``:

* ``put``  — full record (payload file just renamed into place): replaces
  any prior record for the key.
* ``meta`` — metadata-only refresh (stamps / hit counts / snapshot): merged
  into the existing record; ignored if the key is unknown (the matching
  ``put`` may have been lost to a crash — a metadata orphan is not a hit).
* ``del``  — tombstone: removes the record.

A torn tail line (kill mid-append), a corrupted line (CRC mismatch), or an
unknown op is *skipped and counted*, never fatal: the manifest recovers the
longest consistent prefix.  Compaction folds the current record set into a
fresh checkpoint (atomic rename) and then truncates the log — a crash
between those two steps merely replays log records that are already in the
checkpoint, which is idempotent.

Thread-safety: none here.  All calls are serialized by the owning
:class:`repro.storage.engine.TieredStore` under its ``_lock`` (the class is
registered in the analysis annotations as externally synchronized).
"""
from __future__ import annotations

import errno
import json
import os
import zlib
from typing import Iterable, Optional

from ..resilience import faults

__all__ = ["DurableManifest", "CHECKPOINT_NAME", "LOG_NAME"]

CHECKPOINT_NAME = "manifest.json"
LOG_NAME = "manifest.log"

# record fields merged (not replaced) by a ``meta`` op
_META_FIELDS = ("hits", "refreshes", "lru_stamp", "store_stamp", "version",
                "snapshot_id", "cost_ms", "ttl_s", "origin")


def _crc_payload(rec: dict) -> str:
    body = json.dumps({k: v for k, v in rec.items() if k != "crc"},
                      sort_keys=True, separators=(",", ":"))
    return format(zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF, "08x")


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # platform without directory fds
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class DurableManifest:
    """Checkpoint + WAL over one store directory.  Not thread-safe by
    itself — see module docstring."""

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        self.checkpoint_path = os.path.join(path, CHECKPOINT_NAME)
        self.log_path = os.path.join(path, LOG_NAME)
        self._fh = None            # lazily opened append handle for the log
        self.log_records = 0       # records appended since last checkpoint
        self.torn_records = 0      # skipped lines over the store's lifetime

    # ------------------------------------------------------------- append
    def append(self, record: dict) -> None:
        """Durably append one log record (op defaults to ``put``).

        Chaos injection points (deterministic, via ``REPRO_FAULTS``):
        ``storage.wal_enospc`` / ``storage.wal_oserror`` raise before any
        byte lands (disk full / generic IO failure); ``storage.wal_torn``
        writes *half* a frame then raises — the kill-mid-append case replay
        must skip as a torn tail."""
        faults.fire_os("storage.wal_enospc", err_no=errno.ENOSPC)
        faults.fire_os("storage.wal_oserror")
        rec = dict(record)
        rec.setdefault("op", "put")
        rec["crc"] = _crc_payload(rec)
        line = json.dumps(rec, sort_keys=True, separators=(",", ":")) + "\n"
        if self._fh is None:
            self._fh = open(self.log_path, "a", encoding="utf-8")
        if faults.should_fire("storage.wal_torn"):
            self._fh.write(line[:max(len(line) // 2, 1)])
            self._fh.flush()
            if self.fsync:
                os.fsync(self._fh.fileno())
            raise OSError("injected fault: storage.wal_torn (half frame)")
        self._fh.write(line)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.log_records += 1

    # ------------------------------------------------------------- replay
    def replay(self) -> tuple[dict, dict]:
        """Rebuild ``{key: record}`` from checkpoint + log.

        Returns ``(records, report)`` where ``report`` counts what was seen
        and what was skipped (torn/CRC-failed lines, orphan meta records).
        """
        records: dict[str, dict] = {}
        report = {"checkpoint_records": 0, "log_records": 0,
                  "torn_records": 0, "orphan_meta": 0, "tombstones": 0}
        if os.path.exists(self.checkpoint_path):
            try:
                with open(self.checkpoint_path, "r", encoding="utf-8") as f:
                    base = json.load(f)
            except (OSError, ValueError):
                base = []
                report["torn_records"] += 1
            if isinstance(base, list):
                for rec in base:
                    if isinstance(rec, dict) and rec.get("key"):
                        rec.pop("op", None)
                        rec.pop("crc", None)
                        records[rec["key"]] = rec
                        report["checkpoint_records"] += 1
        applied = 0
        if os.path.exists(self.log_path):
            with open(self.log_path, "rb") as f:
                raw = f.read()
            for line in raw.split(b"\n"):
                if not line.strip():
                    continue
                try:
                    rec = json.loads(line.decode("utf-8"))
                except (ValueError, UnicodeDecodeError):
                    report["torn_records"] += 1
                    continue
                if not isinstance(rec, dict) or "crc" not in rec \
                        or rec["crc"] != _crc_payload(rec):
                    report["torn_records"] += 1
                    continue
                key = rec.get("key")
                op = rec.pop("op", "put")
                rec.pop("crc", None)
                if not key:
                    report["torn_records"] += 1
                    continue
                applied += 1
                if op == "del":
                    records.pop(key, None)
                    report["tombstones"] += 1
                elif op == "meta":
                    cur = records.get(key)
                    if cur is None:
                        report["orphan_meta"] += 1
                    else:
                        for f_ in _META_FIELDS:
                            if f_ in rec:
                                cur[f_] = rec[f_]
                elif op == "put":
                    records[key] = rec
                else:
                    report["torn_records"] += 1
        report["log_records"] = applied
        self.log_records = applied
        self.torn_records += report["torn_records"]
        return records, report

    # --------------------------------------------------------- checkpoint
    def checkpoint(self, records: Iterable[dict]) -> int:
        """Fold ``records`` into a fresh checkpoint (atomic rename), then
        truncate the log.  Crash between the two steps is idempotent on
        replay.  Returns the number of records written."""
        out = []
        for rec in records:
            rec = {k: v for k, v in rec.items()
                   if not k.startswith("_") and k not in ("op", "crc")}
            out.append(rec)
        tmp = self.checkpoint_path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(out, f, indent=1, sort_keys=True)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, self.checkpoint_path)
        if self.fsync:
            _fsync_dir(self.path)
        # now the log is redundant: truncate it
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        with open(self.log_path, "w", encoding="utf-8") as f:
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        self.log_records = 0
        return len(out)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
