"""Tiered durable cache storage (PR 8).

A hot (RAM) / cold (disk) hierarchy behind :class:`repro.core.cache.SemanticCache`:

* :mod:`repro.storage.manifest` — crash-safe record log: an atomic-rename
  checkpoint (``manifest.json``, the PR 3 format) plus an fsync'd append-only
  CRC-framed WAL (``manifest.log``) that is replayable after a kill at any
  byte offset.
* :mod:`repro.storage.coldstore` — the cold tier proper: per-entry ``.npz``
  payloads written tmp+fsync+rename with sha256/size framing, orphan cleanup
  on replay.
* :mod:`repro.storage.policy` — cost-benefit admission/eviction scoring
  (recompute-cost x decayed hit-count / bytes) with plain LRU kept as the
  differential oracle.
* :mod:`repro.storage.engine` — :class:`TieredStore`, the write-behind spill
  engine (async worker thread, locks via the PR 7 sanitizer factory).

This ``__init__`` stays import-light: ``repro.core.cache`` imports
``repro.storage.policy`` at module scope, and the engine imports
``repro.core.cache`` — the package root must not force the cycle.
"""
from __future__ import annotations

__all__ = ["TieredStore", "ColdTier", "DurableManifest",
           "LruPolicy", "CostPolicy", "make_policy",
           "decayed_hits", "cost_benefit_score"]


def __getattr__(name):  # lazy: avoid core.cache <-> storage import cycle
    if name == "TieredStore":
        from .engine import TieredStore
        return TieredStore
    if name in ("ColdTier",):
        from .coldstore import ColdTier
        return ColdTier
    if name in ("DurableManifest",):
        from .manifest import DurableManifest
        return DurableManifest
    if name in ("LruPolicy", "CostPolicy", "make_policy", "decayed_hits",
                "cost_benefit_score"):
        from . import policy as _p
        return getattr(_p, name)
    raise AttributeError(f"module 'repro.storage' has no attribute {name!r}")
