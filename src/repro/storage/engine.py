"""TieredStore: the write-behind cold-tier engine.

One instance owns one spill directory (a :class:`ColdTier`) and offers the
cache three durability verbs:

* ``spill(key, entry, table)`` — schedule a durable write of this entry
  *version*.  Asynchronous by default: the job is parked in a per-key
  pending map and a FIFO worker thread performs the ``.npz`` write outside
  the engine lock, finalizing (rename already done by the tier; manifest
  append + pending release) back under it.  A newer spill or a delete simply
  replaces/removes the pending claim — the worker detects the stale claim at
  finalize time and drops its work, so same-key writes can never finish out
  of order.  If the durable record already matches the entry's ``version``
  (and snapshot), only a cheap metadata log record is appended — this is
  what makes ``save_cache`` incremental.
* ``peek(key)`` / ``promote(key)`` — read a table back: pending claim first
  (the write may not have landed yet), then disk with sha verification.  A
  damaged payload reads as ``None`` (miss), never a false hit.  Promotion
  leaves the durable record in place: the cold copy stays a *clean* replica
  until the entry is rewritten or dropped.
* ``delete`` / ``purge`` — tombstone records and cancel pending claims, so
  dropped entries can never resurrect on replay.

``open()`` replays the manifest into table-less :class:`CacheEntry` metas
(signature-validated) and advances the process-wide recency clock past every
persisted stamp, so warm-restart stamps keep increasing.  ``flush()`` polls
the pending map empty (declared via ``note_blocking`` — callers must hold no
sanitized lock).  ``close()`` flushes, stops the worker, and compacts the
manifest.

Write-behind staleness window: between a hot mutation and the worker's
finalize, the durable copy is one version behind; a kill in that window
recovers the *previous* version of that entry (or none), never a torn or
mixed one.

Locking: ``TieredStore._lock`` (via PR 7's :func:`make_lock`) is a leaf —
acquired under ``CacheShard.lock`` on the request path, held across no
blocking call and no payload IO.  :class:`ColdTier`/:class:`DurableManifest`
have no locks of their own; every call into them is made under this lock
(except ``write_payload``, which targets a unique tmp name — see
``coldstore``).
"""
from __future__ import annotations

import os
import queue
import threading
import time
from typing import Optional

from ..analysis.sanitizer import make_lock, note_blocking
from ..core.cache import CacheEntry, advance_stamp
from ..core.table import ResultTable
from ..obs.trace import adopt, child_span, current_ctx
from ..resilience import faults
from ..resilience.primitives import CircuitBreaker, backoff_delays
from .coldstore import ColdTier

__all__ = ["TieredStore", "entry_meta"]

_STOP = object()


def entry_meta(entry: CacheEntry) -> dict:
    """The manifest-record metadata for one cache entry (everything but the
    payload fields, which come from the tier's payload writer)."""
    return {
        "signature": entry.signature.to_json(),
        "origin": entry.origin,
        "snapshot_id": entry.snapshot_id,
        "hits": entry.hits,
        "refreshes": entry.refreshes,
        "lru_stamp": entry.lru_stamp,
        "store_stamp": entry.store_stamp,
        "version": entry.version,
        "cost_ms": entry.cost_ms,
        "ttl_s": entry.ttl_s,
    }


def _entry_from_record(rec: dict, now: float) -> CacheEntry:
    """A table-less (cold) CacheEntry rebuilt from a manifest record.  The
    persisted stamps ride in through the constructor, so LRU order and probe
    MRU order reconstruct deterministically on warm restart."""
    return CacheEntry(
        signature=rec["_sig"],
        table=None,
        origin=rec.get("origin", "sql"),
        snapshot_id=rec.get("snapshot_id", "snap0"),
        stored_at=now,
        hits=int(rec.get("hits", 0)),
        refreshes=int(rec.get("refreshes", 0)),
        table_nbytes=int(rec.get("nbytes", 0)),
        lru_stamp=int(rec.get("lru_stamp", 0)),
        store_stamp=int(rec.get("store_stamp", 0)),
        version=int(rec.get("version", 0)),
        cost_ms=float(rec.get("cost_ms", 0.0)),
        ttl_s=rec.get("ttl_s"),
        last_used_at=now,
    )


class _Spill:
    """One pending write-behind job: the claim for a key's next durable
    state.  Identity (``cur is job``) is the cancellation token."""

    __slots__ = ("entry", "table", "meta", "ctx")

    def __init__(self, entry: CacheEntry, table: ResultTable, meta: dict,
                 ctx=None):
        self.entry = entry
        self.table = table
        self.meta = meta
        # the scheduling thread's trace context: the worker adopts it so the
        # write-behind span lands under the originating request's trace even
        # though it finishes after the response went out
        self.ctx = ctx


class TieredStore:
    """Write-behind durable cold tier over one spill directory."""

    def __init__(self, path: str, *, fsync: bool = True,
                 async_spill: bool = True):
        self.path = os.path.abspath(path)
        self.async_spill = async_spill
        self._lock = make_lock("TieredStore._lock")
        self._tier = ColdTier(self.path, fsync=fsync)  # guarded-by: self._lock
        self._pending: dict[str, _Spill] = {}  # guarded-by: self._lock
        self._queue: "queue.Queue" = queue.Queue()  # own internal lock
        self._worker: Optional[threading.Thread] = None  # guarded-by: self._lock
        self._closed = False  # guarded-by: self._lock
        self.spilled_writes = 0  # guarded-by: self._lock
        self.spill_meta_only = 0  # guarded-by: self._lock
        self.spill_superseded = 0  # guarded-by: self._lock
        self.spill_errors = 0  # guarded-by: self._lock
        self.payload_corrupt = 0  # guarded-by: self._lock
        self.deletes = 0  # guarded-by: self._lock
        # resilience: spill/read retry budgets (set before traffic; read-only
        # after), error surfacing, and the cold tier's availability breaker
        self.spill_attempts = 3
        self.read_attempts = 3
        self.spill_retries = 0  # guarded-by: self._lock
        self.spill_last_error: Optional[str] = None  # guarded-by: self._lock
        self.worker_deaths = 0  # guarded-by: self._lock
        self.wal_append_errors = 0  # guarded-by: self._lock
        self.read_errors = 0  # guarded-by: self._lock
        # leaf lock of its own: safe to consult under self._lock, never the
        # other way round
        self.cold_breaker = CircuitBreaker("cold_tier", recovery_s=0.25)

    # -------------------------------------------------------------- open
    def open(self) -> list[CacheEntry]:
        """Replay the manifest; return cold entry metas (table=None) for the
        cache to adopt.  Advances the global recency clock past every
        persisted stamp so new stamps stay strictly above restored ones."""
        now = time.monotonic()
        with self._lock:
            records = self._tier.open()
            entries = [_entry_from_record(rec, now) for rec in records.values()]
        max_stamp = max((max(e.lru_stamp, e.store_stamp) for e in entries),
                        default=0)
        advance_stamp(max_stamp)
        return entries

    @property
    def replay_report(self) -> dict:
        return dict(self._tier.replay_report)

    # ------------------------------------------------------------- spill
    def spill(self, key: str, entry: CacheEntry, table: ResultTable) -> None:
        """Schedule (async) or perform (sync) a durable write of this entry
        version.  Clean records (same version + snapshot, payload intact)
        only get a metadata log record — the incremental-save fast path."""
        meta = entry_meta(entry)
        with self._lock:
            if self._closed:
                return
            rec = self._tier.record(key)
            if (rec is not None and rec.get("sha")
                    and rec.get("version") == entry.version
                    and rec.get("snapshot_id") == entry.snapshot_id
                    and key not in self._pending):
                try:
                    self._tier.meta_record(key, meta)
                    self.spill_meta_only += 1
                    return
                except Exception:  # noqa: BLE001 — WAL append failed (disk
                    # full, injected IO fault): fall through to a full
                    # pending job so the payload path's retry machinery owns
                    # this version's durability instead of silently losing it
                    self.wal_append_errors += 1
            job = _Spill(entry, table, meta, ctx=current_ctx())
            self._pending[key] = job
            if self.async_spill:
                self._queue.put(key)
                self._ensure_worker()
                return
        self._write_job_with_retry(key, job)

    def _ensure_worker(self) -> None:  # requires-lock: self._lock
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._spill_loop, name="tiered-spill", daemon=True)
            self._worker.start()

    def _spill_loop(self) -> None:
        while True:
            key = self._queue.get()
            if key is _STOP:
                return
            if faults.should_fire("storage.spill_death"):
                # chaos: the worker thread dies mid-shift.  The claim stays
                # pending and the key is requeued, so the replacement worker
                # (restarted by the next spill()/flush()) picks it up — a
                # worker death costs latency, never a lost write
                self._queue.put(key)
                with self._lock:
                    self.worker_deaths += 1
                return
            with self._lock:
                job = self._pending.get(key)
            if job is None:
                continue  # cancelled (delete/purge) before we got to it
            self._write_job_with_retry(key, job)

    def _write_job_with_retry(self, key: str, job: _Spill) -> bool:
        """Attempt the durable write up to ``spill_attempts`` times with
        deterministic backoff, abandoning early when the claim is superseded
        or cancelled.  Only after the budget is spent does the claim drop —
        with the error surfaced in ``spill_errors`` / ``spill_last_error``,
        never swallowed.  Returns True on a landed write."""
        sattrs = {"key": key, "version": job.entry.version}
        with adopt(job.ctx), child_span("store.spill", attrs=sattrs):
            ok = self._attempt_write(key, job, sattrs)
            sattrs["ok"] = ok
            return ok

    def _attempt_write(self, key: str, job: _Spill, sattrs: dict) -> bool:
        attempts = max(self.spill_attempts, 1)
        delays = backoff_delays(attempts, 0.002, 0.05, salt=key)
        err: Optional[BaseException] = None
        for attempt in range(attempts):
            try:
                faults.fire_os("storage.spill_error")
                self._write_job(key, job)
                return True
            except Exception as e:  # noqa: BLE001 — retried IO boundary
                err = e
                with self._lock:
                    if self._pending.get(key) is not job:
                        # a newer spill or a delete owns the key now; its
                        # write (or tombstone) supersedes this one
                        self.spill_superseded += 1
                        return False
                    if attempt + 1 < attempts:
                        self.spill_retries += 1
                        sattrs["retries"] = sattrs.get("retries", 0) + 1
                if attempt + 1 < attempts:
                    time.sleep(delays[attempt])
        with self._lock:
            self.spill_errors += 1
            self.spill_last_error = f"{type(err).__name__}: {err}"
            sattrs["error"] = self.spill_last_error
            if self._pending.get(key) is job:
                del self._pending[key]
        return False

    def _write_job(self, key: str, job: _Spill) -> None:
        """Payload IO outside the lock; finalize under it.  The claim check
        (``cur is job``) makes stale writes drop out instead of clobbering a
        newer durable state."""
        payload = self._tier.write_payload(key, job.table)
        with self._lock:
            cur = self._pending.get(key)
            if cur is not job:
                # superseded (newer spill owns the claim now) or cancelled
                # (deleted): the newer job rewrites the payload file, or the
                # delete already tombstoned the record — either way this
                # write must not publish a manifest record
                self.spill_superseded += 1
                return
            self._tier.put_record(key, job.meta, payload)
            del self._pending[key]
            self.spilled_writes += 1
            self._tier.maybe_compact()

    # -------------------------------------------------------------- read
    def peek(self, key: str) -> Optional[ResultTable]:
        """Read a table back without consuming the record: pending claim
        first (freshest state), then disk with sha verification.  An
        unreadable, unavailable, or damaged payload is a miss — never a
        false hit, never an exception."""
        try:
            return self._read_payload(key)
        except OSError:
            return None

    def promote(self, key: str) -> Optional[ResultTable]:
        """Like :meth:`peek`, but distinguishes *transient* unavailability
        (IO errors exhausted the retry budget, or the cold breaker is open —
        raises ``OSError``) from *damage* (sha mismatch — returns ``None``),
        so the cache keeps the cold entry across an outage instead of
        dropping a clean durable replica."""
        return self._read_payload(key)

    def _read_payload(self, key: str) -> Optional[ResultTable]:
        """Shared read path: pending claim first (freshest state), then disk
        behind the cold tier's circuit breaker with a bounded micro-retry
        (reads can execute under a shard lock, so the worst-case added hold
        time stays a few milliseconds).  Returns the table, ``None`` for a
        missing/damaged payload, raises ``OSError`` when the tier is
        transiently unavailable."""
        with self._lock:
            job = self._pending.get(key)
            if job is not None:
                return job.table
            rec = self._tier.record(key)
        if rec is None:
            return None
        if not self.cold_breaker.allow():
            # fail fast while the cold tier is unavailable
            raise OSError("cold tier circuit breaker open")
        attempts = max(self.read_attempts, 1)
        delays = backoff_delays(attempts, 0.001, 0.004, salt=key)
        for attempt in range(attempts):
            try:
                faults.fire_os("coldtier.read_error")
                table = self._tier.read_payload(rec)
            except OSError:
                with self._lock:
                    self.read_errors += 1
                if attempt + 1 < attempts:
                    time.sleep(delays[attempt])
                continue
            self.cold_breaker.record_success()
            if table is None:
                with self._lock:
                    self.payload_corrupt += 1
            return table
        self.cold_breaker.record_failure()
        raise OSError(f"cold read failed after {attempts} attempts")

    def has(self, key: str) -> bool:
        with self._lock:
            return key in self._pending or self._tier.record(key) is not None

    def record_version(self, key: str) -> Optional[int]:
        with self._lock:
            rec = self._tier.record(key)
            return None if rec is None else rec.get("version")

    def keys(self) -> list:
        with self._lock:
            ks = set(self._tier.keys())
            ks.update(self._pending.keys())
            return sorted(ks)

    # ------------------------------------------------------------ delete
    def delete(self, key: str) -> None:
        """Tombstone + cancel any pending claim: the key can never
        resurrect on replay."""
        with self._lock:
            self._pending.pop(key, None)
            if self._tier.delete(key):
                self.deletes += 1

    def purge(self) -> int:
        with self._lock:
            self._pending.clear()
            return self._tier.purge()

    # --------------------------------------------------------- lifecycle
    def flush(self, timeout: float = 30.0) -> bool:
        """Wait (poll) until no spill is pending.  Callers must hold no
        sanitized lock — this blocks on the worker's progress."""
        note_blocking("TieredStore.flush")
        deadline = time.monotonic() + timeout
        while True:
            with self._lock:
                busy = bool(self._pending)
                if busy and self.async_spill and not self._closed and (
                        self._worker is None or not self._worker.is_alive()):
                    # the worker died (crash or injected storage.spill_death)
                    # with claims outstanding: requeue them (duplicates are
                    # harmless — the loop re-checks each claim) and restart
                    # it, so a dead worker can never wedge flush()
                    for k in self._pending:
                        self._queue.put(k)
                    self._ensure_worker()
            if not busy:
                return True
            if time.monotonic() > deadline:
                return False
            time.sleep(0.002)

    def compact(self) -> int:
        with self._lock:
            return self._tier.compact()

    def close(self, compact: bool = True) -> None:
        self.flush()
        with self._lock:
            worker = self._worker
            self._worker = None
            self._closed = True
        if worker is not None and worker.is_alive():
            self._queue.put(_STOP)
            worker.join(timeout=10.0)
        with self._lock:
            if compact:
                self._tier.compact()
            self._tier.close()

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            return {
                "path": self.path,
                "records": len(self._tier.keys()),
                "disk_bytes": self._tier.disk_bytes(),
                "spill_queue_depth": len(self._pending),
                "spilled_writes": self.spilled_writes,
                "spill_meta_only": self.spill_meta_only,
                "spill_superseded": self.spill_superseded,
                "spill_errors": self.spill_errors,
                "spill_retries": self.spill_retries,
                "spill_last_error": self.spill_last_error,
                "worker_deaths": self.worker_deaths,
                "wal_append_errors": self.wal_append_errors,
                "read_errors": self.read_errors,
                "payload_corrupt": self.payload_corrupt,
                "deletes": self.deletes,
                "log_records": self._tier.manifest.log_records,
                "torn_records": self._tier.manifest.torn_records,
                "cold_breaker": self.cold_breaker.snapshot(),
            }
