"""Cold tier: per-entry ``.npz`` payloads + the durable manifest.

Payload protocol (unchanged file naming from the PR 3 spill format, so old
spill directories replay):

* one ``entry_<key[:24]>.npz`` per record, serialized in memory first, then
  written to a unique ``.tmp`` sibling, flushed + fsync'd, and atomically
  renamed into place;
* the manifest record carries ``sha`` (sha256 of the npz bytes), ``file_bytes``
  (size framing, checked at replay) and ``columns`` (order restoration) —
  records written by the pre-PR 8 format lack these and are trusted like the
  old loader trusted them;
* a read re-verifies ``sha`` before deserializing: a torn or tampered payload
  is a *miss*, never a false hit.

Replay validates each record's embedded signature against its key
(``sig.key() == key`` — the same tamper/versioning guard ``load_cache``
always had) and deletes orphans: ``entry_*.npz`` files no manifest record
references, and leftover ``*.tmp`` from a mid-write kill.

Thread-safety: none here; every call is serialized under the owning
:class:`~repro.storage.engine.TieredStore`'s ``_lock`` or happens on the
single spill worker via the engine's pending-claim protocol (payload writes
target unique tmp names, and renames are finalized under the engine lock).
"""
from __future__ import annotations

import hashlib
import io
import itertools
import os
from typing import Optional

import numpy as np

from ..core.signature import signature_from_json
from ..core.table import ResultTable
from ..resilience import faults
from .manifest import DurableManifest

__all__ = ["ColdTier", "payload_name"]

PAYLOAD_PREFIX = "entry_"
PAYLOAD_SUFFIX = ".npz"

_TMP_SEQ = itertools.count(1)


def payload_name(key: str) -> str:
    """Stable payload filename for a cache key (legacy-compatible)."""
    return f"{PAYLOAD_PREFIX}{key[:24]}{PAYLOAD_SUFFIX}"


def _serialize_table(table: ResultTable) -> tuple[bytes, list]:
    buf = io.BytesIO()
    np.savez(buf, **{c: np.asarray(v) for c, v in table.columns.items()})
    return buf.getvalue(), list(table.columns.keys())


class ColdTier:
    """Disk records + payloads for one store directory."""

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = os.path.abspath(path)
        os.makedirs(self.path, exist_ok=True)
        self.fsync = fsync
        self.manifest = DurableManifest(self.path, fsync=fsync)
        # key -> record dict; parsed Signature cached under "_sig".
        # Serialized externally by the owning TieredStore._lock.
        self._records: dict[str, dict] = {}
        self.replay_report: dict = {}

    # -------------------------------------------------------------- open
    def open(self) -> dict[str, dict]:
        """Replay the manifest, validate records, clean orphans.  Returns the
        surviving ``{key: record}`` map (also kept as ``self._records``)."""
        raw, report = self.manifest.replay()
        report["invalid_records"] = 0
        report["missing_payloads"] = 0
        report["orphan_files"] = 0
        keep: dict[str, dict] = {}
        for key, rec in raw.items():
            sig_json = rec.get("signature")
            if not isinstance(sig_json, dict):
                report["invalid_records"] += 1
                continue
            try:
                sig = signature_from_json(sig_json)
            except Exception:
                report["invalid_records"] += 1
                continue
            if sig.key() != key:
                report["invalid_records"] += 1
                continue
            fname = rec.get("file")
            fpath = os.path.join(self.path, fname) if fname else None
            if not fname or not os.path.exists(fpath):
                report["missing_payloads"] += 1
                continue
            if "file_bytes" in rec and os.path.getsize(fpath) != rec["file_bytes"]:
                # torn payload that was renamed anyway (should not happen
                # with tmp+rename, but tolerate a damaged store)
                report["missing_payloads"] += 1
                continue
            rec["_sig"] = sig
            keep[key] = rec
        referenced = {rec["file"] for rec in keep.values()}
        for fname in os.listdir(self.path):
            fpath = os.path.join(self.path, fname)
            stale_payload = (fname.startswith(PAYLOAD_PREFIX)
                             and fname.endswith(PAYLOAD_SUFFIX)
                             and fname not in referenced)
            torn_tmp = fname.endswith(".tmp")
            if stale_payload or torn_tmp:
                try:
                    os.unlink(fpath)
                    report["orphan_files"] += 1
                except OSError:
                    pass
        self._records = keep
        self.replay_report = report
        return keep

    # ---------------------------------------------------------- payloads
    def write_payload(self, key: str, table: ResultTable) -> dict:
        """Write the payload file (tmp+fsync+atomic rename).  Returns the
        payload fields for the manifest record.  Safe to call without the
        engine lock: the tmp name is unique per call and the rename replaces
        the whole file atomically."""
        data, columns = _serialize_table(table)
        fname = payload_name(key)
        tmp = os.path.join(self.path, f"{fname}.{next(_TMP_SEQ)}.{os.getpid()}.tmp")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.path, fname))
        return {
            "file": fname,
            "file_bytes": len(data),
            "sha": hashlib.sha256(data).hexdigest(),
            "columns": columns,
            "nbytes": int(table.nbytes()),
        }

    def read_payload(self, rec: dict) -> Optional[ResultTable]:
        """Load and verify a record's payload.  ``None`` on any damage —
        a cold read never produces a false hit."""
        fname = rec.get("file")
        if not fname:
            return None
        fpath = os.path.join(self.path, fname)
        try:
            with open(fpath, "rb") as f:
                data = f.read()
        except OSError:
            return None
        if faults.should_fire("storage.sha_corrupt"):
            # chaos: bit-rot between write and read — the sha check below
            # must turn this into a miss, never a served wrong table
            data = data[:-1] + bytes([data[-1] ^ 0xFF]) if data else b"\x00"
        sha = rec.get("sha")
        if sha is not None and hashlib.sha256(data).hexdigest() != sha:
            return None
        try:
            with np.load(io.BytesIO(data)) as z:
                loaded = {c: np.array(z[c]) for c in z.files}
        except Exception:
            return None
        order = rec.get("columns") or list(loaded.keys())
        if any(c not in loaded for c in order):
            return None
        return ResultTable(columns={c: loaded[c] for c in order})

    # ----------------------------------------------------------- records
    def record(self, key: str) -> Optional[dict]:
        return self._records.get(key)

    def keys(self) -> list:
        return list(self._records.keys())

    def put_record(self, key: str, meta: dict, payload: dict) -> None:
        rec = {"key": key, **meta, **payload}
        self.manifest.append({**rec, "op": "put"})
        sig = rec.get("signature")
        self._records[key] = rec
        if isinstance(sig, dict) and "_sig" not in rec:
            try:
                rec["_sig"] = signature_from_json(sig)
            except Exception:
                pass

    def meta_record(self, key: str, meta: dict) -> None:
        cur = self._records.get(key)
        if cur is None:
            return
        fields = {k: meta[k] for k in
                  ("hits", "refreshes", "lru_stamp", "store_stamp", "version",
                   "snapshot_id", "cost_ms", "ttl_s", "origin") if k in meta}
        self.manifest.append({"key": key, "op": "meta", **fields})
        cur.update(fields)

    def delete(self, key: str) -> bool:
        rec = self._records.pop(key, None)
        if rec is None:
            return False
        self.manifest.append({"key": key, "op": "del"})
        fname = rec.get("file")
        if fname:
            try:
                os.unlink(os.path.join(self.path, fname))
            except OSError:
                pass
        return True

    def purge(self) -> int:
        n = 0
        for key in list(self._records.keys()):
            if self.delete(key):
                n += 1
        self.compact()
        return n

    # -------------------------------------------------------- compaction
    def compact(self) -> int:
        return self.manifest.checkpoint(self._records.values())

    def maybe_compact(self) -> None:
        if self.manifest.log_records > max(64, 4 * len(self._records)):
            self.compact()

    def disk_bytes(self) -> int:
        return sum(int(rec.get("file_bytes", rec.get("nbytes", 0)))
                   for rec in self._records.values())

    def close(self) -> None:
        self.manifest.close()
