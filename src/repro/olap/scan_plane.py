"""Partition-parallel scan plane: planning and merge algebra glue.

The cache only pays off when misses are survivable — at the paper's 82% hit
rate, one in five dashboard tiles still runs a full fact-table scan.  This
module holds the *pure* half of the partition-parallel miss path that
:class:`repro.olap.executor.OlapExecutor` drives:

* **Chunk planning** — :func:`plan_scan` splits the fact row space into
  ``partitions`` contiguous row-range partitions (scanned concurrently by
  per-partition sub-executors, pinned to distinct JAX devices when the host
  exposes several).  A ``max_device_rows`` budget further splits each
  partition into *streaming chunks* scanned sequentially with double-buffered
  uploads; chunk sizes are powers of two so every interior chunk of every
  partition reuses the same jitted kernel shapes.

* **Signature decomposition** — :func:`decompose` rewrites a signature into
  its partition-*composable* form: SUM/COUNT/MIN/MAX pass through, AVG is
  decomposed into SUM + COUNT(*) partials (finalized as SUM/COUNT after the
  merge, exactly how the executor itself finalizes AVG from its fused count
  column), and post-aggregation (HAVING / ORDER BY / LIMIT) is stripped from
  the partial signature and re-applied to the merged table.  COUNT DISTINCT
  does not decompose — :func:`partition_compatible` gates it back to
  single-partition execution.

* **Finalization** — :func:`finalize_partials` maps the merged partial table
  (produced by :func:`repro.core.refresh.merge_partials`, the k-way
  generalization of the incremental-refresh merge algebra) back to the
  original signature's ``m0..mK`` measure columns.

The correctness contract mirrors PR 3's refresh merge: grouped aggregation
over a disjoint row union decomposes per group, so the merged table equals
the unpartitioned fused scan — ``partitions=1`` is kept as the differential
oracle by the executor and the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.signature import Measure, Signature
from ..core.table import ResultTable

# Aggregations the scan plane can split across row partitions.  AVG rides on
# the SUM/COUNT decomposition; COUNT DISTINCT genuinely does not compose
# (distinct sets don't add) and falls back to a single-partition scan.
PARTITIONABLE_AGGS = ("SUM", "COUNT", "MIN", "MAX", "AVG")


# ------------------------------------------------------------- chunk planning


@dataclasses.dataclass(frozen=True)
class ScanPlan:
    """Row-range layout of one partition-parallel scan.

    ``chunks[p]`` is partition ``p``'s ordered list of ``[start, end)`` fact
    row ranges.  Partitions are scanned concurrently; the chunks *within* a
    partition are scanned sequentially (the streaming mode), chunk ``k+1``'s
    columns staged while chunk ``k`` scans.
    """

    n_rows: int
    chunks: tuple[tuple[tuple[int, int], ...], ...]

    @property
    def num_partitions(self) -> int:
        return len(self.chunks)

    @property
    def num_chunks(self) -> int:
        return sum(len(c) for c in self.chunks)

    @property
    def streaming(self) -> bool:
        return any(len(c) > 1 for c in self.chunks)


def _pow2_floor(x: int) -> int:
    return 1 << (int(x).bit_length() - 1) if x >= 1 else 0


def plan_scan(n_rows: int, partitions: int,
              max_device_rows: Optional[int] = None) -> ScanPlan:
    """Split ``[0, n_rows)`` into a :class:`ScanPlan`.

    Partitions are contiguous equal-size row ranges (the last takes the
    remainder; empty trailing partitions are dropped) so same-size partitions
    share jitted kernel shapes.  When a partition exceeds ``max_device_rows``
    it is further cut into power-of-two-sized streaming chunks — every
    interior chunk of every partition then has the *same* row count, so one
    compile serves the whole streamed scan.  Chunks are disjoint and exactly
    cover the row space: ``sum(chunk rows) == n_rows`` (the
    no-double-count-at-chunk-boundaries accounting invariant).
    """
    if partitions < 1:
        raise ValueError(f"partitions must be >= 1, got {partitions}")
    if max_device_rows is not None and max_device_rows < 1:
        raise ValueError(f"max_device_rows must be >= 1, got {max_device_rows}")
    if n_rows <= 0:
        return ScanPlan(n_rows, (((0, n_rows),),) if n_rows == 0 else ())
    q = -(-n_rows // partitions)  # ceil: first partitions equal, last smaller
    ranges = [(s, min(s + q, n_rows)) for s in range(0, n_rows, q)]
    chunk = None
    if max_device_rows is not None and q > max_device_rows:
        chunk = _pow2_floor(max_device_rows)
    parts = []
    for s, e in ranges:
        if chunk is None:
            parts.append(((s, e),))
        else:
            parts.append(tuple((c, min(c + chunk, e))
                               for c in range(s, e, chunk)))
    return ScanPlan(n_rows, tuple(parts))


# ----------------------------------------------------- signature decomposition


def partition_compatible(sig: Signature) -> bool:
    """True when the signature's measures can be computed per row partition
    and merged (HAVING / ORDER BY / LIMIT are fine — they are stripped from
    the partials and applied to the merged table).  COUNT DISTINCT is the one
    aggregate that cannot be split."""
    return all(m.agg in PARTITIONABLE_AGGS and not m.distinct
               for m in sig.measures)


@dataclasses.dataclass(frozen=True)
class PartialPlan:
    """Composable rewrite of one signature for partition-parallel execution.

    ``partial_sig`` carries only SUM/COUNT/MIN/MAX measures and no
    post-aggregation; ``finalize`` maps each *original* measure back to the
    merged partial columns: ``('direct', j)`` reads partial column ``mj``,
    ``('avg', sum_j, count_j)`` divides merged SUM by merged COUNT(*).
    """

    partial_sig: Signature
    finalize: tuple[tuple, ...]


def decompose(sig: Signature) -> PartialPlan:
    if not partition_compatible(sig):
        raise ValueError(
            f"signature is not partitionable (COUNT DISTINCT present): "
            f"{sig.canonical_json()}")
    partial: list[Measure] = []
    index: dict[Measure, int] = {}

    def add(m: Measure) -> int:
        j = index.get(m)
        if j is None:
            j = index[m] = len(partial)
            partial.append(m)
        return j

    finalize: list[tuple] = []
    for m in sig.measures:
        if m.agg == "AVG":
            # the executor finalizes AVG as fused-SUM / COUNT(*); decompose
            # identically so the merged result matches it bit-for-bit
            finalize.append(("avg", add(Measure("SUM", m.expr)),
                             add(Measure("COUNT", "*"))))
        else:
            finalize.append(("direct", add(m)))
    return PartialPlan(
        sig.replace(measures=tuple(partial), having=(), order_by=(),
                    limit=None),
        tuple(finalize))


def finalize_partials(sig: Signature, plan: PartialPlan,
                      merged: ResultTable) -> ResultTable:
    """Assemble the original signature's measure columns from the merged
    partial table (post-aggregation is the caller's tail, exactly as on the
    unpartitioned path)."""
    cols: dict[str, np.ndarray] = {lv: merged.columns[lv] for lv in sig.levels}
    for i, spec in enumerate(plan.finalize):
        if spec[0] == "direct":
            cols[f"m{i}"] = np.asarray(merged.columns[f"m{spec[1]}"],
                                       np.float64)
        else:  # ('avg', sum_j, count_j)
            s = np.asarray(merged.columns[f"m{spec[1]}"], np.float64)
            c = np.asarray(merged.columns[f"m{spec[2]}"], np.float64)
            with np.errstate(invalid="ignore", divide="ignore"):
                cols[f"m{i}"] = np.where(c > 0, s / c, np.nan)
    return ResultTable(cols)


def merge_and_finalize(sig: Signature, plan: PartialPlan,
                       partials: Sequence[ResultTable]) -> ResultTable:
    """Merge per-chunk partial tables and finalize (one factorization pass,
    fold-order independent group space).  Post-aggregation still pending."""
    from ..core.refresh import merge_partials

    return finalize_partials(sig, plan, merge_partials(plan.partial_sig,
                                                       partials))
