"""OLAP backend executor — a device-resident execution engine for intent
signatures over columnar data.

Replaces the paper's DuckDB backend.  Architecture (fast path, any JAX impl):

* **Storage** — ``Dataset.device()`` yields a :class:`DeviceDataset` that
  uploads fact columns / FK gathers once per dataset and memoizes every
  derived device array (measure blocks, predicate stacks, group ids).
* **Plan compiler** — a signature's measures are split into one fused
  ``(N, M)`` SUM/COUNT/AVG block executed by a **single** ``seg_agg`` launch
  (COUNT rides along as a ones column, COUNT(expr) as a finite-indicator
  column, AVG as SUM/COUNT at post-aggregation) plus one fused MIN/MAX block
  (MAX columns are negated so both share a single ``min`` launch).
* **Predicates** — filters and the time window are encoded as per-column
  range bounds ``(P, K, 2)`` (OR over K inclusive [lo, hi] ranges, AND over
  P columns); the mask is built on-device — inside the Pallas tile on the
  kernel path (no HBM mask round-trip), under ``jit`` on the XLA path.
* **Batch API** — :meth:`OlapExecutor.execute_batch` shares one scan (and a
  single kernel launch per agg block) across signatures that differ only in
  filters/time-window — the dashboard-refresh scenario (§7).

``impl='numpy'`` gives a fully independent numpy oracle used by the tests to
cross-check the JAX paths; ``fused=False`` preserves the legacy per-measure
path (one seg_agg launch per measure, host-side numpy masks/expressions) as
the benchmark baseline.  Post-aggregation (HAVING/ORDER BY/LIMIT), group
decoding, and COUNT DISTINCT remain host-side — they touch only the small
aggregate, never the fact table.
"""
from __future__ import annotations

import dataclasses
import threading as _threading
from typing import Optional, Sequence

import numpy as np

from ..core import sqlparse as sp
from ..core.signature import Signature
from ..core.sql_canon import CanonicalizationError, SQLCanonicalizer
from ..core.sqlparse import SQLSyntaxError, UnsupportedQuery
from ..core.table import ResultTable
from ..kernels.seg_agg.ops import (seg_agg, seg_agg_batch_blocks,
                                   seg_agg_fused, seg_agg_masked)
from .columnar import Dataset, date_to_days

MAX_DENSE_GROUPS = 1 << 20  # dense group-space cap for the segment-reduce path

_NEVER = (np.inf, -np.inf)  # pad range that matches nothing


@dataclasses.dataclass
class _LevelPlan:
    name: str  # 'table.column'
    codes: np.ndarray  # compact codes aligned to fact rows
    uniques: np.ndarray  # physical uniques (code -> physical value)
    card: int


@dataclasses.dataclass
class _MeasurePlan:
    """Device-compiled aggregation plan for one measure tuple.

    ``sum_block`` is the fused (N, 1+S) f32 block — column 0 is the hidden
    COUNT(*) ones column; ``minmax_block`` is (N, Mm) with MAX columns
    negated (one ``min`` launch covers both).  ``out_spec`` maps each
    requested measure to its block column: ('count',) | ('sumcol', j) |
    ('avg', j) | ('mincol', j) | ('maxcol', j) | ('distinct', expr).
    """

    sum_block: object
    minmax_block: Optional[object]
    out_spec: list[tuple]


class OlapExecutor:
    def __init__(self, dataset: Dataset, impl: str = "auto", fused: bool = True):
        """impl: 'auto' (seg_agg kernel dispatch), 'numpy' (independent
        oracle), or any explicit seg_agg impl ('xla' | 'interpret' |
        'pallas').  ``fused=False`` keeps the legacy per-measure host path
        (the pre-device-resident baseline) for JAX impls."""
        if impl not in ("auto", "numpy", "xla", "interpret", "pallas"):
            raise ValueError(
                f"unknown impl {impl!r}: expected 'auto', 'numpy', 'xla', "
                "'interpret', or 'pallas'")
        self.ds = dataset
        self.impl = impl
        self.fused = bool(fused) and impl != "numpy"
        self._canon = SQLCanonicalizer(dataset.schema)
        self._level_cache: dict[str, _LevelPlan] = {}
        self._gids_cache: dict[tuple, tuple] = {}
        self._rect_cache: dict[tuple, object] = {}
        self._mplans: dict[tuple, _MeasurePlan] = {}
        self._exact_cols: dict[str, bool] = {}
        self._nan_cols: dict[str, bool] = {}
        self._ds_version = getattr(dataset, "version", 0)
        self.executions = 0
        self.rows_scanned = 0
        self.batch_calls = 0  # execute_batch invocations (service miss planner)
        self.batch_groups = 0  # shared-scan groups actually fused across those
        # the cluster miss planner runs shard groups on concurrent threads;
        # bare '+=' on shared counters would drop increments
        self._count_lock = _threading.Lock()

    def _count(self, executions: int = 0, rows_scanned: int = 0,
               batch_calls: int = 0, batch_groups: int = 0) -> None:
        with self._count_lock:
            self.executions += executions
            self.rows_scanned += rows_scanned
            self.batch_calls += batch_calls
            self.batch_groups += batch_groups

    @property
    def dev(self):
        return self.ds.device()

    def _sync(self) -> None:
        """Resynchronize with the dataset after appends: every memoized plan
        (level codes, group ids, rect layouts, measure blocks, predicate
        exactness/NaN probes) is row-aligned or value-dependent, so a version
        bump invalidates all of them.  The device mirror itself was already
        dropped by ``Dataset.append_rows``."""
        v = getattr(self.ds, "version", 0)
        if v != self._ds_version:
            self._level_cache.clear()
            self._gids_cache.clear()
            self._rect_cache.clear()
            self._mplans.clear()
            self._exact_cols.clear()
            self._nan_cols.clear()
            self._ds_version = v

    # ------------------------------------------------------------------ api
    def execute(self, sig: Signature) -> ResultTable:
        self._sync()
        self._count(executions=1, rows_scanned=self.ds.fact.num_rows)
        if self.fused:
            return self._execute_fused(sig)
        return self._execute_host(sig)

    def execute_batch(
        self,
        sigs: Sequence[Signature],
        partition: Optional[tuple[int, int]] = None,
    ) -> list[ResultTable]:
        """Shared-scan batched execution (the dashboard-refresh scenario).

        Signatures are grouped by (levels, measures); each group that differs
        only in filters/time-window shares its level codes, group ids, and
        fused measure blocks, and is executed with a **single** ``seg_agg``
        launch per agg block for the whole group (masks for all S signatures
        are built on-device from one (S, P, K, 2) bounds tensor against the
        union of predicate columns).  ``rows_scanned`` advances once per
        shared scan, not once per signature.  Results match ``execute`` per
        signature exactly; COUNT DISTINCT or singleton groups fall back to
        the single-query path.

        ``partition=(start, end)`` bounds the scan to that fact row range
        (the incremental-refresh delta scan): execution is delegated to a
        sub-executor over a row-slice view of the dataset, so only the delta
        rows are uploaded and reduced — cost proportional to the delta, not
        the table.
        """
        sigs = list(sigs)
        if not sigs:
            return []
        self._sync()
        if partition is not None:
            sub = self._partition_executor(*partition)
            out = sub.execute_batch(sigs)
            # the sub-executor is fresh: its counters are exactly this call's
            self._count(executions=sub.executions,
                        rows_scanned=sub.rows_scanned,
                        batch_calls=sub.batch_calls,
                        batch_groups=sub.batch_groups)
            return out
        self._count(batch_calls=1)
        out: list[Optional[ResultTable]] = [None] * len(sigs)
        if not self.fused:
            return [self.execute(s) for s in sigs]
        groups: dict[tuple, list[int]] = {}
        for i, s in enumerate(sigs):
            groups.setdefault((s.levels, s.measures), []).append(i)
        for (lvls, measures), idxs in groups.items():
            distinct = any(m.agg == "COUNT_DISTINCT" for m in measures)
            if not distinct:
                # predicates that need exact host masks can't share the
                # encoded-bounds scan; run those signatures individually
                exact = [i for i in idxs if self._sig_ranges(sigs[i]) is not None]
            else:
                exact = []
            for i in idxs:
                if i not in exact:
                    out[i] = self.execute(sigs[i])
            idxs = exact
            if len(idxs) == 1:
                out[idxs[0]] = self.execute(sigs[idxs[0]])
                continue
            if not idxs:
                continue
            self._count(batch_groups=1, executions=len(idxs),
                        rows_scanned=self.ds.fact.num_rows)  # one shared scan
            levels = [self._level_plan(lv) for lv in lvls]
            gids_np, n_groups, sparse_uniq = self._group_ids(levels)
            gids_dev = self._device_gids(lvls, gids_np)
            rect = self._rect_index(lvls, gids_np, n_groups)
            plan = self._measure_plan(measures)
            group_sigs = [sigs[i] for i in idxs]
            pred_block, bounds = self._batch_predicates(group_sigs)
            impl = None if self.impl == "auto" else self.impl
            sums_dev, mms_dev = seg_agg_batch_blocks(
                plan.sum_block, plan.minmax_block, gids_dev, pred_block,
                bounds, n_groups, impl=impl, rect_idx=rect)
            sums = np.asarray(sums_dev, np.float64)  # (S, G, 1+Ms)
            mms = None if mms_dev is None else np.asarray(mms_dev, np.float64)
            for s_i, i in enumerate(idxs):
                out[i] = self._finalize(
                    sigs[i], levels, plan, sums[s_i],
                    None if mms is None else mms[s_i],
                    gids_np, n_groups, sparse_uniq)
        return out  # type: ignore[return-value]

    def _partition_executor(self, start: int, end: int) -> "OlapExecutor":
        """Fresh executor over fact rows [start, end).  Each delta partition
        is scanned once per refresh, so the executor itself is not memoized —
        cross-tick reuse comes from the global jit cache (delta ticks of
        similar size hit the same compiled shapes via the pow2 rect padding)
        and from sharing the parent mirror's dimension uploads, so the tick
        uploads only delta-sized fact columns."""
        sub = OlapExecutor(self.ds.slice_rows(start, end),
                           impl=self.impl, fused=self.fused)
        if self.fused and self.ds._device is not None:
            sub.ds.device().share_dim_arrays(self.ds._device)
        return sub

    def execute_raw(self, sql: str) -> Optional[ResultTable]:
        """Bypass path: out-of-scope requests run directly on the backend.
        We execute what we can canonicalize; genuinely out-of-scope SQL is
        acknowledged (None) — its cost is still a backend execution."""
        try:
            sig = self._canon.canonicalize(sql)
        except (UnsupportedQuery, SQLSyntaxError, CanonicalizationError):
            self._count(executions=1, rows_scanned=self.ds.fact.num_rows)
            return None
        return self.execute(sig)

    # ------------------------------------------------------- fused (device)
    def _execute_fused(self, sig: Signature) -> ResultTable:
        levels = [self._level_plan(lv) for lv in sig.levels]
        gids_np, n_groups, sparse_uniq = self._group_ids(levels)
        gids_dev = self._device_gids(sig.levels, gids_np)
        rect = self._rect_index(sig.levels, gids_np, n_groups)
        plan = self._measure_plan(sig.measures)
        impl = None if self.impl == "auto" else self.impl
        enc = self._predicate_plan(sig)
        if enc is None:
            # some predicate can't be evaluated exactly in f32: build the
            # mask on host (exact, oracle-identical) and keep the fused
            # single-launch device aggregation
            mask = self._filter_mask(sig)
            sums = np.asarray(
                seg_agg_masked(plan.sum_block, gids_dev, mask, n_groups,
                               "sum", impl=impl, rect_idx=rect),
                np.float64)
            mm = None
            if plan.minmax_block is not None:
                mm = np.asarray(
                    seg_agg_masked(plan.minmax_block, gids_dev, mask, n_groups,
                                   "min", impl=impl, rect_idx=rect),
                    np.float64)
        else:
            pred_block, bounds = enc
            sums = np.asarray(
                seg_agg_fused(plan.sum_block, gids_dev, pred_block, bounds,
                              n_groups, "sum", impl=impl, rect_idx=rect),
                np.float64)
            mm = None
            if plan.minmax_block is not None:
                mm = np.asarray(
                    seg_agg_fused(plan.minmax_block, gids_dev, pred_block, bounds,
                                  n_groups, "min", impl=impl, rect_idx=rect),
                    np.float64)
        return self._finalize(sig, levels, plan, sums, mm, gids_np, n_groups,
                              sparse_uniq)

    def _finalize(self, sig, levels, plan, sums, mm, gids_np, n_groups,
                  sparse_uniq) -> ResultTable:
        """Assemble measures from the fused blocks and apply the shared
        host-side tail (empty-group drop, decode, HAVING/ORDER/LIMIT)."""
        count_col = sums[:, 0]
        host_mask = None  # built at most once, shared by all distinct specs
        out_measures: list[np.ndarray] = []
        for spec in plan.out_spec:
            kind = spec[0]
            if kind == "count":
                out_measures.append(count_col.copy())
            elif kind == "sumcol":
                out_measures.append(sums[:, spec[1]])
            elif kind == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    out_measures.append(
                        np.where(count_col > 0, sums[:, spec[1]] / count_col, np.nan))
            elif kind == "mincol":
                out_measures.append(mm[:, spec[1]])
            elif kind == "maxcol":
                out_measures.append(-mm[:, spec[1]])
            else:  # ('distinct', expr): host-side exact, rare
                if host_mask is None:
                    host_mask = self._filter_mask(sig)
                out_measures.append(self._count_distinct(
                    self._expr_values(spec[1]), gids_np, host_mask, n_groups))
        return self._build_result(sig, levels, count_col, out_measures, sparse_uniq)

    def _build_result(self, sig, levels, count_col, out_measures,
                      sparse_uniq) -> ResultTable:
        """Shared result tail for the fused and host paths: drop empty groups
        (SQL semantics: they are absent; global aggregates keep their single
        row), decode surviving group ids, then HAVING/ORDER/LIMIT."""
        keep = count_col > 0
        if not sig.levels:
            keep = np.ones(1, dtype=bool)
        cols: dict[str, np.ndarray] = {}
        if levels:
            group_idx = np.nonzero(keep)[0]
            decoded = self._decode_groups(levels, group_idx, sparse_uniq)
            for lv, vals in zip(levels, decoded):
                cols[lv.name] = vals
        for i, mvals in enumerate(out_measures):
            cols[f"m{i}"] = mvals[keep] if sig.levels else mvals
        return self._post_aggregate(sig, ResultTable(cols))

    def _device_gids(self, levels_key: tuple, gids_np: np.ndarray):
        return self.dev.cache(("gids", levels_key), lambda: gids_np)

    # rect layout gate: padded size must stay close to N (skew guard) and
    # below an absolute element cap (memory guard)
    _RECT_MAX_BLOWUP = 2.0
    _RECT_MIN_CELLS = 1 << 16  # always allow tiny group spaces
    _RECT_MAX_CELLS = 1 << 25

    def _rect_index(self, levels_key: tuple, gids_np: np.ndarray, n_groups: int):
        """Cached (n_groups, R) row-index rectangle for a level combination:
        row g lists the fact rows of group g, padded with the out-of-range
        index N.  Lets the XLA path reduce with a vectorized gather instead
        of a serial scatter; None when group sizes are too skewed (padding
        blowup) or the padded matrix would be too large."""
        key = ("rectidx", levels_key)
        if key in self._rect_cache:
            return self._rect_cache[key]
        n = len(gids_np)
        counts = np.bincount(gids_np, minlength=n_groups)
        r0 = int(counts.max()) if n_groups else 0
        # pad R to a power of two: repeated delta scans (appends of similar
        # size) then hit the same jitted shapes instead of recompiling per
        # tick; pad cells hold the out-of-range index and read as identity.
        # Padding must respect the same work budget as the skew guard — when
        # the padded rectangle would blow past it, keep the exact R (shape
        # stability lost for that combination, work bound kept).
        r = 1 << (r0 - 1).bit_length() if r0 > 0 else 0
        if n_groups * r > max(self._RECT_MIN_CELLS, self._RECT_MAX_BLOWUP * n) \
                or n_groups * r > self._RECT_MAX_CELLS:
            r = r0  # padding alone must never disqualify a layout
        cells = n_groups * r0
        ok = r0 > 0 and n_groups * r <= self._RECT_MAX_CELLS and (
            cells <= self._RECT_MIN_CELLS or cells <= self._RECT_MAX_BLOWUP * n)
        if not ok:
            self._rect_cache[key] = None
            return None
        order = np.argsort(gids_np, kind="stable")
        starts = np.concatenate([[0], np.cumsum(counts[:-1])])
        sorted_gids = gids_np[order]
        pos = np.arange(n) - starts[sorted_gids]
        idx = np.full((n_groups, r), n, np.int32)
        idx[sorted_gids, pos] = order
        dev_idx = self.dev.cache(key, lambda: idx)
        self._rect_cache[key] = dev_idx
        return dev_idx

    def _measure_plan(self, measures: tuple) -> _MeasurePlan:
        plan = self._mplans.get(measures)
        if plan is not None:
            return plan
        jnp = self.dev._jnp
        n = self.ds.fact.num_rows
        ones = self.dev.cache(("ones",), lambda: np.ones(n, np.float32))
        sum_cols = [ones]
        sum_keys: list[tuple] = [("ones",)]
        mm_cols: list = []
        mm_keys: list[tuple] = []
        out_spec: list[tuple] = []
        for m in measures:
            if m.agg == "COUNT_DISTINCT":
                out_spec.append(("distinct", m.expr))
            elif m.agg == "COUNT":
                if m.expr == "*":
                    out_spec.append(("count",))
                else:
                    out_spec.append(("sumcol", len(sum_cols)))
                    sum_keys.append(("finite", m.expr))
                    sum_cols.append(self.dev.cache(
                        ("finite", m.expr),
                        lambda e=m.expr: jnp.isfinite(self._dev_expr(e)).astype(jnp.float32)))
            elif m.agg in ("SUM", "AVG"):
                out_spec.append(("sumcol" if m.agg == "SUM" else "avg", len(sum_cols)))
                sum_keys.append(("expr", m.expr))
                sum_cols.append(self._dev_expr(m.expr))
            elif m.agg == "MIN":
                out_spec.append(("mincol", len(mm_cols)))
                mm_keys.append(("expr", m.expr))
                mm_cols.append(self._dev_expr(m.expr))
            else:  # MAX: negate so MIN and MAX share one 'min' launch
                out_spec.append(("maxcol", len(mm_cols)))
                mm_keys.append(("negexpr", m.expr))
                mm_cols.append(self.dev.cache(
                    ("negexpr", m.expr), lambda e=m.expr: -self._dev_expr(e)))
        sum_block = self.dev.cache(
            ("sumblock", tuple(sum_keys)), lambda: jnp.stack(sum_cols, axis=1))
        mm_block = None
        if mm_cols:
            mm_block = self.dev.cache(
                ("mmblock", tuple(mm_keys)), lambda: jnp.stack(mm_cols, axis=1))
        plan = _MeasurePlan(sum_block, mm_block, out_spec)
        self._mplans[measures] = plan
        return plan

    def _dev_expr(self, expr: str):
        """Measure expression evaluated on-device (f32) from uploaded base
        columns, memoized per canonical expression string."""

        def build():
            jnp = self.dev._jnp
            ast = sp.parse_expr(expr)

            def ev(e):
                if isinstance(e, sp.ColRef):
                    q = f"{e.table}.{e.column}" if e.table else e.column
                    return self.dev.fact_aligned_f32(q)
                if isinstance(e, sp.Literal):
                    return float(e.value)
                if isinstance(e, sp.BinOp):
                    left, right = ev(e.left), ev(e.right)
                    if e.op == "+":
                        return left + right
                    if e.op == "-":
                        return left - right
                    if e.op == "*":
                        return left * right
                    return left / right
                raise ValueError(f"unexpected node in measure expression: {e}")

            v = ev(ast)
            if np.isscalar(v):
                return np.full(self.ds.fact.num_rows, v, dtype=np.float32)
            return jnp.asarray(v, jnp.float32)

        return self.dev.cache(("expr", expr), build)

    # ----------------------------------------------------- predicate encode
    def _f32_exact_col(self, qualified: str) -> bool:
        """True when every physical value of the column round-trips through
        f32 exactly (dictionary codes and date-days always do; int/float
        columns are checked once and cached).  Predicates over inexact
        columns fall back to the host-evaluated mask — the encoded-bounds
        comparison runs in f32 on device and must never diverge from the
        oracle's exact comparisons."""
        hit = self._exact_cols.get(qualified)
        if hit is None:
            data = self.ds.column(qualified).data
            if data.dtype.kind in "iu":
                hit = bool(np.all(np.abs(data) <= (1 << 24)))
            else:
                v32 = data.astype(np.float32).astype(data.dtype)
                hit = bool(np.all(v32 == data))  # NaN present -> inexact
            self._exact_cols[qualified] = hit
        return hit

    @staticmethod
    def _f32_exact_value(v: float) -> bool:
        return bool(np.isfinite(v)) and float(np.float32(v)) == float(v)

    def _filter_ranges(self, f) -> Optional[list[tuple[float, float]]]:
        """Encode one filter as a disjunction of inclusive f32 [lo, hi]
        ranges over the column's physical domain (str -> dictionary code,
        date -> days).  Open endpoints use f32 nextafter, which is exact
        because both column values and literals are gated to the f32 lattice
        — None when the column or a literal is not exactly representable
        (caller falls back to the host mask)."""
        if not self._f32_exact_col(f.col):
            return None
        col = self.ds.column(f.col)

        def enc(v) -> Optional[float]:
            pv = float(col.encode_value(v))
            return pv if self._f32_exact_value(pv) else None

        def down(v: float) -> float:
            return float(np.nextafter(np.float32(v), np.float32(-np.inf)))

        def up(v: float) -> float:
            return float(np.nextafter(np.float32(v), np.float32(np.inf)))

        if f.op == "in":
            vals = f.val if isinstance(f.val, (list, tuple)) else [f.val]
            encs = [enc(v) for v in vals]
            if any(e is None for e in encs):
                return None
            return [(e, e) for e in encs]
        v = enc(f.val)
        if v is None:
            return None
        if f.op == "=":
            return [(v, v)]
        if f.op == "!=":
            # NaN sentinel range: numpy semantics keep NaN rows (NaN != v)
            return [(-np.inf, down(v)), (up(v), np.inf), (np.nan, np.nan)]
        if f.op == "<":
            return [(-np.inf, down(v))]
        if f.op == "<=":
            return [(-np.inf, v)]
        if f.op == ">":
            return [(up(v), np.inf)]
        return [(v, np.inf)]  # >=

    def _window_range(self, tw) -> Optional[tuple[str, tuple[float, float]]]:
        date_col = self.ds.schema.fact.date_column
        if date_col is None:
            return None
        qualified = f"{self.ds.fact.name}.{date_col}"
        # [start, end) on int days -> inclusive [start, end-1]
        return qualified, (float(date_to_days(tw.start)),
                           float(date_to_days(tw.end) - 1))

    def _sig_ranges(self, sig: Signature) -> Optional[list[tuple[str, list]]]:
        """Per-predicate (column, ranges) pairs for one signature; None when
        any predicate can't be encoded exactly in f32 (the caller evaluates
        the mask on host instead)."""
        out = []
        for f in sig.filters:
            r = self._filter_ranges(f)
            if r is None:
                return None
            out.append((f.col, r))
        if sig.time_window is not None:
            wr = self._window_range(sig.time_window)
            if wr is not None:
                out.append((wr[0], [wr[1]]))
        return out

    def _accept_all(self, qualified: str) -> list[tuple[float, float]]:
        """Range disjunction matching every row of a column (batch filler
        for signatures that don't constrain it)."""
        hit = self._nan_cols.get(qualified)
        if hit is None:
            data = self.ds.column(qualified).data
            hit = bool(data.dtype.kind == "f" and np.isnan(data).any())
            self._nan_cols[qualified] = hit
        if hit:
            return [(-np.inf, np.inf), (np.nan, np.nan)]
        return [(-np.inf, np.inf)]

    def _pred_block(self, cols: tuple):
        jnp = self.dev._jnp
        n = self.ds.fact.num_rows
        if not cols:
            return self.dev.cache(
                ("preds", ()), lambda: np.zeros((n, 0), np.float32))
        return self.dev.cache(
            ("preds", cols),
            lambda: jnp.stack([self.dev.fact_aligned_f32(c) for c in cols], axis=1))

    def _predicate_plan(self, sig: Signature):
        """Device predicate-column stack (cached per column tuple) plus this
        query's (P, K, 2) bounds (tiny, host-encoded per query); None when
        the predicates need exact host evaluation."""
        pairs = self._sig_ranges(sig)
        if pairs is None:
            return None
        cols = tuple(c for c, _ in pairs)
        return self._pred_block(cols), _pack_bounds([r for _, r in pairs])

    def _batch_predicates(self, sigs: list[Signature]):
        """Union predicate columns across the batch; per-signature bounds
        with multiple predicates on one column intersected into a single
        range disjunction, unconstrained columns spanning everything."""
        per_sig: list[dict[str, list]] = []
        union: list[str] = []
        for s in sigs:
            d: dict[str, list] = {}
            for col, ranges in self._sig_ranges(s):
                d[col] = _intersect_ranges(d[col], ranges) if col in d else ranges
                if col not in union:
                    union.append(col)
            per_sig.append(d)
        cols = tuple(union)
        if not cols:
            # no predicates anywhere: one always-true pseudo-predicate over a
            # zeros column (zeros are never NaN, a plain full range suffices)
            bounds = np.empty((len(sigs), 1, 1, 2), np.float32)
            bounds[..., 0], bounds[..., 1] = -np.inf, np.inf
            block = self.dev.cache(
                ("preds", ("__zeros__",)),
                lambda: np.zeros((self.ds.fact.num_rows, 1), np.float32))
            return block, bounds
        # a column some other signature filters must accept *every* row here:
        # full range, plus the NaN sentinel only when the column can actually
        # hold NaNs (int/dictionary/date columns never do — skipping the
        # sentinel keeps the packed K small and the batched mask pass cheap)
        packed = [_pack_bounds([d.get(c, self._accept_all(c)) for c in cols])
                  for d in per_sig]
        k = max(b.shape[1] for b in packed)
        bounds = np.empty((len(sigs), len(cols), k, 2), np.float32)
        bounds[..., 0], bounds[..., 1] = _NEVER
        for s_i, b in enumerate(packed):
            bounds[s_i, :, : b.shape[1]] = b
        return self._pred_block(cols), bounds

    # ------------------------------------------------- legacy host baseline
    def _execute_host(self, sig: Signature) -> ResultTable:
        """Seed per-measure path: host numpy masks/expressions, one seg_agg
        launch per measure (plus the COUNT column).  ``impl='numpy'`` makes
        this the independent oracle; other impls keep it as the perf
        baseline that ``benchmarks/bench_backend.py`` measures against."""
        n = self.ds.fact.num_rows
        mask = self._filter_mask(sig)
        levels = [self._level_plan(lv) for lv in sig.levels]
        gids, n_groups, sparse_uniq = self._group_ids(levels)

        count_col = self._aggregate(np.ones((n, 1), np.float32), gids, mask, n_groups, "sum")[:, 0]
        out_measures: list[np.ndarray] = []
        for m in sig.measures:
            if m.agg == "COUNT" and not m.distinct:
                if m.expr == "*":
                    out_measures.append(count_col.copy())
                else:
                    vals = np.isfinite(self._expr_values(m.expr)).astype(np.float32)
                    out_measures.append(
                        self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0]
                    )
                continue
            if m.distinct:  # COUNT(DISTINCT expr): host-side exact
                out_measures.append(
                    self._count_distinct(self._expr_values(m.expr), gids, mask, n_groups)
                )
                continue
            vals = self._expr_values(m.expr).astype(np.float32)
            if m.agg == "AVG":
                s = self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0]
                with np.errstate(invalid="ignore", divide="ignore"):
                    out_measures.append(np.where(count_col > 0, s / count_col, np.nan))
            elif m.agg == "SUM":
                out_measures.append(
                    self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0].astype(np.float64)
                )
            else:  # MIN / MAX
                out_measures.append(
                    self._aggregate(vals[:, None], gids, mask, n_groups, m.agg.lower())[:, 0]
                )

        return self._build_result(sig, levels, count_col, out_measures, sparse_uniq)

    # ------------------------------------------------------------ internals
    def _aggregate(self, values, gids, mask, n_groups, op):
        if self.impl == "numpy":
            return _np_segment(values, gids, mask, n_groups, op)
        impl = None if self.impl == "auto" else self.impl
        return np.asarray(seg_agg(values, gids, mask.astype(np.float32), n_groups, op, impl=impl))

    def _filter_mask(self, sig: Signature) -> np.ndarray:
        n = self.ds.fact.num_rows
        mask = np.ones(n, dtype=bool)
        for f in sig.filters:
            col = self.ds.column(f.col)
            vals = self.ds.fact_aligned(f.col)
            if f.op == "in":
                phys = [col.encode_value(v) for v in (f.val if isinstance(f.val, (list, tuple)) else [f.val])]
                mask &= np.isin(vals, phys)
                continue
            pv = col.encode_value(f.val)
            if f.op == "=":
                mask &= vals == pv
            elif f.op == "!=":
                mask &= vals != pv
            elif f.op == "<":
                mask &= vals < pv
            elif f.op == "<=":
                mask &= vals <= pv
            elif f.op == ">":
                mask &= vals > pv
            elif f.op == ">=":
                mask &= vals >= pv
        tw = sig.time_window
        if tw is not None:
            date_col = self.ds.schema.fact.date_column
            if date_col is not None:
                days = self.ds.fact.columns[date_col].data
                mask &= (days >= date_to_days(tw.start)) & (days < date_to_days(tw.end))
        return mask

    def _level_plan(self, level: str) -> _LevelPlan:
        lp = self._level_cache.get(level)
        if lp is not None:
            return lp
        aligned = self.ds.fact_aligned(level)
        t, c = level.split(".", 1)
        table_col = self.ds.table(t).columns[c]
        uniques = np.unique(table_col.data)
        codes = np.searchsorted(uniques, aligned).astype(np.int32)
        lp = _LevelPlan(level, codes, uniques, len(uniques))
        self._level_cache[level] = lp
        return lp

    def _group_ids(self, levels: list[_LevelPlan]) -> tuple[np.ndarray, int, Optional[np.ndarray]]:
        """Dense (or compacted-sparse) group ids for a level combination.

        Returns ``(gids, n_groups, sparse_uniq)`` — ``sparse_uniq`` is the
        observed-group compaction table (None on the dense path) and is
        threaded through to ``_decode_groups`` by the caller instead of
        living in mutable instance state (stale/racy across calls).
        Memoized per level combination: the mapping depends only on the
        dataset, not on the query's filters.
        """
        n = self.ds.fact.num_rows
        if not levels:
            return np.zeros(n, dtype=np.int32), 1, None
        cache_key = tuple(lp.name for lp in levels)
        hit = self._gids_cache.get(cache_key)
        if hit is not None:
            return hit
        g = 1
        gids = np.zeros(n, dtype=np.int64)
        for lp in levels:
            gids = gids * lp.card + lp.codes
            g *= lp.card
        if g > MAX_DENSE_GROUPS:
            # compact the observed group space (rare for dashboard queries)
            uniq, gids = np.unique(gids, return_inverse=True)
            result = (gids.astype(np.int32), len(uniq), uniq)
        else:
            result = (gids.astype(np.int32), g, None)
        self._gids_cache[cache_key] = result
        return result

    def _decode_groups(self, levels: list[_LevelPlan], group_idx: np.ndarray,
                       sparse_uniq: Optional[np.ndarray] = None):
        """Map surviving dense group ids back to per-level decoded values."""
        if sparse_uniq is not None:
            group_idx = sparse_uniq[group_idx]
        out = []
        rem = group_idx.astype(np.int64)
        cards = [lp.card for lp in levels]
        comps: list[np.ndarray] = []
        for card in reversed(cards):
            comps.append(rem % card)
            rem = rem // card
        comps.reverse()
        for lp, comp in zip(levels, comps):
            t, c = lp.name.split(".", 1)
            col = self.ds.table(t).columns[c]
            out.append(col.decode(lp.uniques[comp]))
        return out

    def _expr_values(self, expr: str) -> np.ndarray:
        ast = sp.parse_expr(expr)

        def ev(e) -> np.ndarray | float:
            if isinstance(e, sp.ColRef):
                q = f"{e.table}.{e.column}" if e.table else e.column
                return self.ds.fact_aligned(q).astype(np.float64)
            if isinstance(e, sp.Literal):
                return float(e.value)
            if isinstance(e, sp.BinOp):
                l, r = ev(e.left), ev(e.right)
                if e.op == "+":
                    return l + r
                if e.op == "-":
                    return l - r
                if e.op == "*":
                    return l * r
                return l / r
            raise ValueError(f"unexpected node in measure expression: {e}")

        v = ev(ast)
        if np.isscalar(v):
            v = np.full(self.ds.fact.num_rows, v, dtype=np.float64)
        return v

    def _count_distinct(self, vals, gids, mask, n_groups) -> np.ndarray:
        sel = mask
        pairs = np.stack([gids[sel].astype(np.int64), vals[sel].astype(np.int64)], axis=1)
        uniq = np.unique(pairs, axis=0)
        out = np.zeros(n_groups, dtype=np.float64)
        np.add.at(out, uniq[:, 0], 1.0)
        return out

    def _post_aggregate(self, sig: Signature, table: ResultTable) -> ResultTable:
        for h in sig.having:
            col = table.columns[f"m{h.measure}"]
            from ..core.table import eval_predicate

            table = table.mask(eval_predicate(col, h.op, h.val))
        if sig.order_by:
            keys = []
            for o in sig.order_by:
                name = f"m{o.key.split(':', 1)[1]}" if o.key.startswith("measure:") else o.key
                keys.append((name, o.desc))
            table = table.sort(keys)
        if sig.limit is not None:
            table = table.head(sig.limit)
        return table


def _pack_bounds(ranges: list[list[tuple[float, float]]]) -> np.ndarray:
    """Pack per-predicate range lists into a (P, K, 2) f32 bounds tensor,
    K padded to a power of two (fewer distinct jit shapes) with never-match
    pad ranges."""
    p = len(ranges)
    if p == 0:
        return np.zeros((0, 1, 2), np.float32)
    k = max(1, max(len(r) for r in ranges))
    k = 1 << (k - 1).bit_length()
    out = np.empty((p, k, 2), np.float32)
    out[..., 0], out[..., 1] = _NEVER
    for i, r in enumerate(ranges):
        for j, (lo, hi) in enumerate(r):
            out[i, j] = (lo, hi)
    return out


def _intersect_ranges(a: list, b: list) -> list:
    """Intersection of two inclusive range disjunctions (AND of ORs back to
    one OR list); empty result means the conjunction is unsatisfiable.
    NaN-sentinel ranges (see ``bounds_mask_ref``) survive only when both
    sides carry one — NaN passes a conjunction iff every predicate admits
    NaN."""

    def split(rs):
        return ([r for r in rs if not np.isnan(r[0])],
                [r for r in rs if np.isnan(r[0])])

    a_num, a_nan = split(a)
    b_num, b_nan = split(b)
    out = []
    for lo1, hi1 in a_num:
        for lo2, hi2 in b_num:
            lo, hi = max(lo1, lo2), min(hi1, hi2)
            if lo <= hi:
                out.append((lo, hi))
    if a_nan and b_nan:
        out.append((np.nan, np.nan))
    return out


def _np_segment(values, gids, mask, n_groups, op) -> np.ndarray:
    """Independent numpy oracle for the segment reduce (no JAX involved).

    MIN/MAX are NaN-aware the same way the kernels' fillers are (via the
    shared numpy-only ``_extreme_at``): NaN rows are masked out of the
    ``.at`` scatter and their groups re-poisoned afterwards — a qualifying
    NaN row still yields a NaN group, matching the device path's NaN
    propagation, warning-free."""
    from ..core.derivations import _extreme_at

    values = np.asarray(values, np.float64)
    m = values.shape[1]
    sel = np.asarray(mask, bool)
    g = gids[sel]
    v = values[sel]
    if op == "sum":
        out = np.zeros((n_groups, m))
        for j in range(m):
            np.add.at(out[:, j], g, v[:, j])
        return out
    out = np.full((n_groups, m), np.inf if op == "min" else -np.inf)
    for j in range(m):
        _extreme_at(op.upper(), v[:, j], g, out[:, j])
    return out
