"""OLAP backend executor — evaluates intent signatures over columnar data.

Replaces the paper's DuckDB backend.  The streaming hot spot (scan the fact
table, apply predicate masks, and segment-reduce measures into group cells) is
the ``seg_agg`` kernel (Pallas on TPU, XLA elsewhere); plan construction,
expression preparation, and post-aggregation (HAVING/ORDER BY/LIMIT) are
host-side.  ``impl='numpy'`` gives a fully independent numpy oracle used by
the tests to cross-check the JAX path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import sqlparse as sp
from ..core.signature import Signature
from ..core.sql_canon import CanonicalizationError, SQLCanonicalizer
from ..core.sqlparse import SQLSyntaxError, UnsupportedQuery
from ..core.table import ResultTable
from ..kernels.seg_agg.ops import seg_agg
from .columnar import Dataset, date_to_days

MAX_DENSE_GROUPS = 1 << 20  # dense group-space cap for the segment-reduce path


@dataclasses.dataclass
class _LevelPlan:
    name: str  # 'table.column'
    codes: np.ndarray  # compact codes aligned to fact rows
    uniques: np.ndarray  # physical uniques (code -> physical value)
    card: int


class OlapExecutor:
    def __init__(self, dataset: Dataset, impl: str = "auto"):
        """impl: 'auto' (seg_agg kernel dispatch), 'numpy' (independent oracle),
        or any explicit seg_agg impl ('xla' | 'interpret' | 'pallas')."""
        self.ds = dataset
        self.impl = impl
        self._canon = SQLCanonicalizer(dataset.schema)
        self._level_cache: dict[str, _LevelPlan] = {}
        self.executions = 0
        self.rows_scanned = 0

    # ------------------------------------------------------------------ api
    def execute(self, sig: Signature) -> ResultTable:
        self.executions += 1
        n = self.ds.fact.num_rows
        self.rows_scanned += n
        mask = self._filter_mask(sig)
        levels = [self._level_plan(lv) for lv in sig.levels]
        gids, n_groups = self._group_ids(levels)

        # measure evaluation: SUM/MIN/MAX stream through seg_agg; COUNT uses
        # the hidden count column; AVG = SUM/COUNT; COUNT DISTINCT is host-side
        count_col = self._aggregate(np.ones((n, 1), np.float32), gids, mask, n_groups, "sum")[:, 0]
        out_measures: list[np.ndarray] = []
        for m in sig.measures:
            if m.agg == "COUNT" and not m.distinct:
                if m.expr == "*":
                    out_measures.append(count_col.copy())
                else:
                    vals = np.isfinite(self._expr_values(m.expr)).astype(np.float32)
                    out_measures.append(
                        self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0]
                    )
                continue
            if m.distinct:  # COUNT(DISTINCT expr): host-side exact
                out_measures.append(
                    self._count_distinct(self._expr_values(m.expr), gids, mask, n_groups)
                )
                continue
            vals = self._expr_values(m.expr).astype(np.float32)
            if m.agg == "AVG":
                s = self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0]
                with np.errstate(invalid="ignore", divide="ignore"):
                    out_measures.append(np.where(count_col > 0, s / count_col, np.nan))
            elif m.agg == "SUM":
                out_measures.append(
                    self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0].astype(np.float64)
                )
            else:  # MIN / MAX
                out_measures.append(
                    self._aggregate(vals[:, None], gids, mask, n_groups, m.agg.lower())[:, 0]
                )

        # SQL semantics: groups with no qualifying rows are absent
        keep = count_col > 0
        if not sig.levels:
            keep = np.ones(1, dtype=bool)  # global aggregate: always one row
        cols: dict[str, np.ndarray] = {}
        if levels:
            group_idx = np.nonzero(keep)[0]
            decoded = self._decode_groups(levels, group_idx)
            for lv, vals in zip(levels, decoded):
                cols[lv.name] = vals
        for i, mvals in enumerate(out_measures):
            cols[f"m{i}"] = mvals[keep] if sig.levels else mvals

        table = ResultTable(cols)
        return self._post_aggregate(sig, table)

    def execute_raw(self, sql: str) -> Optional[ResultTable]:
        """Bypass path: out-of-scope requests run directly on the backend.
        We execute what we can canonicalize; genuinely out-of-scope SQL is
        acknowledged (None) — its cost is still a backend execution."""
        try:
            sig = self._canon.canonicalize(sql)
        except (UnsupportedQuery, SQLSyntaxError, CanonicalizationError):
            self.executions += 1
            self.rows_scanned += self.ds.fact.num_rows
            return None
        return self.execute(sig)

    # ------------------------------------------------------------ internals
    def _aggregate(self, values, gids, mask, n_groups, op):
        if self.impl == "numpy":
            return _np_segment(values, gids, mask, n_groups, op)
        impl = None if self.impl == "auto" else self.impl
        return np.asarray(seg_agg(values, gids, mask.astype(np.float32), n_groups, op, impl=impl))

    def _filter_mask(self, sig: Signature) -> np.ndarray:
        n = self.ds.fact.num_rows
        mask = np.ones(n, dtype=bool)
        for f in sig.filters:
            col = self.ds.column(f.col)
            vals = self.ds.fact_aligned(f.col)
            if f.op == "in":
                phys = [col.encode_value(v) for v in (f.val if isinstance(f.val, (list, tuple)) else [f.val])]
                mask &= np.isin(vals, phys)
                continue
            pv = col.encode_value(f.val)
            if f.op == "=":
                mask &= vals == pv
            elif f.op == "!=":
                mask &= vals != pv
            elif f.op == "<":
                mask &= vals < pv
            elif f.op == "<=":
                mask &= vals <= pv
            elif f.op == ">":
                mask &= vals > pv
            elif f.op == ">=":
                mask &= vals >= pv
        tw = sig.time_window
        if tw is not None:
            date_col = self.ds.schema.fact.date_column
            if date_col is not None:
                days = self.ds.fact.columns[date_col].data
                mask &= (days >= date_to_days(tw.start)) & (days < date_to_days(tw.end))
        return mask

    def _level_plan(self, level: str) -> _LevelPlan:
        lp = self._level_cache.get(level)
        if lp is not None:
            return lp
        aligned = self.ds.fact_aligned(level)
        t, c = level.split(".", 1)
        table_col = self.ds.table(t).columns[c]
        uniques = np.unique(table_col.data)
        codes = np.searchsorted(uniques, aligned).astype(np.int32)
        lp = _LevelPlan(level, codes, uniques, len(uniques))
        self._level_cache[level] = lp
        return lp

    def _group_ids(self, levels: list[_LevelPlan]) -> tuple[np.ndarray, int]:
        n = self.ds.fact.num_rows
        if not levels:
            return np.zeros(n, dtype=np.int32), 1
        g = 1
        gids = np.zeros(n, dtype=np.int64)
        for lp in levels:
            gids = gids * lp.card + lp.codes
            g *= lp.card
        if g > MAX_DENSE_GROUPS:
            # compact the observed group space (rare for dashboard queries)
            uniq, gids = np.unique(gids, return_inverse=True)
            self._sparse_uniq = uniq
            return gids.astype(np.int32), len(uniq)
        self._sparse_uniq = None
        return gids.astype(np.int32), g

    def _decode_groups(self, levels: list[_LevelPlan], group_idx: np.ndarray):
        """Map surviving dense group ids back to per-level decoded values."""
        if self._sparse_uniq is not None:
            group_idx = self._sparse_uniq[group_idx]
        out = []
        rem = group_idx.astype(np.int64)
        cards = [lp.card for lp in levels]
        comps: list[np.ndarray] = []
        for card in reversed(cards):
            comps.append(rem % card)
            rem = rem // card
        comps.reverse()
        for lp, comp in zip(levels, comps):
            t, c = lp.name.split(".", 1)
            col = self.ds.table(t).columns[c]
            out.append(col.decode(lp.uniques[comp]))
        return out

    def _expr_values(self, expr: str) -> np.ndarray:
        ast = sp.parse_expr(expr)

        def ev(e) -> np.ndarray | float:
            if isinstance(e, sp.ColRef):
                q = f"{e.table}.{e.column}" if e.table else e.column
                return self.ds.fact_aligned(q).astype(np.float64)
            if isinstance(e, sp.Literal):
                return float(e.value)
            if isinstance(e, sp.BinOp):
                l, r = ev(e.left), ev(e.right)
                if e.op == "+":
                    return l + r
                if e.op == "-":
                    return l - r
                if e.op == "*":
                    return l * r
                return l / r
            raise ValueError(f"unexpected node in measure expression: {e}")

        v = ev(ast)
        if np.isscalar(v):
            v = np.full(self.ds.fact.num_rows, v, dtype=np.float64)
        return v

    def _count_distinct(self, vals, gids, mask, n_groups) -> np.ndarray:
        sel = mask
        pairs = np.stack([gids[sel].astype(np.int64), vals[sel].astype(np.int64)], axis=1)
        uniq = np.unique(pairs, axis=0)
        out = np.zeros(n_groups, dtype=np.float64)
        np.add.at(out, uniq[:, 0], 1.0)
        return out

    def _post_aggregate(self, sig: Signature, table: ResultTable) -> ResultTable:
        for h in sig.having:
            col = table.columns[f"m{h.measure}"]
            from ..core.table import eval_predicate

            table = table.mask(eval_predicate(col, h.op, h.val))
        if sig.order_by:
            keys = []
            for o in sig.order_by:
                name = f"m{o.key.split(':', 1)[1]}" if o.key.startswith("measure:") else o.key
                keys.append((name, o.desc))
            table = table.sort(keys)
        if sig.limit is not None:
            table = table.head(sig.limit)
        return table


def _np_segment(values, gids, mask, n_groups, op) -> np.ndarray:
    """Independent numpy oracle for the segment reduce (no JAX involved)."""
    values = np.asarray(values, np.float64)
    m = values.shape[1]
    sel = np.asarray(mask, bool)
    g = gids[sel]
    v = values[sel]
    if op == "sum":
        out = np.zeros((n_groups, m))
        for j in range(m):
            np.add.at(out[:, j], g, v[:, j])
        return out
    if op == "min":
        out = np.full((n_groups, m), np.inf)
        for j in range(m):
            np.minimum.at(out[:, j], g, v[:, j])
        return out
    out = np.full((n_groups, m), -np.inf)
    for j in range(m):
        np.maximum.at(out[:, j], g, v[:, j])
    return out
