"""OLAP backend executor — a device-resident execution engine for intent
signatures over columnar data.

Replaces the paper's DuckDB backend.  Architecture (fast path, any JAX impl):

* **Storage** — ``Dataset.device()`` yields a :class:`DeviceDataset` that
  uploads fact columns / FK gathers once per dataset and memoizes every
  derived device array (measure blocks, predicate stacks, group ids).
* **Plan compiler** — a signature's measures are split into one fused
  ``(N, M)`` SUM/COUNT/AVG block executed by a **single** ``seg_agg`` launch
  (COUNT rides along as a ones column, COUNT(expr) as a finite-indicator
  column, AVG as SUM/COUNT at post-aggregation) plus one fused MIN/MAX block
  (MAX columns are negated so both share a single ``min`` launch).
* **Predicates** — filters and the time window are encoded as per-column
  range bounds ``(P, K, 2)`` (OR over K inclusive [lo, hi] ranges, AND over
  P columns); the mask is built on-device — inside the Pallas tile on the
  kernel path (no HBM mask round-trip), under ``jit`` on the XLA path.
* **Batch API** — :meth:`OlapExecutor.execute_batch` shares one scan (and a
  single kernel launch per agg block) across signatures that differ only in
  filters/time-window — the dashboard-refresh scenario (§7).

``impl='numpy'`` gives a fully independent numpy oracle used by the tests to
cross-check the JAX paths; ``fused=False`` preserves the legacy per-measure
path (one seg_agg launch per measure, host-side numpy masks/expressions) as
the benchmark baseline.  Post-aggregation (HAVING/ORDER BY/LIMIT), group
decoding, and COUNT DISTINCT remain host-side — they touch only the small
aggregate, never the fact table.

* **Scan plane** — ``OlapExecutor(partitions=N, max_device_rows=...)``
  activates the partition-parallel miss path: the fact table is split into
  contiguous row-range partitions (``scan_plane.plan_scan``), each scanned by
  a per-partition sub-executor on a thread pool (pinned to distinct JAX
  devices when the host exposes several), and the partial tables are merged
  with the refresh merge algebra (``core.refresh.merge_partials``) —
  SUM/COUNT add, NaN-aware MIN/MAX, AVG finalized from merged SUM/COUNT.
  ``max_device_rows`` adds streaming: partitions larger than the budget are
  scanned as a sequence of pow2-sized chunks with the next chunk's columns
  staged while the current one scans.  ``partitions=1`` (the default) is the
  unpartitioned oracle the merged tables are differential-tested against.
"""
from __future__ import annotations

import collections
import dataclasses
import threading as _threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional, Sequence

import numpy as np

from ..analysis.sanitizer import make_lock
from ..core import sqlparse as sp
from ..core.refresh import merge_partials
from ..core.signature import Signature
from ..core.sql_canon import CanonicalizationError, SQLCanonicalizer
from ..core.sqlparse import SQLSyntaxError, UnsupportedQuery
from ..core.table import ResultTable
from ..obs.trace import adopt, child_span, current_ctx
from ..resilience import faults
from ..kernels.seg_agg.ops import (seg_agg, seg_agg_batch_blocks,
                                   seg_agg_fused, seg_agg_masked)
from . import scan_plane
from .columnar import Dataset, date_to_days

MAX_DENSE_GROUPS = 1 << 20  # dense group-space cap for the segment-reduce path

DEFAULT_MEMO_CAP = 64  # per-executor LRU bound on plan/index memo dicts

_NEVER = (np.inf, -np.inf)  # pad range that matches nothing

_UNSET = object()


class _LRU:
    """Bounded memo dict: get/set bump recency, inserts past ``cap`` evict
    the least-recently-used entry through ``on_evict`` (which drops the
    entry's device-store arrays, so a long-lived multi-tenant executor's
    device footprint is bounded along with the host dicts).  A small lock
    keeps the recency list coherent under the scan plane's partition
    threads."""

    def __init__(self, cap: int,
                 on_evict: Optional[Callable[[object, object], None]] = None):
        self.cap = int(cap)
        self._d: collections.OrderedDict = collections.OrderedDict()  # guarded-by: self._lock
        self._on_evict = on_evict
        self._lock = make_lock("_LRU._lock")

    def get(self, key, default=None):
        with self._lock:
            if key not in self._d:
                return default
            self._d.move_to_end(key)
            return self._d[key]

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._d

    def __getitem__(self, key):
        with self._lock:
            v = self._d[key]
            self._d.move_to_end(key)
            return v

    def __setitem__(self, key, value) -> None:
        evicted = []
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.cap:
                evicted.append(self._d.popitem(last=False))
        if self._on_evict is not None:
            for k, v in evicted:  # outside the lock: callbacks touch stores
                self._on_evict(k, v)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()


@dataclasses.dataclass
class _LevelPlan:
    name: str  # 'table.column'
    codes: np.ndarray  # compact codes aligned to fact rows
    uniques: np.ndarray  # physical uniques (code -> physical value)
    card: int


@dataclasses.dataclass
class _MeasurePlan:
    """Device-compiled aggregation plan for one measure tuple.

    ``sum_block`` is the fused (N, 1+S) f32 block — column 0 is the hidden
    COUNT(*) ones column; ``minmax_block`` is (N, Mm) with MAX columns
    negated (one ``min`` launch covers both).  ``out_spec`` maps each
    requested measure to its block column: ('count',) | ('sumcol', j) |
    ('avg', j) | ('mincol', j) | ('maxcol', j) | ('distinct', expr).
    """

    sum_block: object
    minmax_block: Optional[object]
    out_spec: list[tuple]
    # device-store keys of the blocks, so LRU eviction of the plan can also
    # release the device arrays it pinned
    sum_key: Optional[tuple] = None
    mm_key: Optional[tuple] = None


class OlapExecutor:
    def __init__(self, dataset: Dataset, impl: str = "auto", fused: bool = True,
                 partitions: int = 1, max_device_rows: Optional[int] = None,
                 memo_cap: int = DEFAULT_MEMO_CAP):
        """impl: 'auto' (seg_agg kernel dispatch), 'numpy' (independent
        oracle), or any explicit seg_agg impl ('xla' | 'interpret' |
        'pallas').  ``fused=False`` keeps the legacy per-measure host path
        (the pre-device-resident baseline) for JAX impls.

        ``partitions=N`` activates the partition-parallel scan plane (N
        concurrent row-range scans merged with the refresh algebra);
        ``max_device_rows`` bounds per-scan device residency and turns
        larger partitions into streamed chunk sequences.  ``memo_cap``
        bounds every plan/index memo dict (LRU)."""
        if impl not in ("auto", "numpy", "xla", "interpret", "pallas"):
            raise ValueError(
                f"unknown impl {impl!r}: expected 'auto', 'numpy', 'xla', "
                "'interpret', or 'pallas'")
        if partitions < 1:
            raise ValueError(f"partitions must be >= 1, got {partitions}")
        if memo_cap < 1:
            raise ValueError(f"memo_cap must be >= 1, got {memo_cap}")
        self.ds = dataset
        self.impl = impl
        self.fused = bool(fused) and impl != "numpy"
        self.partitions = int(partitions)
        self.max_device_rows = max_device_rows
        self._memo_cap = int(memo_cap)
        self._canon = SQLCanonicalizer(dataset.schema)
        self._level_cache: _LRU = _LRU(memo_cap)  # guarded-by: external[_LRU synchronizes internally via _LRU._lock]
        self._gids_cache: _LRU = _LRU(memo_cap, self._evict_gids)  # guarded-by: external[_LRU synchronizes internally via _LRU._lock]
        self._rect_cache: _LRU = _LRU(memo_cap, self._evict_rect)  # guarded-by: external[_LRU synchronizes internally via _LRU._lock]
        self._mplans: _LRU = _LRU(memo_cap, self._evict_mplan)  # guarded-by: external[_LRU synchronizes internally via _LRU._lock]
        # per-column predicate probes: idempotent memos (the value is a pure
        # function of the column), registered as benign races in the
        # analysis registry rather than lock-guarded
        self._exact_cols: dict[str, bool] = {}
        self._nan_cols: dict[str, bool] = {}
        # version only changes while the tenant's exclusive write gate is
        # held (advance_snapshot), so _sync's clears never race a scan
        self._ds_version = getattr(dataset, "version", 0)  # guarded-by: external[tenant ReadWriteGate.write serializes version changes]
        self.executions = 0  # guarded-by: self._count_lock
        self.rows_scanned = 0  # guarded-by: self._count_lock
        # execute_batch invocations (service miss planner)
        self.batch_calls = 0  # guarded-by: self._count_lock
        # shared-scan groups actually fused across those
        self.batch_groups = 0  # guarded-by: self._count_lock
        # scan-plane invocations
        self.partitioned_scans = 0  # guarded-by: self._count_lock
        # sigs routed to single-partition scan
        self.partition_fallbacks = 0  # guarded-by: self._count_lock
        # chunk scans beyond the first per partition
        self.streaming_chunks = 0  # guarded-by: self._count_lock
        # the cluster miss planner runs shard groups on concurrent threads;
        # bare '+=' on shared counters would drop increments
        self._count_lock = make_lock("OlapExecutor._count_lock")
        # serializes scans on this executor when it acts as a resident
        # per-partition sub (keeps counter deltas attributable per scan)
        self._scan_mutex = make_lock("OlapExecutor._scan_mutex")
        self._subs_lock = make_lock("OlapExecutor._subs_lock")
        self._subs: dict[tuple[int, int], "OlapExecutor"] = {}  # guarded-by: self._subs_lock
        # device -> shared dimcol store dict
        self._dim_pools: dict = {}  # guarded-by: self._subs_lock
        self._pool_obj: Optional[ThreadPoolExecutor] = None  # guarded-by: self._subs_lock
        self._plan_cache: Optional[scan_plane.ScanPlan] = None  # guarded-by: self._subs_lock
        self._pstats: list[dict] = []  # guarded-by: self._count_lock
        self._devices = _UNSET

    def _count(self, executions: int = 0, rows_scanned: int = 0,
               batch_calls: int = 0, batch_groups: int = 0) -> None:
        with self._count_lock:
            self.executions += executions
            self.rows_scanned += rows_scanned
            self.batch_calls += batch_calls
            self.batch_groups += batch_groups

    # ------------------------------------------------------- memo LRU bounds
    def _dev_drop(self, *keys) -> None:
        dev = self.ds._device
        if dev is None:
            return
        for k in keys:
            if k is not None:
                dev.drop(k)

    def _evict_gids(self, key, value) -> None:
        self._dev_drop(("gids", key))

    def _evict_rect(self, key, value) -> None:
        self._dev_drop(key)  # the memo key IS the device key ('rectidx', lvls)

    def _evict_mplan(self, key, plan) -> None:
        self._dev_drop(plan.sum_key, plan.mm_key)

    def memo_sizes(self) -> dict[str, int]:
        """Current entry counts of every per-executor memo (all LRU-bounded
        by ``memo_cap`` except the per-column predicate probes, which are
        naturally bounded by the schema's column count)."""
        return {
            "level_plans": len(self._level_cache),
            "gids": len(self._gids_cache),
            "rect_index": len(self._rect_cache),
            "measure_plans": len(self._mplans),
            "pred_exact_cols": len(self._exact_cols),
            "pred_nan_cols": len(self._nan_cols),
        }

    def stats(self) -> dict:
        """Executor counters: totals, memo sizes, and — when the scan plane
        is active — per-partition rows/executions/chunk accounting."""
        with self._count_lock:
            return {
                "executions": self.executions,
                "rows_scanned": self.rows_scanned,
                "batch_calls": self.batch_calls,
                "batch_groups": self.batch_groups,
                "partitions": self.partitions,
                "max_device_rows": self.max_device_rows,
                "partitioned_scans": self.partitioned_scans,
                "partition_fallbacks": self.partition_fallbacks,
                "streaming_chunks": self.streaming_chunks,
                "memo_sizes": self.memo_sizes(),
                "per_partition": [dict(p) for p in self._pstats],
            }

    @property
    def dev(self):
        return self.ds.device()

    def _sync(self) -> None:
        """Resynchronize with the dataset after appends: every memoized plan
        (level codes, group ids, rect layouts, measure blocks, predicate
        exactness/NaN probes) is row-aligned or value-dependent, so a version
        bump invalidates all of them.  The device mirror itself was already
        dropped by ``Dataset.append_rows``."""
        v = getattr(self.ds, "version", 0)
        if v != self._ds_version:
            self._level_cache.clear()
            self._gids_cache.clear()
            self._rect_cache.clear()
            self._mplans.clear()
            self._exact_cols.clear()
            self._nan_cols.clear()
            with self._subs_lock:
                # partition layout and row slices are stale; dim pools
                # survive (dimension tables are immutable across appends)
                self._subs.clear()
                self._plan_cache = None
            with self._count_lock:
                self._pstats = []
            self._ds_version = v

    # ------------------------------------------------------------------ api
    def execute(self, sig: Signature) -> ResultTable:
        self._sync()
        if self._scan_active():
            if scan_plane.partition_compatible(sig):
                self._count(executions=1)
                return self._execute_partitioned([sig])[0]
            with self._count_lock:
                self.partition_fallbacks += 1
        self._count(executions=1, rows_scanned=self.ds.fact.num_rows)
        if self.fused:
            return self._execute_fused(sig)
        return self._execute_host(sig)

    def execute_batch(
        self,
        sigs: Sequence[Signature],
        partition: Optional[tuple[int, int]] = None,
    ) -> list[ResultTable]:
        """Shared-scan batched execution (the dashboard-refresh scenario).

        Signatures are grouped by (levels, measures); each group that differs
        only in filters/time-window shares its level codes, group ids, and
        fused measure blocks, and is executed with a **single** ``seg_agg``
        launch per agg block for the whole group (masks for all S signatures
        are built on-device from one (S, P, K, 2) bounds tensor against the
        union of predicate columns).  ``rows_scanned`` advances once per
        shared scan, not once per signature.  Results match ``execute`` per
        signature exactly; COUNT DISTINCT or singleton groups fall back to
        the single-query path.

        ``partition=(start, end)`` bounds the scan to that fact row range
        (the incremental-refresh delta scan): execution is delegated to a
        sub-executor over a row-slice view of the dataset, so only the delta
        rows are uploaded and reduced — cost proportional to the delta, not
        the table.
        """
        sigs = list(sigs)
        if not sigs:
            return []
        self._sync()
        if partition is not None:
            sub = self._partition_executor(*partition)
            out = sub.execute_batch(sigs)
            # the sub-executor is fresh: its counters are exactly this call's
            self._count(executions=sub.executions,
                        rows_scanned=sub.rows_scanned,
                        batch_calls=sub.batch_calls,
                        batch_groups=sub.batch_groups)
            return out
        if self._scan_active():
            return self._execute_batch_partitioned(sigs)
        self._count(batch_calls=1)
        out: list[Optional[ResultTable]] = [None] * len(sigs)
        if not self.fused:
            return [self.execute(s) for s in sigs]
        groups: dict[tuple, list[int]] = {}
        for i, s in enumerate(sigs):
            groups.setdefault((s.levels, s.measures), []).append(i)
        for (lvls, measures), idxs in groups.items():
            distinct = any(m.agg == "COUNT_DISTINCT" for m in measures)
            if not distinct:
                # predicates that need exact host masks can't share the
                # encoded-bounds scan; run those signatures individually
                exact = [i for i in idxs if self._sig_ranges(sigs[i]) is not None]
            else:
                exact = []
            for i in idxs:
                if i not in exact:
                    out[i] = self.execute(sigs[i])
            idxs = exact
            if len(idxs) == 1:
                out[idxs[0]] = self.execute(sigs[idxs[0]])
                continue
            if not idxs:
                continue
            self._count(batch_groups=1, executions=len(idxs),
                        rows_scanned=self.ds.fact.num_rows)  # one shared scan
            levels = [self._level_plan(lv) for lv in lvls]
            gids_np, n_groups, sparse_uniq = self._group_ids(levels)
            gids_dev = self._device_gids(lvls, gids_np)
            rect = self._rect_index(lvls, gids_np, n_groups)
            plan = self._measure_plan(measures)
            group_sigs = [sigs[i] for i in idxs]
            pred_block, bounds = self._batch_predicates(group_sigs)
            impl = None if self.impl == "auto" else self.impl
            sums_dev, mms_dev = seg_agg_batch_blocks(
                plan.sum_block, plan.minmax_block, gids_dev, pred_block,
                bounds, n_groups, impl=impl, rect_idx=rect)
            sums = np.asarray(sums_dev, np.float64)  # (S, G, 1+Ms)
            mms = None if mms_dev is None else np.asarray(mms_dev, np.float64)
            for s_i, i in enumerate(idxs):
                out[i] = self._finalize(
                    sigs[i], levels, plan, sums[s_i],
                    None if mms is None else mms[s_i],
                    gids_np, n_groups, sparse_uniq)
        return out  # type: ignore[return-value]

    def _partition_executor(self, start: int, end: int) -> "OlapExecutor":
        """Fresh executor over fact rows [start, end).  Each delta partition
        is scanned once per refresh, so the executor itself is not memoized —
        cross-tick reuse comes from the global jit cache (delta ticks of
        similar size hit the same compiled shapes via the pow2 rect padding)
        and from sharing the parent mirror's dimension uploads, so the tick
        uploads only delta-sized fact columns."""
        sub = OlapExecutor(self.ds.slice_rows(start, end),
                           impl=self.impl, fused=self.fused)
        if self.fused and self.ds._device is not None:
            sub.ds.device().share_dim_arrays(self.ds._device)
        return sub

    # ------------------------------------------------ partition-parallel scan
    def _scan_active(self) -> bool:
        """True when the scan plane handles full-table scans: multiple
        partitions requested, or the table exceeds the per-scan device-row
        budget (streaming).  Sub-executors are built with ``partitions=1``
        and no budget, so they never re-enter this path."""
        n = self.ds.fact.num_rows
        if n <= 0:
            return False
        if self.partitions > 1:
            return True
        return self.max_device_rows is not None and n > self.max_device_rows

    def _scan_plan(self) -> scan_plane.ScanPlan:
        with self._subs_lock:
            plan = self._plan_cache
            if plan is None:
                plan = scan_plane.plan_scan(
                    self.ds.fact.num_rows, self.partitions,
                    self.max_device_rows)
                self._plan_cache = plan
                with self._count_lock:
                    self._pstats = [
                        {"start": c[0][0], "end": c[-1][1], "rows_scanned": 0,
                         "executions": 0, "batch_groups": 0, "chunks": 0}
                        for c in plan.chunks]
            return plan

    def _scan_devices(self):
        """JAX devices for partition pinning — populated only when several
        exist and the fused device path is on; single-device hosts run the
        thread-pool path unpinned."""
        if self._devices is _UNSET:
            devs = None
            if self.fused:
                try:
                    import jax

                    local = jax.local_devices()
                    devs = local if len(local) > 1 else None
                except Exception:
                    devs = None
            self._devices = devs
        return self._devices

    def _pool(self) -> ThreadPoolExecutor:
        with self._subs_lock:
            if self._pool_obj is None:
                self._pool_obj = ThreadPoolExecutor(
                    max_workers=self.partitions,
                    thread_name_prefix="scan-part")
            return self._pool_obj

    def _execute_batch_partitioned(self, sigs: list) -> list:
        """Batch entry of the scan plane: partition-compatible signatures go
        through one partitioned scan (sharing per-partition scans exactly as
        the plain batch shares the full-table scan), the rest fall back to
        single-partition execution."""
        self._count(batch_calls=1)
        out: list[Optional[ResultTable]] = [None] * len(sigs)
        par = [i for i, s in enumerate(sigs)
               if scan_plane.partition_compatible(s)]
        rest = [i for i in range(len(sigs)) if i not in set(par)]
        if rest:
            with self._count_lock:
                self.partition_fallbacks += len(rest)
            for i in rest:
                self._count(executions=1,
                            rows_scanned=self.ds.fact.num_rows)
                out[i] = (self._execute_fused(sigs[i]) if self.fused
                          else self._execute_host(sigs[i]))
        if par:
            self._count(executions=len(par))
            for i, t in zip(par, self._execute_partitioned(
                    [sigs[i] for i in par])):
                out[i] = t
        return out  # type: ignore[return-value]

    def _execute_partitioned(self, sigs: list) -> list[ResultTable]:
        """Partition-parallel fused scan: decompose each signature into its
        composable partial form, scan every partition concurrently (streaming
        chunks sequentially inside each partition), merge the per-partition
        partial tables with the refresh algebra, finalize AVG from merged
        SUM/COUNT, and apply post-aggregation on the merged result."""
        plan = self._scan_plan()
        pplans = [scan_plane.decompose(s) for s in sigs]
        psigs = [p.partial_sig for p in pplans]
        with self._count_lock:
            self.partitioned_scans += 1
        devices = self._scan_devices()
        # capture the submitting thread's trace context so each partition
        # worker's span hangs off the request's execute span (obs plane);
        # None when the request is unsampled — adopt() is then a no-op
        obs_ctx = current_ctx()
        jobs = [
            self._pool().submit(
                self._scan_partition, p, chunks, psigs,
                devices[p % len(devices)] if devices else None, obs_ctx)
            for p, chunks in enumerate(plan.chunks)]
        partials = [j.result() for j in jobs]  # [partition][sig] tables
        out = []
        for i, (sig, pplan) in enumerate(zip(sigs, pplans)):
            merged = merge_partials(
                pplan.partial_sig, [part[i] for part in partials])
            out.append(self._post_aggregate(
                sig, scan_plane.finalize_partials(sig, pplan, merged)))
        return out

    def _scan_partition(self, p: int, chunks, psigs, dev,
                        obs_ctx=None) -> list[ResultTable]:
        """One partition job: scan its chunks in order, pre-merging the
        per-chunk partial tables (merge is associative and fold-order
        independent, so two-level partition-then-global merging is exact).
        ``dev`` pins all of the partition's uploads and launches to one JAX
        device via the thread-local default-device context."""
        with adopt(obs_ctx), child_span(
                "execute.partition",
                attrs={"partition": p, "chunks": len(chunks),
                       "sigs": len(psigs)}):
            # chaos: one partition worker fails while its siblings succeed —
            # the whole batch must error (a merge over missing partials would
            # be a silent wrong answer), and the caller's retry machinery
            # re-runs it
            faults.fire("backend.partial")
            if dev is not None:
                import jax

                with jax.default_device(dev):
                    return self._scan_chunks(p, chunks, psigs, dev)
            return self._scan_chunks(p, chunks, psigs, None)

    def _scan_chunks(self, p: int, chunks, psigs, dev) -> list[ResultTable]:
        streaming = len(chunks) > 1
        per_sig: list[list[ResultTable]] = [[] for _ in psigs]
        sub = self._chunk_sub(chunks[0], dev, resident=not streaming)
        for k in range(len(chunks)):
            stager = None
            next_sub = None
            if k + 1 < len(chunks):
                # double buffer: stage chunk k+1's device arrays while
                # chunk k scans
                next_sub = self._chunk_sub(chunks[k + 1], dev, resident=False)
                stager = _threading.Thread(
                    target=self._prestage, args=(next_sub, psigs, dev),
                    daemon=True)
                stager.start()
            with sub._scan_mutex:
                before = (sub.executions, sub.rows_scanned, sub.batch_groups)
                tables = sub.execute_batch(psigs)
                delta = (sub.executions - before[0],
                         sub.rows_scanned - before[1],
                         sub.batch_groups - before[2])
            for i, t in enumerate(tables):
                per_sig[i].append(t)
            self._note_partition(p, rows=delta[1], executions=delta[0],
                                 groups=delta[2], chunk_no=k)
            if streaming:
                self._release_chunk(sub)
            if stager is not None:
                stager.join()
            if next_sub is not None:
                sub = next_sub
        return [tl[0] if len(tl) == 1 else merge_partials(ps, tl)
                for ps, tl in zip(psigs, per_sig)]

    def _chunk_sub(self, rng: tuple[int, int], dev,
                   resident: bool) -> "OlapExecutor":
        """Sub-executor over fact rows [start, end).  Non-streaming
        partitions keep a resident sub (its memos and device arrays are the
        warm-scan fast path); streaming chunks get ephemeral subs whose
        device arrays are released after the scan.  Dimension uploads are
        shared through a per-device pool — dims never cross devices, but
        within a device every chunk of every partition reuses one upload."""
        if resident:
            with self._subs_lock:
                hit = self._subs.get(rng)
            if hit is not None:
                return hit
        sub = OlapExecutor(self.ds.slice_rows(*rng), impl=self.impl,
                           fused=self.fused, memo_cap=self._memo_cap)
        if self.fused:
            self._share_dims(sub, dev)
        if resident:
            with self._subs_lock:
                sub = self._subs.setdefault(rng, sub)
        return sub

    def _share_dims(self, sub: "OlapExecutor", dev) -> None:
        with self._subs_lock:
            pool = self._dim_pools.get(dev)
            if pool is None:
                # unpinned scans can share the parent mirror's live dimcol
                # store; pinned devices each get their own (device arrays
                # must not cross devices)
                pool = (self.ds.device()._dim_store if dev is None
                        else {})
                self._dim_pools[dev] = pool
        mirror = sub.ds.device()
        for k, v in mirror._dim_store.items():
            pool.setdefault(k, v)
        mirror._dim_store = pool

    def _release_chunk(self, sub: "OlapExecutor") -> None:
        """Drop an ephemeral streaming chunk's device arrays (its share of
        the dim pool survives — the pool dict is aliased, not owned)."""
        dev = sub.ds._device
        if dev is not None:
            dev._store.clear()
        sub.ds._device = None

    def _note_partition(self, p: int, rows: int, executions: int,
                        groups: int, chunk_no: int) -> None:
        with self._count_lock:
            self.rows_scanned += rows
            if chunk_no > 0:
                self.streaming_chunks += 1
            if p < len(self._pstats):
                st = self._pstats[p]
                st["rows_scanned"] += rows
                st["executions"] += executions
                st["batch_groups"] += groups
                st["chunks"] += 1

    def _prestage(self, sub: "OlapExecutor", psigs, dev) -> None:
        """Stager thread body: force the next chunk's fact-column uploads
        (level alignments, measure expressions, predicate columns) while the
        current chunk scans.  Advisory — any failure falls through to the
        scan's own lazy build."""
        try:
            if dev is not None:
                import jax

                with jax.default_device(dev):
                    self._stage_arrays(sub, psigs)
            else:
                self._stage_arrays(sub, psigs)
        except Exception:
            pass

    def _stage_arrays(self, sub: "OlapExecutor", psigs) -> None:
        if not sub.fused:
            return
        mirror = sub.ds.device()
        n = sub.ds.fact.num_rows
        mirror.cache(("ones",), lambda: np.ones(n, np.float32))
        date_col = sub.ds.schema.fact.date_column
        for s in psigs:
            for lv in s.levels:
                mirror.fact_aligned(lv)
            for m in s.measures:
                if m.expr != "*":
                    sub._dev_expr(m.expr)
            for f in s.filters:
                mirror.fact_aligned_f32(f.col)
            if s.time_window is not None and date_col is not None:
                mirror.fact_aligned_f32(f"{sub.ds.fact.name}.{date_col}")

    def execute_raw(self, sql: str) -> Optional[ResultTable]:
        """Bypass path: out-of-scope requests run directly on the backend.
        We execute what we can canonicalize; genuinely out-of-scope SQL is
        acknowledged (None) — its cost is still a backend execution."""
        try:
            sig = self._canon.canonicalize(sql)
        except (UnsupportedQuery, SQLSyntaxError, CanonicalizationError):
            self._count(executions=1, rows_scanned=self.ds.fact.num_rows)
            return None
        return self.execute(sig)

    # ------------------------------------------------------- fused (device)
    def _execute_fused(self, sig: Signature) -> ResultTable:
        levels = [self._level_plan(lv) for lv in sig.levels]
        gids_np, n_groups, sparse_uniq = self._group_ids(levels)
        gids_dev = self._device_gids(sig.levels, gids_np)
        rect = self._rect_index(sig.levels, gids_np, n_groups)
        plan = self._measure_plan(sig.measures)
        impl = None if self.impl == "auto" else self.impl
        enc = self._predicate_plan(sig)
        if enc is None:
            # some predicate can't be evaluated exactly in f32: build the
            # mask on host (exact, oracle-identical) and keep the fused
            # single-launch device aggregation
            mask = self._filter_mask(sig)
            sums = np.asarray(
                seg_agg_masked(plan.sum_block, gids_dev, mask, n_groups,
                               "sum", impl=impl, rect_idx=rect),
                np.float64)
            mm = None
            if plan.minmax_block is not None:
                mm = np.asarray(
                    seg_agg_masked(plan.minmax_block, gids_dev, mask, n_groups,
                                   "min", impl=impl, rect_idx=rect),
                    np.float64)
        else:
            pred_block, bounds = enc
            sums = np.asarray(
                seg_agg_fused(plan.sum_block, gids_dev, pred_block, bounds,
                              n_groups, "sum", impl=impl, rect_idx=rect),
                np.float64)
            mm = None
            if plan.minmax_block is not None:
                mm = np.asarray(
                    seg_agg_fused(plan.minmax_block, gids_dev, pred_block, bounds,
                                  n_groups, "min", impl=impl, rect_idx=rect),
                    np.float64)
        return self._finalize(sig, levels, plan, sums, mm, gids_np, n_groups,
                              sparse_uniq)

    def _finalize(self, sig, levels, plan, sums, mm, gids_np, n_groups,
                  sparse_uniq) -> ResultTable:
        """Assemble measures from the fused blocks and apply the shared
        host-side tail (empty-group drop, decode, HAVING/ORDER/LIMIT)."""
        count_col = sums[:, 0]
        host_mask = None  # built at most once, shared by all distinct specs
        out_measures: list[np.ndarray] = []
        for spec in plan.out_spec:
            kind = spec[0]
            if kind == "count":
                out_measures.append(count_col.copy())
            elif kind == "sumcol":
                out_measures.append(sums[:, spec[1]])
            elif kind == "avg":
                with np.errstate(invalid="ignore", divide="ignore"):
                    out_measures.append(
                        np.where(count_col > 0, sums[:, spec[1]] / count_col, np.nan))
            elif kind == "mincol":
                out_measures.append(mm[:, spec[1]])
            elif kind == "maxcol":
                out_measures.append(-mm[:, spec[1]])
            else:  # ('distinct', expr): host-side exact, rare
                if host_mask is None:
                    host_mask = self._filter_mask(sig)
                out_measures.append(self._count_distinct(
                    self._expr_values(spec[1]), gids_np, host_mask, n_groups))
        return self._build_result(sig, levels, count_col, out_measures, sparse_uniq)

    def _build_result(self, sig, levels, count_col, out_measures,
                      sparse_uniq) -> ResultTable:
        """Shared result tail for the fused and host paths: drop empty groups
        (SQL semantics: they are absent; global aggregates keep their single
        row), decode surviving group ids, then HAVING/ORDER/LIMIT."""
        keep = count_col > 0
        if not sig.levels:
            keep = np.ones(1, dtype=bool)
        cols: dict[str, np.ndarray] = {}
        if levels:
            group_idx = np.nonzero(keep)[0]
            decoded = self._decode_groups(levels, group_idx, sparse_uniq)
            for lv, vals in zip(levels, decoded):
                cols[lv.name] = vals
        for i, mvals in enumerate(out_measures):
            cols[f"m{i}"] = mvals[keep] if sig.levels else mvals
        return self._post_aggregate(sig, ResultTable(cols))

    def _device_gids(self, levels_key: tuple, gids_np: np.ndarray):
        return self.dev.cache(("gids", levels_key), lambda: gids_np)

    # rect layout gate: padded size must stay close to N (skew guard) and
    # below an absolute element cap (memory guard)
    _RECT_MAX_BLOWUP = 2.0
    _RECT_MIN_CELLS = 1 << 16  # always allow tiny group spaces
    _RECT_MAX_CELLS = 1 << 25

    def _rect_index(self, levels_key: tuple, gids_np: np.ndarray, n_groups: int):
        """Cached (n_groups, R) row-index rectangle for a level combination:
        row g lists the fact rows of group g, padded with the out-of-range
        index N.  Lets the XLA path reduce with a vectorized gather instead
        of a serial scatter; None when group sizes are too skewed (padding
        blowup) or the padded matrix would be too large."""
        key = ("rectidx", levels_key)
        if key in self._rect_cache:
            return self._rect_cache[key]
        n = len(gids_np)
        counts = np.bincount(gids_np, minlength=n_groups)
        r0 = int(counts.max()) if n_groups else 0
        # pad R to a power of two: repeated delta scans (appends of similar
        # size) then hit the same jitted shapes instead of recompiling per
        # tick; pad cells hold the out-of-range index and read as identity.
        # Padding must respect the same work budget as the skew guard — when
        # the padded rectangle would blow past it, keep the exact R (shape
        # stability lost for that combination, work bound kept).
        r = 1 << (r0 - 1).bit_length() if r0 > 0 else 0
        if n_groups * r > max(self._RECT_MIN_CELLS, self._RECT_MAX_BLOWUP * n) \
                or n_groups * r > self._RECT_MAX_CELLS:
            r = r0  # padding alone must never disqualify a layout
        cells = n_groups * r0
        ok = r0 > 0 and n_groups * r <= self._RECT_MAX_CELLS and (
            cells <= self._RECT_MIN_CELLS or cells <= self._RECT_MAX_BLOWUP * n)
        if not ok:
            self._rect_cache[key] = None
            return None
        order = np.argsort(gids_np, kind="stable")
        starts = np.concatenate([[0], np.cumsum(counts[:-1])])
        sorted_gids = gids_np[order]
        pos = np.arange(n) - starts[sorted_gids]
        idx = np.full((n_groups, r), n, np.int32)
        idx[sorted_gids, pos] = order
        dev_idx = self.dev.cache(key, lambda: idx)
        self._rect_cache[key] = dev_idx
        return dev_idx

    def _measure_plan(self, measures: tuple) -> _MeasurePlan:
        plan = self._mplans.get(measures)
        if plan is not None:
            return plan
        jnp = self.dev._jnp
        n = self.ds.fact.num_rows
        ones = self.dev.cache(("ones",), lambda: np.ones(n, np.float32))
        sum_cols = [ones]
        sum_keys: list[tuple] = [("ones",)]
        mm_cols: list = []
        mm_keys: list[tuple] = []
        out_spec: list[tuple] = []
        for m in measures:
            if m.agg == "COUNT_DISTINCT":
                out_spec.append(("distinct", m.expr))
            elif m.agg == "COUNT":
                if m.expr == "*":
                    out_spec.append(("count",))
                else:
                    out_spec.append(("sumcol", len(sum_cols)))
                    sum_keys.append(("finite", m.expr))
                    sum_cols.append(self.dev.cache(
                        ("finite", m.expr),
                        lambda e=m.expr: jnp.isfinite(self._dev_expr(e)).astype(jnp.float32)))
            elif m.agg in ("SUM", "AVG"):
                out_spec.append(("sumcol" if m.agg == "SUM" else "avg", len(sum_cols)))
                sum_keys.append(("expr", m.expr))
                sum_cols.append(self._dev_expr(m.expr))
            elif m.agg == "MIN":
                out_spec.append(("mincol", len(mm_cols)))
                mm_keys.append(("expr", m.expr))
                mm_cols.append(self._dev_expr(m.expr))
            else:  # MAX: negate so MIN and MAX share one 'min' launch
                out_spec.append(("maxcol", len(mm_cols)))
                mm_keys.append(("negexpr", m.expr))
                mm_cols.append(self.dev.cache(
                    ("negexpr", m.expr), lambda e=m.expr: -self._dev_expr(e)))
        sum_key = ("sumblock", tuple(sum_keys))
        sum_block = self.dev.cache(sum_key, lambda: jnp.stack(sum_cols, axis=1))
        mm_block, mm_key = None, None
        if mm_cols:
            mm_key = ("mmblock", tuple(mm_keys))
            mm_block = self.dev.cache(
                mm_key, lambda: jnp.stack(mm_cols, axis=1))
        plan = _MeasurePlan(sum_block, mm_block, out_spec, sum_key, mm_key)
        self._mplans[measures] = plan
        return plan

    def _dev_expr(self, expr: str):
        """Measure expression evaluated on-device (f32) from uploaded base
        columns, memoized per canonical expression string."""

        def build():
            jnp = self.dev._jnp
            ast = sp.parse_expr(expr)

            def ev(e):
                if isinstance(e, sp.ColRef):
                    q = f"{e.table}.{e.column}" if e.table else e.column
                    return self.dev.fact_aligned_f32(q)
                if isinstance(e, sp.Literal):
                    return float(e.value)
                if isinstance(e, sp.BinOp):
                    left, right = ev(e.left), ev(e.right)
                    if e.op == "+":
                        return left + right
                    if e.op == "-":
                        return left - right
                    if e.op == "*":
                        return left * right
                    return left / right
                raise ValueError(f"unexpected node in measure expression: {e}")

            v = ev(ast)
            if np.isscalar(v):
                return np.full(self.ds.fact.num_rows, v, dtype=np.float32)
            return jnp.asarray(v, jnp.float32)

        return self.dev.cache(("expr", expr), build)

    # ----------------------------------------------------- predicate encode
    def _f32_exact_col(self, qualified: str) -> bool:
        """True when every physical value of the column round-trips through
        f32 exactly (dictionary codes and date-days always do; int/float
        columns are checked once and cached).  Predicates over inexact
        columns fall back to the host-evaluated mask — the encoded-bounds
        comparison runs in f32 on device and must never diverge from the
        oracle's exact comparisons."""
        hit = self._exact_cols.get(qualified)
        if hit is None:
            data = self.ds.column(qualified).data
            if data.dtype.kind in "iu":
                hit = bool(np.all(np.abs(data) <= (1 << 24)))
            else:
                v32 = data.astype(np.float32).astype(data.dtype)
                hit = bool(np.all(v32 == data))  # NaN present -> inexact
            self._exact_cols[qualified] = hit
        return hit

    @staticmethod
    def _f32_exact_value(v: float) -> bool:
        return bool(np.isfinite(v)) and float(np.float32(v)) == float(v)

    def _filter_ranges(self, f) -> Optional[list[tuple[float, float]]]:
        """Encode one filter as a disjunction of inclusive f32 [lo, hi]
        ranges over the column's physical domain (str -> dictionary code,
        date -> days).  Open endpoints use f32 nextafter, which is exact
        because both column values and literals are gated to the f32 lattice
        — None when the column or a literal is not exactly representable
        (caller falls back to the host mask)."""
        if not self._f32_exact_col(f.col):
            return None
        col = self.ds.column(f.col)

        def enc(v) -> Optional[float]:
            pv = float(col.encode_value(v))
            return pv if self._f32_exact_value(pv) else None

        def down(v: float) -> float:
            return float(np.nextafter(np.float32(v), np.float32(-np.inf)))

        def up(v: float) -> float:
            return float(np.nextafter(np.float32(v), np.float32(np.inf)))

        if f.op == "in":
            vals = f.val if isinstance(f.val, (list, tuple)) else [f.val]
            encs = [enc(v) for v in vals]
            if any(e is None for e in encs):
                return None
            return [(e, e) for e in encs]
        v = enc(f.val)
        if v is None:
            return None
        if f.op == "=":
            return [(v, v)]
        if f.op == "!=":
            # NaN sentinel range: numpy semantics keep NaN rows (NaN != v)
            return [(-np.inf, down(v)), (up(v), np.inf), (np.nan, np.nan)]
        if f.op == "<":
            return [(-np.inf, down(v))]
        if f.op == "<=":
            return [(-np.inf, v)]
        if f.op == ">":
            return [(up(v), np.inf)]
        return [(v, np.inf)]  # >=

    def _window_range(self, tw) -> Optional[tuple[str, tuple[float, float]]]:
        date_col = self.ds.schema.fact.date_column
        if date_col is None:
            return None
        qualified = f"{self.ds.fact.name}.{date_col}"
        # [start, end) on int days -> inclusive [start, end-1]
        return qualified, (float(date_to_days(tw.start)),
                           float(date_to_days(tw.end) - 1))

    def _sig_ranges(self, sig: Signature) -> Optional[list[tuple[str, list]]]:
        """Per-predicate (column, ranges) pairs for one signature; None when
        any predicate can't be encoded exactly in f32 (the caller evaluates
        the mask on host instead)."""
        out = []
        for f in sig.filters:
            r = self._filter_ranges(f)
            if r is None:
                return None
            out.append((f.col, r))
        if sig.time_window is not None:
            wr = self._window_range(sig.time_window)
            if wr is not None:
                out.append((wr[0], [wr[1]]))
        return out

    def _accept_all(self, qualified: str) -> list[tuple[float, float]]:
        """Range disjunction matching every row of a column (batch filler
        for signatures that don't constrain it)."""
        hit = self._nan_cols.get(qualified)
        if hit is None:
            data = self.ds.column(qualified).data
            hit = bool(data.dtype.kind == "f" and np.isnan(data).any())
            self._nan_cols[qualified] = hit
        if hit:
            return [(-np.inf, np.inf), (np.nan, np.nan)]
        return [(-np.inf, np.inf)]

    def _pred_block(self, cols: tuple):
        jnp = self.dev._jnp
        n = self.ds.fact.num_rows
        if not cols:
            return self.dev.cache(
                ("preds", ()), lambda: np.zeros((n, 0), np.float32))
        return self.dev.cache(
            ("preds", cols),
            lambda: jnp.stack([self.dev.fact_aligned_f32(c) for c in cols], axis=1))

    def _predicate_plan(self, sig: Signature):
        """Device predicate-column stack (cached per column tuple) plus this
        query's (P, K, 2) bounds (tiny, host-encoded per query); None when
        the predicates need exact host evaluation."""
        pairs = self._sig_ranges(sig)
        if pairs is None:
            return None
        cols = tuple(c for c, _ in pairs)
        return self._pred_block(cols), _pack_bounds([r for _, r in pairs])

    def _batch_predicates(self, sigs: list[Signature]):
        """Union predicate columns across the batch; per-signature bounds
        with multiple predicates on one column intersected into a single
        range disjunction, unconstrained columns spanning everything."""
        per_sig: list[dict[str, list]] = []
        union: list[str] = []
        for s in sigs:
            d: dict[str, list] = {}
            for col, ranges in self._sig_ranges(s):
                d[col] = _intersect_ranges(d[col], ranges) if col in d else ranges
                if col not in union:
                    union.append(col)
            per_sig.append(d)
        cols = tuple(union)
        if not cols:
            # no predicates anywhere: one always-true pseudo-predicate over a
            # zeros column (zeros are never NaN, a plain full range suffices)
            bounds = np.empty((len(sigs), 1, 1, 2), np.float32)
            bounds[..., 0], bounds[..., 1] = -np.inf, np.inf
            block = self.dev.cache(
                ("preds", ("__zeros__",)),
                lambda: np.zeros((self.ds.fact.num_rows, 1), np.float32))
            return block, bounds
        # a column some other signature filters must accept *every* row here:
        # full range, plus the NaN sentinel only when the column can actually
        # hold NaNs (int/dictionary/date columns never do — skipping the
        # sentinel keeps the packed K small and the batched mask pass cheap)
        packed = [_pack_bounds([d.get(c, self._accept_all(c)) for c in cols])
                  for d in per_sig]
        k = max(b.shape[1] for b in packed)
        bounds = np.empty((len(sigs), len(cols), k, 2), np.float32)
        bounds[..., 0], bounds[..., 1] = _NEVER
        for s_i, b in enumerate(packed):
            bounds[s_i, :, : b.shape[1]] = b
        return self._pred_block(cols), bounds

    # ------------------------------------------------- legacy host baseline
    def _execute_host(self, sig: Signature) -> ResultTable:
        """Seed per-measure path: host numpy masks/expressions, one seg_agg
        launch per measure (plus the COUNT column).  ``impl='numpy'`` makes
        this the independent oracle; other impls keep it as the perf
        baseline that ``benchmarks/bench_backend.py`` measures against."""
        n = self.ds.fact.num_rows
        mask = self._filter_mask(sig)
        levels = [self._level_plan(lv) for lv in sig.levels]
        gids, n_groups, sparse_uniq = self._group_ids(levels)

        count_col = self._aggregate(np.ones((n, 1), np.float32), gids, mask, n_groups, "sum")[:, 0]
        out_measures: list[np.ndarray] = []
        for m in sig.measures:
            if m.agg == "COUNT" and not m.distinct:
                if m.expr == "*":
                    out_measures.append(count_col.copy())
                else:
                    vals = np.isfinite(self._expr_values(m.expr)).astype(np.float32)
                    out_measures.append(
                        self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0]
                    )
                continue
            if m.distinct:  # COUNT(DISTINCT expr): host-side exact
                out_measures.append(
                    self._count_distinct(self._expr_values(m.expr), gids, mask, n_groups)
                )
                continue
            vals = self._expr_values(m.expr).astype(np.float32)
            if m.agg == "AVG":
                s = self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0]
                with np.errstate(invalid="ignore", divide="ignore"):
                    out_measures.append(np.where(count_col > 0, s / count_col, np.nan))
            elif m.agg == "SUM":
                out_measures.append(
                    self._aggregate(vals[:, None], gids, mask, n_groups, "sum")[:, 0].astype(np.float64)
                )
            else:  # MIN / MAX
                out_measures.append(
                    self._aggregate(vals[:, None], gids, mask, n_groups, m.agg.lower())[:, 0]
                )

        return self._build_result(sig, levels, count_col, out_measures, sparse_uniq)

    # ------------------------------------------------------------ internals
    def _aggregate(self, values, gids, mask, n_groups, op):
        if self.impl == "numpy":
            return _np_segment(values, gids, mask, n_groups, op)
        impl = None if self.impl == "auto" else self.impl
        return np.asarray(seg_agg(values, gids, mask.astype(np.float32), n_groups, op, impl=impl))

    def _filter_mask(self, sig: Signature) -> np.ndarray:
        n = self.ds.fact.num_rows
        mask = np.ones(n, dtype=bool)
        for f in sig.filters:
            col = self.ds.column(f.col)
            vals = self.ds.fact_aligned(f.col)
            if f.op == "in":
                phys = [col.encode_value(v) for v in (f.val if isinstance(f.val, (list, tuple)) else [f.val])]
                mask &= np.isin(vals, phys)
                continue
            pv = col.encode_value(f.val)
            if f.op == "=":
                mask &= vals == pv
            elif f.op == "!=":
                mask &= vals != pv
            elif f.op == "<":
                mask &= vals < pv
            elif f.op == "<=":
                mask &= vals <= pv
            elif f.op == ">":
                mask &= vals > pv
            elif f.op == ">=":
                mask &= vals >= pv
        tw = sig.time_window
        if tw is not None:
            date_col = self.ds.schema.fact.date_column
            if date_col is not None:
                days = self.ds.fact.columns[date_col].data
                mask &= (days >= date_to_days(tw.start)) & (days < date_to_days(tw.end))
        return mask

    def _level_plan(self, level: str) -> _LevelPlan:
        lp = self._level_cache.get(level)
        if lp is not None:
            return lp
        aligned = self.ds.fact_aligned(level)
        t, c = level.split(".", 1)
        table_col = self.ds.table(t).columns[c]
        uniques = np.unique(table_col.data)
        codes = np.searchsorted(uniques, aligned).astype(np.int32)
        lp = _LevelPlan(level, codes, uniques, len(uniques))
        self._level_cache[level] = lp
        return lp

    def _group_ids(self, levels: list[_LevelPlan]) -> tuple[np.ndarray, int, Optional[np.ndarray]]:
        """Dense (or compacted-sparse) group ids for a level combination.

        Returns ``(gids, n_groups, sparse_uniq)`` — ``sparse_uniq`` is the
        observed-group compaction table (None on the dense path) and is
        threaded through to ``_decode_groups`` by the caller instead of
        living in mutable instance state (stale/racy across calls).
        Memoized per level combination: the mapping depends only on the
        dataset, not on the query's filters.
        """
        n = self.ds.fact.num_rows
        if not levels:
            return np.zeros(n, dtype=np.int32), 1, None
        cache_key = tuple(lp.name for lp in levels)
        hit = self._gids_cache.get(cache_key)
        if hit is not None:
            return hit
        g = 1
        gids = np.zeros(n, dtype=np.int64)
        for lp in levels:
            gids = gids * lp.card + lp.codes
            g *= lp.card
        if g > MAX_DENSE_GROUPS:
            # compact the observed group space (rare for dashboard queries)
            uniq, gids = np.unique(gids, return_inverse=True)
            result = (gids.astype(np.int32), len(uniq), uniq)
        else:
            result = (gids.astype(np.int32), g, None)
        self._gids_cache[cache_key] = result
        return result

    def _decode_groups(self, levels: list[_LevelPlan], group_idx: np.ndarray,
                       sparse_uniq: Optional[np.ndarray] = None):
        """Map surviving dense group ids back to per-level decoded values."""
        if sparse_uniq is not None:
            group_idx = sparse_uniq[group_idx]
        out = []
        rem = group_idx.astype(np.int64)
        cards = [lp.card for lp in levels]
        comps: list[np.ndarray] = []
        for card in reversed(cards):
            comps.append(rem % card)
            rem = rem // card
        comps.reverse()
        for lp, comp in zip(levels, comps):
            t, c = lp.name.split(".", 1)
            col = self.ds.table(t).columns[c]
            out.append(col.decode(lp.uniques[comp]))
        return out

    def _expr_values(self, expr: str) -> np.ndarray:
        ast = sp.parse_expr(expr)

        def ev(e) -> np.ndarray | float:
            if isinstance(e, sp.ColRef):
                q = f"{e.table}.{e.column}" if e.table else e.column
                return self.ds.fact_aligned(q).astype(np.float64)
            if isinstance(e, sp.Literal):
                return float(e.value)
            if isinstance(e, sp.BinOp):
                l, r = ev(e.left), ev(e.right)
                if e.op == "+":
                    return l + r
                if e.op == "-":
                    return l - r
                if e.op == "*":
                    return l * r
                return l / r
            raise ValueError(f"unexpected node in measure expression: {e}")

        v = ev(ast)
        if np.isscalar(v):
            v = np.full(self.ds.fact.num_rows, v, dtype=np.float64)
        return v

    def _count_distinct(self, vals, gids, mask, n_groups) -> np.ndarray:
        sel = mask
        pairs = np.stack([gids[sel].astype(np.int64), vals[sel].astype(np.int64)], axis=1)
        uniq = np.unique(pairs, axis=0)
        out = np.zeros(n_groups, dtype=np.float64)
        np.add.at(out, uniq[:, 0], 1.0)
        return out

    def _post_aggregate(self, sig: Signature, table: ResultTable) -> ResultTable:
        for h in sig.having:
            col = table.columns[f"m{h.measure}"]
            from ..core.table import eval_predicate

            table = table.mask(eval_predicate(col, h.op, h.val))
        if sig.order_by:
            keys = []
            for o in sig.order_by:
                name = f"m{o.key.split(':', 1)[1]}" if o.key.startswith("measure:") else o.key
                keys.append((name, o.desc))
            table = table.sort(keys)
        if sig.limit is not None:
            table = table.head(sig.limit)
        return table


def _pack_bounds(ranges: list[list[tuple[float, float]]]) -> np.ndarray:
    """Pack per-predicate range lists into a (P, K, 2) f32 bounds tensor,
    K padded to a power of two (fewer distinct jit shapes) with never-match
    pad ranges."""
    p = len(ranges)
    if p == 0:
        return np.zeros((0, 1, 2), np.float32)
    k = max(1, max(len(r) for r in ranges))
    k = 1 << (k - 1).bit_length()
    out = np.empty((p, k, 2), np.float32)
    out[..., 0], out[..., 1] = _NEVER
    for i, r in enumerate(ranges):
        for j, (lo, hi) in enumerate(r):
            out[i, j] = (lo, hi)
    return out


def _intersect_ranges(a: list, b: list) -> list:
    """Intersection of two inclusive range disjunctions (AND of ORs back to
    one OR list); empty result means the conjunction is unsatisfiable.
    NaN-sentinel ranges (see ``bounds_mask_ref``) survive only when both
    sides carry one — NaN passes a conjunction iff every predicate admits
    NaN."""

    def split(rs):
        return ([r for r in rs if not np.isnan(r[0])],
                [r for r in rs if np.isnan(r[0])])

    a_num, a_nan = split(a)
    b_num, b_nan = split(b)
    out = []
    for lo1, hi1 in a_num:
        for lo2, hi2 in b_num:
            lo, hi = max(lo1, lo2), min(hi1, hi2)
            if lo <= hi:
                out.append((lo, hi))
    if a_nan and b_nan:
        out.append((np.nan, np.nan))
    return out


def _np_segment(values, gids, mask, n_groups, op) -> np.ndarray:
    """Independent numpy oracle for the segment reduce (no JAX involved).

    MIN/MAX are NaN-aware the same way the kernels' fillers are (via the
    shared numpy-only ``_extreme_at``): NaN rows are masked out of the
    ``.at`` scatter and their groups re-poisoned afterwards — a qualifying
    NaN row still yields a NaN group, matching the device path's NaN
    propagation, warning-free."""
    from ..core.derivations import _extreme_at

    values = np.asarray(values, np.float64)
    m = values.shape[1]
    sel = np.asarray(mask, bool)
    g = gids[sel]
    v = values[sel]
    if op == "sum":
        out = np.zeros((n_groups, m))
        for j in range(m):
            np.add.at(out[:, j], g, v[:, j])
        return out
    out = np.full((n_groups, m), np.inf if op == "min" else -np.inf)
    for j in range(m):
        _extreme_at(op.upper(), v[:, j], g, out[:, j])
    return out
