"""Columnar star-schema datasets (the backend's storage layer).

TPU-friendly representation: every column is a flat numpy array; string
columns are dictionary-encoded (int32 codes + vocab) so the JAX executor works
purely on integer/float arrays; dates are int32 days-since-epoch.  Dimension
primary keys are row positions (0..n-1) by construction, so a fact->dimension
join is a single gather by the foreign-key column.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
from typing import Optional

import numpy as np

from ..core.schema import StarSchema

_EPOCH = _dt.date(1970, 1, 1)


def date_to_days(iso: str) -> int:
    return (_dt.date.fromisoformat(str(iso)) - _EPOCH).days


def days_to_date(days: int) -> str:
    return (_EPOCH + _dt.timedelta(days=int(days))).isoformat()


@dataclasses.dataclass
class ColumnData:
    dtype: str  # 'int' | 'float' | 'str' | 'date'
    data: np.ndarray  # numeric values, int32 codes (str), int32 days (date)
    vocab: Optional[np.ndarray] = None  # str columns: code -> string

    def __post_init__(self):
        if self.dtype == "str" and self.vocab is None:
            # dictionary-encode on construction
            vocab, codes = np.unique(np.asarray(self.data, dtype=str), return_inverse=True)
            self.vocab = vocab
            self.data = codes.astype(np.int32)
        elif self.dtype == "date" and self.data.dtype.kind in ("U", "O"):
            self.data = np.asarray([date_to_days(d) for d in self.data], dtype=np.int32)

    @property
    def n(self) -> int:
        return len(self.data)

    def encode_value(self, v):
        """Map a literal to the physical domain (string->code, date->days)."""
        if self.dtype == "str":
            idx = np.searchsorted(self.vocab, str(v))
            if idx < len(self.vocab) and self.vocab[idx] == str(v):
                return int(idx)
            return -1  # value absent: matches nothing
        if self.dtype == "date":
            return date_to_days(v)
        return v

    def decode(self, physical: np.ndarray) -> np.ndarray:
        if self.dtype == "str":
            return self.vocab[physical]
        if self.dtype == "date":
            return np.asarray([days_to_date(d) for d in physical])
        return physical


@dataclasses.dataclass
class TableData:
    name: str
    columns: dict[str, ColumnData]

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).n if self.columns else 0


@dataclasses.dataclass
class Dataset:
    schema: StarSchema
    fact: TableData
    dims: dict[str, TableData]
    snapshot_id: str = "snap0"
    _device: Optional["DeviceDataset"] = dataclasses.field(
        default=None, repr=False, compare=False)

    def device(self) -> "DeviceDataset":
        """The shared device-resident mirror (created on first use, so the
        numpy-oracle path never imports JAX)."""
        if self._device is None:
            self._device = DeviceDataset(self)
        return self._device

    # ------------------------------------------------------------- accessors
    def table(self, name: str) -> TableData:
        if name == self.fact.name:
            return self.fact
        return self.dims[name]

    def column(self, qualified: str) -> ColumnData:
        t, c = qualified.split(".", 1)
        return self.table(t).columns[c]

    def fact_aligned(self, qualified: str) -> np.ndarray:
        """Physical values of ``table.column`` aligned to fact rows (dimension
        columns are gathered through the FK; pk == row position)."""
        t, c = qualified.split(".", 1)
        if t == self.fact.name:
            return self.fact.columns[c].data
        dim = self.schema.dimension(t)
        fk = self.fact.columns[dim.fact_fk].data
        return self.dims[t].columns[c].data[fk]

    # --------------------------------------------------------- hierarchy map
    def level_mapper(self):
        """Build the LevelMapper used by roll-up derivations: maps fine-level
        *decoded* values to coarse-level decoded values via the dim table."""

        def mapper(dim_name: str, fine: str, coarse: str, fine_values: np.ndarray):
            dim = self.dims.get(dim_name)
            if dim is None:
                return None
            fc, cc = dim.columns.get(fine), dim.columns.get(coarse)
            if fc is None or cc is None:
                return None
            fine_dec = fc.decode(fc.data)
            coarse_dec = cc.decode(cc.data)
            lut: dict = {}
            for f, c in zip(fine_dec, coarse_dec):
                prev = lut.get(f)
                if prev is not None and prev != c:
                    return None  # not summarizable: child with two parents
                lut[f] = c
            try:
                return np.asarray([lut[v] for v in fine_values])
            except KeyError:
                return None

        return mapper

    def upload_time_ms(self) -> float:
        """Milliseconds spent so far uploading/deriving device arrays."""
        return self._device.upload_ms if self._device is not None else 0.0

    def validate_hierarchies(self) -> list[str]:
        """Check declared-summarizable hierarchies are functional in the data."""
        problems = []
        for d in self.schema.dimensions:
            td = self.dims.get(d.name)
            if td is None:
                continue
            for h in d.hierarchies:
                if not h.summarizable:
                    continue
                for fine, coarse in zip(h.levels, h.levels[1:]):
                    fc, cc = td.columns.get(fine), td.columns.get(coarse)
                    if fc is None or cc is None:
                        continue
                    pairs = {}
                    for f, c in zip(fc.data, cc.data):
                        if pairs.setdefault(int(f), int(c)) != int(c):
                            problems.append(f"{d.name}: {fine}->{coarse} not functional")
                            break
        return problems


class DeviceDataset:
    """Device-resident mirror of a :class:`Dataset` — the JAX executor's
    storage layer.

    Fact columns, dimension columns, and FK gathers are uploaded to the
    accelerator *once per dataset* and memoized; derived arrays (fact-aligned
    f32 casts, level codes, group-id vectors, fused measure blocks, predicate
    column stacks) are computed on-device and memoized under caller-chosen
    keys via :meth:`cache`.  The host numpy ``Dataset`` is untouched and
    remains the fallback for the independent numpy oracle
    (``OlapExecutor(impl='numpy')``).
    """

    def __init__(self, dataset: Dataset):
        import time as _time

        import jax.numpy as jnp  # lazy: the host path never needs JAX

        self._jnp = jnp
        self._time = _time
        self.ds = dataset
        self._store: dict = {}
        self.upload_ms = 0.0
        self._timing_depth = 0

    @property
    def num_rows(self) -> int:
        return self.ds.fact.num_rows

    def cache(self, key, build):
        """Memoized device array: ``build()`` may return a host numpy array
        (uploaded) or a jnp array (kept as-is).  Keys are caller-namespaced
        tuples, e.g. ``('aligned', 'customer.c_region')``."""
        v = self._store.get(key)
        if v is None:
            # only the outermost frame accrues upload_ms: builders call
            # cache() recursively (aligned -> col/dimcol) and the inner
            # elapsed is already inside the outer measurement
            t0 = self._time.perf_counter()
            self._timing_depth += 1
            try:
                v = self._jnp.asarray(build())
                v.block_until_ready()
            finally:
                self._timing_depth -= 1
            if self._timing_depth == 0:
                self.upload_ms += (self._time.perf_counter() - t0) * 1e3
            self._store[key] = v
        return v

    def fact_aligned(self, qualified: str):
        """Device array of ``table.column`` aligned to fact rows; dimension
        columns are gathered through the FK *on device* (upload the dim column
        and the FK once, gather once, cache the result)."""

        def build():
            t, c = qualified.split(".", 1)
            if t == self.ds.fact.name:
                return self.ds.fact.columns[c].data
            dim = self.ds.schema.dimension(t)
            fk = self.cache(
                ("col", f"{self.ds.fact.name}.{dim.fact_fk}"),
                lambda: self.ds.fact.columns[dim.fact_fk].data,
            )
            dcol = self.cache(
                ("dimcol", t, c), lambda: self.ds.dims[t].columns[c].data
            )
            return dcol[fk]

        return self.cache(("aligned", qualified), build)

    def fact_aligned_f32(self, qualified: str):
        return self.cache(
            ("aligned32", qualified),
            lambda: self.fact_aligned(qualified).astype(self._jnp.float32),
        )

    def nbytes(self) -> int:
        return int(sum(getattr(v, "nbytes", 0) for v in self._store.values()))
