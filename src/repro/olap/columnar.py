"""Columnar star-schema datasets (the backend's storage layer).

TPU-friendly representation: every column is a flat numpy array; string
columns are dictionary-encoded (int32 codes + vocab) so the JAX executor works
purely on integer/float arrays; dates are int32 days-since-epoch.  Dimension
primary keys are row positions (0..n-1) by construction, so a fact->dimension
join is a single gather by the foreign-key column.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
from typing import Optional

import numpy as np

from ..core.schema import StarSchema

_EPOCH = _dt.date(1970, 1, 1)


def date_to_days(iso: str) -> int:
    return (_dt.date.fromisoformat(str(iso)) - _EPOCH).days


def days_to_date(days: int) -> str:
    return (_EPOCH + _dt.timedelta(days=int(days))).isoformat()


@dataclasses.dataclass
class ColumnData:
    dtype: str  # 'int' | 'float' | 'str' | 'date'
    data: np.ndarray  # numeric values, int32 codes (str), int32 days (date)
    vocab: Optional[np.ndarray] = None  # str columns: code -> string

    def __post_init__(self):
        if self.dtype == "str" and self.vocab is None:
            # dictionary-encode on construction
            vocab, codes = np.unique(np.asarray(self.data, dtype=str), return_inverse=True)
            self.vocab = vocab
            self.data = codes.astype(np.int32)
        elif self.dtype == "date" and self.data.dtype.kind in ("U", "O"):
            self.data = np.asarray([date_to_days(d) for d in self.data], dtype=np.int32)

    @property
    def n(self) -> int:
        return len(self.data)

    def encode_value(self, v):
        """Map a literal to the physical domain (string->code, date->days)."""
        if self.dtype == "str":
            idx = np.searchsorted(self.vocab, str(v))
            if idx < len(self.vocab) and self.vocab[idx] == str(v):
                return int(idx)
            return -1  # value absent: matches nothing
        if self.dtype == "date":
            return date_to_days(v)
        return v

    def _appended(self, values: np.ndarray) -> tuple[np.ndarray, Optional[np.ndarray]]:
        """Pure form of :meth:`append`: the (data, vocab) this column would
        hold after appending ``values`` — nothing is assigned, so callers can
        stage every column's conversion (which may raise on bad input)
        before committing any of them."""
        values = np.asarray(values)
        if self.dtype == "str":
            new = np.asarray(values, dtype=str)
            if len(self.vocab):
                codes = np.searchsorted(self.vocab, new)
                codes = np.clip(codes, 0, len(self.vocab) - 1)
                if bool(np.all(self.vocab[codes] == new)):
                    return (np.concatenate([self.data, codes.astype(np.int32)]),
                            self.vocab)
            # unseen values: re-encode everything, because ``encode_value``
            # binary-searches a *sorted* vocab
            decoded = self.vocab[self.data] if len(self.vocab) else self.data.astype(str)
            vocab, codes = np.unique(np.concatenate([decoded, new]), return_inverse=True)
            return codes.astype(np.int32), vocab
        if self.dtype == "date" and values.dtype.kind in ("U", "O"):
            values = np.asarray([date_to_days(d) for d in values], dtype=np.int32)
        cast = values.astype(self.data.dtype, copy=False)
        if self.data.dtype.kind in "iu" and values.dtype.kind in "fiu" \
                and not np.array_equal(cast.astype(values.dtype), values):
            # fractional/NaN/overflowing values for an int column: reject
            # like every other malformed delta instead of silently
            # truncating or wrapping
            raise ValueError(
                f"lossy cast: {values.dtype} values do not fit the column's "
                f"{self.data.dtype} domain exactly")
        return np.concatenate([self.data, cast]), self.vocab

    def append(self, values: np.ndarray) -> None:
        """Append raw (decoded-domain) values in place — the streaming-ingest
        path.  Dates accept ISO strings or int days; strings re-encode the
        whole column when the delta carries unseen values."""
        self.data, self.vocab = self._appended(values)

    def decode(self, physical: np.ndarray) -> np.ndarray:
        if self.dtype == "str":
            return self.vocab[physical]
        if self.dtype == "date":
            return np.asarray([days_to_date(d) for d in physical])
        return physical


@dataclasses.dataclass
class TableData:
    name: str
    columns: dict[str, ColumnData]

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).n if self.columns else 0


@dataclasses.dataclass(frozen=True)
class Partition:
    """Row-range metadata for one ingest batch of the fact table.

    ``[start_row, end_row)`` are fact row positions; ``[date_start,
    date_end)`` is the batch's time extent on the schema's date column (ISO,
    end exclusive; None when the schema has no date column).  The cache's
    §6.2 refresh rule keys off the date extent; the executor's delta scan
    keys off the row range.
    """

    start_row: int
    end_row: int
    date_start: Optional[str] = None
    date_end: Optional[str] = None
    snapshot_id: str = ""

    @property
    def num_rows(self) -> int:
        return self.end_row - self.start_row


@dataclasses.dataclass
class Dataset:
    schema: StarSchema
    fact: TableData
    dims: dict[str, TableData]
    snapshot_id: str = "snap0"
    version: int = 0  # bumped on every append; executors resync caches on it
    partitions: list[Partition] = dataclasses.field(default_factory=list)
    _device: Optional["DeviceDataset"] = dataclasses.field(
        default=None, repr=False, compare=False)

    def device(self) -> "DeviceDataset":
        """The shared device-resident mirror (created on first use, so the
        numpy-oracle path never imports JAX)."""
        if self._device is None:
            self._device = DeviceDataset(self)
        return self._device

    # ---------------------------------------------------------------- append
    def append_rows(
        self, rows: dict[str, np.ndarray], snapshot_id: Optional[str] = None
    ) -> Partition:
        """Append a batch of fact rows (streaming/delta ingest).

        ``rows`` maps every fact column name to an equal-length array of raw
        (decoded-domain) values; dimension tables are immutable — FK values
        must reference existing dimension rows.  Records a :class:`Partition`
        with the batch's row range and date extent, bumps ``version`` (so
        executors resynchronize their row-aligned caches), and drops the
        mirror's fact-aligned device arrays (rebuilt lazily; dimension
        uploads survive).  The input arrays are never mutated.
        """
        missing = set(self.fact.columns) - set(rows)
        extra = set(rows) - set(self.fact.columns)
        if missing or extra:
            raise ValueError(
                f"delta columns must match the fact table exactly: "
                f"missing {sorted(missing)}, unexpected {sorted(extra)}")
        lengths = {len(np.asarray(v)) for v in rows.values()}
        if len(lengths) != 1:
            raise ValueError(f"ragged delta: column lengths {sorted(lengths)}")
        if lengths == {0}:
            raise ValueError("empty delta: nothing to append")
        start = self.fact.num_rows
        # stage every column's conversion before committing any of it: a bad
        # value (e.g. an unparseable date) must raise with the dataset fully
        # intact, never leave it ragged mid-append
        staged = {name: col._appended(np.asarray(rows[name]))
                  for name, col in self.fact.columns.items()}
        # FK bounds are part of the contract (dimension pk == row position):
        # an out-of-range key would commit fine and then crash every later
        # scan's gather, far from the producer bug — reject it here instead
        for dim in self.schema.dimensions:
            td = self.dims.get(dim.name)
            if td is None or dim.fact_fk not in rows:
                continue
            fk = np.asarray(rows[dim.fact_fk])
            if len(fk) and (int(fk.min()) < 0 or int(fk.max()) >= td.num_rows):
                raise ValueError(
                    f"delta {dim.fact_fk} values [{int(fk.min())}, "
                    f"{int(fk.max())}] fall outside dimension "
                    f"{dim.name!r} (rows 0..{td.num_rows - 1})")
        if not self.partitions:
            # retroactive base partition so row provenance covers every row
            self.partitions.append(Partition(
                0, start, *self._date_extent(0, start), self.snapshot_id))
        for name, col in self.fact.columns.items():
            col.data, col.vocab = staged[name]
        end = self.fact.num_rows
        if snapshot_id:
            self.snapshot_id = snapshot_id
        part = Partition(start, end, *self._date_extent(start, end),
                         self.snapshot_id)
        self.partitions.append(part)
        self.version += 1
        if self._device is not None:
            # fact-aligned device arrays are stale (rebuilt lazily); the
            # dimension uploads survive — dimension tables are immutable
            # across appends, and keeping them is what lets a delta tick
            # upload only delta-sized fact data
            self._device.drop_fact_arrays()
        return part

    def _date_extent(self, start: int, end: int):
        """[start, end) date coverage of a fact row range on the schema's
        date column — ISO inclusive start / exclusive end, (None, None) when
        the schema has no date column or the range is empty."""
        date_col = self.schema.fact.date_column
        if date_col is None or end <= start:
            return None, None
        days = self.fact.columns[date_col].data[start:end]
        return days_to_date(int(days.min())), days_to_date(int(days.max()) + 1)

    def slice_rows(self, start: int, end: int) -> "Dataset":
        """View dataset over fact rows [start, end) sharing the dimension
        tables — the delta-scan storage for incremental refresh.  Column
        arrays are numpy views (no copies); the slice gets its own device
        mirror, which uploads only delta-sized fact columns (dimension
        uploads can be shared from the parent's mirror via
        ``DeviceDataset.share_dim_arrays``)."""
        fact = TableData(self.fact.name, {
            n: ColumnData(c.dtype, c.data[start:end], c.vocab)
            for n, c in self.fact.columns.items()})
        return Dataset(self.schema, fact, self.dims, snapshot_id=self.snapshot_id)

    # ------------------------------------------------------------- accessors
    def table(self, name: str) -> TableData:
        if name == self.fact.name:
            return self.fact
        return self.dims[name]

    def column(self, qualified: str) -> ColumnData:
        t, c = qualified.split(".", 1)
        return self.table(t).columns[c]

    def fact_aligned(self, qualified: str) -> np.ndarray:
        """Physical values of ``table.column`` aligned to fact rows (dimension
        columns are gathered through the FK; pk == row position)."""
        t, c = qualified.split(".", 1)
        if t == self.fact.name:
            return self.fact.columns[c].data
        dim = self.schema.dimension(t)
        fk = self.fact.columns[dim.fact_fk].data
        return self.dims[t].columns[c].data[fk]

    # --------------------------------------------------------- hierarchy map
    def level_mapper(self):
        """Build the LevelMapper used by roll-up derivations: maps fine-level
        *decoded* values to coarse-level decoded values via the dim table.

        The fine->coarse LUT for each (dim, fine, coarse) edge of the level
        lattice is built once and memoized on the closure: dimension tables
        are immutable after build (``append_rows`` only grows the fact), so
        re-deriving the mapping on every roll-up probe was pure waste.
        ``None`` (non-summarizable / unknown column) memoizes too."""
        luts: dict[tuple[str, str, str], Optional[dict]] = {}

        def _lut(dim_name: str, fine: str, coarse: str) -> Optional[dict]:
            dim = self.dims.get(dim_name)
            if dim is None:
                return None
            fc, cc = dim.columns.get(fine), dim.columns.get(coarse)
            if fc is None or cc is None:
                return None
            fine_dec = fc.decode(fc.data)
            coarse_dec = cc.decode(cc.data)
            lut: dict = {}
            for f, c in zip(fine_dec, coarse_dec):
                prev = lut.get(f)
                if prev is not None and prev != c:
                    return None  # not summarizable: child with two parents
                lut[f] = c
            return lut

        def mapper(dim_name: str, fine: str, coarse: str, fine_values: np.ndarray):
            edge = (dim_name, fine, coarse)
            if edge not in luts:
                luts[edge] = _lut(dim_name, fine, coarse)
            lut = luts[edge]
            if lut is None:
                return None
            try:
                return np.asarray([lut[v] for v in fine_values])
            except KeyError:
                return None

        return mapper

    def upload_time_ms(self) -> float:
        """Milliseconds spent so far uploading/deriving device arrays."""
        return self._device.upload_ms if self._device is not None else 0.0

    def validate_hierarchies(self) -> list[str]:
        """Check declared-summarizable hierarchies are functional in the data."""
        problems = []
        for d in self.schema.dimensions:
            td = self.dims.get(d.name)
            if td is None:
                continue
            for h in d.hierarchies:
                if not h.summarizable:
                    continue
                for fine, coarse in zip(h.levels, h.levels[1:]):
                    fc, cc = td.columns.get(fine), td.columns.get(coarse)
                    if fc is None or cc is None:
                        continue
                    pairs = {}
                    for f, c in zip(fc.data, cc.data):
                        if pairs.setdefault(int(f), int(c)) != int(c):
                            problems.append(f"{d.name}: {fine}->{coarse} not functional")
                            break
        return problems


class DeviceDataset:
    """Device-resident mirror of a :class:`Dataset` — the JAX executor's
    storage layer.

    Fact columns, dimension columns, and FK gathers are uploaded to the
    accelerator *once per dataset* and memoized; derived arrays (fact-aligned
    f32 casts, level codes, group-id vectors, fused measure blocks, predicate
    column stacks) are computed on-device and memoized under caller-chosen
    keys via :meth:`cache`.  The host numpy ``Dataset`` is untouched and
    remains the fallback for the independent numpy oracle
    (``OlapExecutor(impl='numpy')``).
    """

    def __init__(self, dataset: Dataset):
        import time as _time

        import jax.numpy as jnp  # lazy: the host path never needs JAX

        self._jnp = jnp
        self._time = _time
        self.ds = dataset
        self._store: dict = {}
        # dimension-column uploads live in their own store so it can be
        # *aliased* between mirrors (see ``share_dim_arrays``): every
        # partition/chunk sub-executor of the scan plane then shares one
        # live dim upload instead of each copying a point-in-time snapshot
        self._dim_store: dict = {}
        self.upload_ms = 0.0
        self._timing_depth = 0

    @property
    def num_rows(self) -> int:
        return self.ds.fact.num_rows

    def _map(self, key) -> dict:
        return self._dim_store if key and key[0] == "dimcol" else self._store

    def cache(self, key, build):
        """Memoized device array: ``build()`` may return a host numpy array
        (uploaded) or a jnp array (kept as-is).  Keys are caller-namespaced
        tuples, e.g. ``('aligned', 'customer.c_region')``.  Concurrent
        builders (scan-plane partition threads, the streaming stager) may
        race on a cold key; both build the same value, last write wins —
        benign for pure uploads."""
        store = self._map(key)
        v = store.get(key)
        if v is None:
            # only the outermost frame accrues upload_ms: builders call
            # cache() recursively (aligned -> col/dimcol) and the inner
            # elapsed is already inside the outer measurement
            t0 = self._time.perf_counter()
            self._timing_depth += 1
            try:
                v = self._jnp.asarray(build())
                v.block_until_ready()
            finally:
                self._timing_depth -= 1
            if self._timing_depth == 0:
                self.upload_ms += (self._time.perf_counter() - t0) * 1e3
            store[key] = v
        return v

    def drop(self, key) -> None:
        """Release one memoized device array (executor memo-LRU eviction)."""
        self._map(key).pop(key, None)

    def fact_aligned(self, qualified: str):
        """Device array of ``table.column`` aligned to fact rows; dimension
        columns are gathered through the FK *on device* (upload the dim column
        and the FK once, gather once, cache the result)."""

        def build():
            t, c = qualified.split(".", 1)
            if t == self.ds.fact.name:
                return self.ds.fact.columns[c].data
            dim = self.ds.schema.dimension(t)
            fk = self.cache(
                ("col", f"{self.ds.fact.name}.{dim.fact_fk}"),
                lambda: self.ds.fact.columns[dim.fact_fk].data,
            )
            dcol = self.cache(
                ("dimcol", t, c), lambda: self.ds.dims[t].columns[c].data
            )
            return dcol[fk]

        return self.cache(("aligned", qualified), build)

    def fact_aligned_f32(self, qualified: str):
        return self.cache(
            ("aligned32", qualified),
            lambda: self.fact_aligned(qualified).astype(self._jnp.float32),
        )

    def drop_fact_arrays(self) -> None:
        """Drop every fact-aligned/derived device array (they are stale
        after a fact append), keeping only the dimension-column uploads —
        dimension tables are immutable, so the next scan re-uploads fact
        data only."""
        self._store.clear()

    def share_dim_arrays(self, other: "DeviceDataset") -> None:
        """Alias this mirror's dimension-column store to another mirror's.
        Valid whenever both datasets share the same dimension tables (row
        slices do): ``('dimcol', ...)`` entries are aligned to dimension
        rows, never to fact rows, so a delta-slice or partition mirror
        reuses them as-is and only uploads its own (slice-sized) fact
        columns.  The stores are *live-shared* after the call: a dim upload
        by either mirror is visible to both (and to every other mirror
        aliased to the same store)."""
        if other.ds.dims is not self.ds.dims:
            raise ValueError("device dim arrays can only be shared between "
                             "mirrors of the same dimension tables")
        for key, v in self._dim_store.items():
            other._dim_store.setdefault(key, v)
        self._dim_store = other._dim_store

    def nbytes(self) -> int:
        return int(sum(getattr(v, "nbytes", 0) for v in self._store.values())
                   + sum(getattr(v, "nbytes", 0)
                         for v in self._dim_store.values()))
