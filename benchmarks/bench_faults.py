"""Fault-injection benchmark: availability and tail latency under chaos.

Two experiments:

* **Availability sweep** — a TTL-churning dashboard stream (short ``ttl_s``
  keeps entries expiring, so the backend is exercised constantly and every
  expired entry is a stale-serving candidate) runs under a mixed
  deterministic fault plan (``backend.error`` + ``backend.latency`` +
  ``storage.spill_error`` + ``coldtier.read_error``) at rates 0/1/10/25%,
  once with the full resilience stack (retries, breakers, stale-on-error)
  and once with recovery disabled (containment only — the control).  Per
  cell: availability (success-or-degraded fraction), p50/p99 latency, retry
  and degraded counts, and a **false-hit audit**: every table served — hit,
  miss, or degraded-stale — is compared bit-for-bit against a directly
  executed reference.  Acceptance: at a 10% fault rate the resilient run
  keeps availability >= 99%, and *zero* false hits at every rate in both
  modes.

* **Breaker recovery** — the backend is hard-failed until the tenant's
  backend breaker opens, then healed; the benchmark measures requests-to-open,
  the fail-fast rejections while open, and the wall-clock from open to the
  first served request (the half-open probe closing the breaker).
  Acceptance: the breaker demonstrably closes again.

Writes ``BENCH_faults.json``.

    PYTHONPATH=src python benchmarks/bench_faults.py           # full run
    PYTHONPATH=src python benchmarks/bench_faults.py --quick   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np  # noqa: E402

JOINS = ("JOIN customer ON lineorder.lo_custkey = customer.c_key "
         "JOIN dates ON lineorder.lo_orderdate = dates.d_key ")
GROUPS = ("c_region", "c_nation", "c_city")
MEASURES = ("SUM(lo_revenue) AS rev",
            "SUM(lo_revenue) AS rev, COUNT(*) AS n",
            "MIN(lo_supplycost) AS lo, MAX(lo_supplycost) AS hi")
YEARS = (1992, 1993, 1994, 1995)

RATES = (0.0, 0.01, 0.10, 0.25)
FAULT_SEEDS = (11, 13, 17, 19)


def build_population(n: int) -> list:
    grid = [f"SELECT {g}, {m} FROM lineorder {JOINS}"
            f"WHERE d_year = {y} GROUP BY {g}"
            for y in YEARS for g in GROUPS for m in MEASURES]
    return grid[:n]


def zipf_stream(n_queries: int, length: int, seed: int, s: float = 0.8) -> list:
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, n_queries + 1) ** s
    return list(rng.choice(n_queries, size=length, p=w / w.sum()))


def fault_plan(rate: float) -> str:
    points = ("backend.error", "backend.latency",
              "storage.spill_error", "coldtier.read_error")
    return ",".join(f"{p}:{rate}:{seed}"
                    for p, seed in zip(points, FAULT_SEEDS))


def make_service(wl, policy, root: str, ttl_s: float):
    from repro.core import SemanticCache
    from repro.olap.executor import OlapExecutor
    from repro.service import CacheService

    svc = CacheService()
    svc.register_tenant(
        "t", schema=wl.schema,
        backend=OlapExecutor(wl.dataset, impl="numpy"),
        cache=SemanticCache(wl.schema, ttl_s=ttl_s,
                            level_mapper=wl.dataset.level_mapper()),
        resilience=policy)
    svc.open(root)
    return svc


# ------------------------------------------------------- availability sweep


def run_cell(wl, queries, stream, refs, rate: float, policy, root: str,
             ttl_s: float) -> dict:
    from repro.resilience import faults
    from repro.service import QueryRequest

    svc = make_service(wl, policy, root, ttl_s)
    try:
        for q in queries:  # warm: every query cached once, fault-free
            svc.submit(QueryRequest(sql=q, tenant="t"))
        served = errors = degraded = false_hits = 0
        lat_ms = []
        with faults.scoped(fault_plan(rate)):
            for qi in stream:
                t0 = time.perf_counter()
                r = svc.submit(QueryRequest(sql=queries[qi], tenant="t"))
                lat_ms.append((time.perf_counter() - t0) * 1e3)
                if r.status == "error":
                    errors += 1
                    continue
                served += 1
                if r.status == "degraded":
                    degraded += 1
                if r.table is None or not r.table.equals(refs[qi]):
                    false_hits += 1
        stats = svc.tenant("t").stats
        health = svc.health("t")
        return {
            "rate": rate,
            "requests": len(stream),
            "availability": round(served / len(stream), 4),
            "errors": errors,
            "degraded_served": degraded,
            "false_hits": false_hits,
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
            "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
            "retries": stats.retries,
            "shed": stats.shed,
            "breaker_opens": health["breakers"]["backend"]["opens"],
            "store_spill_errors": health["storage"]["spill_errors"],
        }
    finally:
        svc.close()


def availability_sweep(wl, queries, stream, ttl_s: float) -> dict:
    from repro.core.sql_canon import SQLCanonicalizer
    from repro.olap.executor import OlapExecutor
    from repro.resilience import ResiliencePolicy

    canon = SQLCanonicalizer(wl.schema)
    ref_exec = OlapExecutor(wl.dataset, impl="numpy")
    refs = [ref_exec.execute(canon.canonicalize(q)) for q in queries]

    cells = {"resilient": [], "containment_only": []}
    for rate in RATES:
        for mode, policy in (("resilient", ResiliencePolicy()),
                             ("containment_only", ResiliencePolicy.disabled())):
            root = tempfile.mkdtemp(prefix="bench_faults_")
            try:
                cell = run_cell(wl, queries, stream, refs, rate, policy,
                                root, ttl_s)
            finally:
                shutil.rmtree(root, ignore_errors=True)
            cells[mode].append(cell)
            print(f"  rate {rate:>5.0%} {mode:>16}: availability "
                  f"{cell['availability']:.4f}, p99 {cell['p99_ms']:.1f} ms, "
                  f"{cell['degraded_served']} degraded, "
                  f"{cell['retries']} retries, "
                  f"{cell['false_hits']} false hits", flush=True)
    at10 = next(c for c in cells["resilient"] if c["rate"] == 0.10)
    return {
        "ttl_s": ttl_s,
        "fault_points": fault_plan(0.0),
        "rates": list(RATES),
        "resilient": cells["resilient"],
        "containment_only": cells["containment_only"],
        "zero_false_hits": all(
            c["false_hits"] == 0
            for cs in cells.values() for c in cs),
        "availability_at_10pct": at10["availability"],
        "meets_99pct_criterion": bool(at10["availability"] >= 0.99),
    }


# --------------------------------------------------------- breaker recovery


class SwitchableBackend:
    def __init__(self, inner):
        self.inner = inner
        self.down = False

    def execute(self, sig):
        if self.down:
            raise RuntimeError("backend down (benchmark outage)")
        return self.inner.execute(sig)

    def execute_raw(self, sql):
        return self.inner.execute_raw(sql)


def breaker_recovery_experiment(wl) -> dict:
    from repro.olap.executor import OlapExecutor
    from repro.resilience import ResiliencePolicy
    from repro.service import CacheService, QueryRequest

    recovery_s = 0.25
    be = SwitchableBackend(OlapExecutor(wl.dataset, impl="numpy"))
    svc = CacheService()
    svc.register_tenant(
        "t", schema=wl.schema, backend=be,
        resilience=ResiliencePolicy(execute_attempts=1, breaker_failures=3,
                                    breaker_recovery_s=recovery_s,
                                    serve_stale=False))
    breaker = svc.tenant("t").resilience.backend
    queries = iter(build_population(36))

    be.down = True
    to_open = 0
    while breaker.snapshot()["state"] != "open":
        svc.submit(QueryRequest(sql=next(queries), tenant="t"))
        to_open += 1
    t_open = time.perf_counter()
    be.down = False  # the dependency heals; the breaker still gates it

    recovery_ms = None
    while True:
        r = svc.submit(QueryRequest(sql=next(queries), tenant="t"))
        if r.status == "miss":
            recovery_ms = (time.perf_counter() - t_open) * 1e3
            break
        if time.perf_counter() - t_open > 10.0:
            break
        time.sleep(0.02)
    snap = breaker.snapshot()
    return {
        "breaker_failures_threshold": 3,
        "recovery_s_config": recovery_s,
        "requests_to_open": to_open,
        "fail_fast_rejections_while_open": snap["rejections"],
        "open_to_served_ms": (round(recovery_ms, 1)
                              if recovery_ms is not None else None),
        "final_state": snap["state"],
        "opens": snap["opens"],
        "closes": snap["closes"],
        "recovered": bool(snap["state"] == "closed" and snap["closes"] >= 1),
    }


# ---------------------------------------------------------------- driver


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=40_000, help="SSB fact rows")
    ap.add_argument("--population", type=int, default=24,
                    help="distinct queries in the Zipf population")
    ap.add_argument("--requests", type=int, default=1_000,
                    help="Zipfian stream length per cell")
    ap.add_argument("--ttl-s", type=float, default=0.05,
                    help="cache TTL: short enough that the stream keeps "
                         "re-executing and stale candidates always exist")
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 6k rows, 250 requests per cell")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.requests, args.population = 6_000, 250, 18

    from repro.workloads import ssb

    print(f"building SSB: {args.rows:,} fact rows ...", flush=True)
    wl = ssb.build(n_fact=args.rows, seed=0)
    queries = build_population(args.population)
    stream = zipf_stream(len(queries), args.requests, seed=23)

    print("availability sweep: fault rates x resilience on/off ...",
          flush=True)
    sweep = availability_sweep(wl, queries, stream, args.ttl_s)

    print("breaker recovery: open -> half-open -> close ...", flush=True)
    rec = breaker_recovery_experiment(wl)
    print(f"  opened after {rec['requests_to_open']} failures, "
          f"{rec['fail_fast_rejections_while_open']} fail-fast rejections, "
          f"served again {rec['open_to_served_ms']} ms after opening "
          f"({'recovered' if rec['recovered'] else 'STUCK'})")

    report = {
        "config": {"rows": args.rows, "population": args.population,
                   "requests": args.requests, "ttl_s": args.ttl_s,
                   "quick": args.quick},
        "availability": sweep,
        "breaker_recovery": rec,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")

    if not sweep["zero_false_hits"]:
        raise SystemExit("false hits observed under fault injection")
    if not sweep["meets_99pct_criterion"]:
        raise SystemExit(
            f"availability at 10% fault rate was "
            f"{sweep['availability_at_10pct']:.4f} (< 0.99)")
    if not rec["recovered"]:
        raise SystemExit("backend breaker never closed after the outage")


if __name__ == "__main__":
    main()
